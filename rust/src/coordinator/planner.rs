//! Fusion-group planner — the Fig 7 trade-off search as a first-class
//! serving component.
//!
//! Given a network and the platform budget, enumerate contiguous-group
//! fusion plans, cost each with the closed-form cycle model and the
//! structural resource model, discard plans that do not fit the board, and
//! pick the objective's winner. The paper's §V discussion (fuse more early —
//! intermediate volumes are huge; spend DSPs on depth parallelism late) falls
//! out of the cost model rather than being hard-coded.

use crate::accel::engine::Weights;
use crate::accel::fusion::{enumerate_plans, FusionPlan};
use crate::accel::latency::{plan_cycles_estimate, plan_traffic_bytes};
use crate::config::{AccelConfig, Network};
use crate::resources::{plan_resources, Resources};

/// What the planner optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize estimated cycles (the paper's headline goal).
    Latency,
    /// Minimize off-chip traffic (the paper's bandwidth-constrained goal).
    Traffic,
    /// Minimize cycles, tie-broken by traffic, among plans whose DSP usage
    /// stays under the given fraction of the budget (Fig 7's "allocate
    /// compute to depth parallelism" trade-off).
    LatencyUnderDspCap(u8),
}

/// A costed plan.
#[derive(Debug, Clone)]
pub struct PlanCost {
    pub plan: FusionPlan,
    pub cycles: u64,
    pub traffic_bytes: u64,
    pub resources: Resources,
    pub fits: bool,
}

/// Cost every contiguous plan of the network.
pub fn cost_all_plans(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
) -> Vec<PlanCost> {
    enumerate_plans(net.layers.len())
        .into_iter()
        .map(|plan| {
            let resources = plan_resources(cfg, net, &plan);
            PlanCost {
                cycles: plan_cycles_estimate(cfg, net, &plan),
                traffic_bytes: plan_traffic_bytes(cfg, net, weights, &plan),
                fits: resources.fits(cfg),
                resources,
                plan,
            }
        })
        .collect()
}

/// Pick the best feasible plan under the objective. Returns `None` only if
/// no plan fits the board (not even fully unfused).
pub fn best_plan(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
    objective: Objective,
) -> Option<PlanCost> {
    let mut candidates: Vec<PlanCost> = cost_all_plans(cfg, net, weights)
        .into_iter()
        .filter(|p| p.fits)
        .collect();
    match objective {
        Objective::Latency => {
            candidates.sort_by_key(|p| (p.cycles, p.traffic_bytes));
        }
        Objective::Traffic => {
            candidates.sort_by_key(|p| (p.traffic_bytes, p.cycles));
        }
        Objective::LatencyUnderDspCap(pct) => {
            let cap = cfg.platform.dsp * pct as usize / 100;
            candidates.retain(|p| p.resources.dsp <= cap);
            candidates.sort_by_key(|p| (p.cycles, p.traffic_bytes));
        }
    }
    candidates.into_iter().next()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{tiny_vgg, vgg16_prefix, AccelConfig};
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn setup() -> (AccelConfig, Network, Weights) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        (AccelConfig::paper_default(), net, w)
    }

    #[test]
    fn best_latency_plan_is_heavily_fused() {
        // On the paper's board the whole 7-layer prefix fits fused, and
        // fusion strictly reduces serialization — the latency winner must be
        // a small number of groups.
        let (cfg, net, w) = setup();
        let best = best_plan(&cfg, &net, &w, Objective::Latency).unwrap();
        assert!(
            best.plan.n_groups() <= 2,
            "latency winner has {} groups ({})",
            best.plan.n_groups(),
            best.plan.label()
        );
    }

    #[test]
    fn best_traffic_plan_is_fully_fused() {
        // Traffic is minimized by never spilling intermediates: one group.
        let (cfg, net, w) = setup();
        let best = best_plan(&cfg, &net, &w, Objective::Traffic).unwrap();
        assert_eq!(best.plan.n_groups(), 1, "{}", best.plan.label());
    }

    #[test]
    fn dsp_cap_forces_smaller_groups() {
        let (cfg, net, w) = setup();
        let free = best_plan(&cfg, &net, &w, Objective::Latency).unwrap();
        // Cap DSPs at 20% of the board: full fusion (≈2333 DSPs) no longer
        // fits; the planner must split.
        let capped = best_plan(&cfg, &net, &w, Objective::LatencyUnderDspCap(20)).unwrap();
        assert!(capped.resources.dsp <= cfg.platform.dsp / 5);
        assert!(capped.plan.n_groups() > free.plan.n_groups());
        assert!(capped.cycles >= free.cycles);
    }

    #[test]
    fn all_plans_costed_and_valid() {
        let (cfg, net, w) = setup();
        let costs = cost_all_plans(&cfg, &net, &w);
        assert_eq!(costs.len(), 64);
        for c in &costs {
            assert!(c.plan.is_valid_partition());
            assert!(c.cycles > 0);
            assert!(c.traffic_bytes > 0);
        }
    }

    #[test]
    fn fig7_monotonicity_traffic_vs_dsp() {
        // Along the A..G prefix-fusion path: traffic non-increasing, DSP
        // non-decreasing (the Fig 7 trade-off curve).
        let (cfg, net, w) = setup();
        let pts = crate::accel::fusion::fig7_points(&net);
        let mut last_traffic = u64::MAX;
        let mut last_dsp = 0usize;
        for (label, plan) in pts {
            let traffic = plan_traffic_bytes(&cfg, &net, &w, &plan);
            let dsp = plan_resources(&cfg, &net, &plan).dsp;
            assert!(traffic <= last_traffic, "traffic rose at {label}");
            assert!(dsp >= last_dsp, "dsp fell at {label}");
            last_traffic = traffic;
            last_dsp = dsp;
        }
    }

    #[test]
    fn objectives_pick_the_expected_plan_on_a_small_net() {
        // tiny-vgg: every objective's winner must be *provably* optimal
        // against the exhaustively costed plan space, not just plausible.
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 5);
        let all = cost_all_plans(&cfg, &net, &w);
        let feasible: Vec<&PlanCost> = all.iter().filter(|p| p.fits).collect();
        assert!(!feasible.is_empty());

        let lat = best_plan(&cfg, &net, &w, Objective::Latency).unwrap();
        assert_eq!(
            lat.cycles,
            feasible.iter().map(|p| p.cycles).min().unwrap(),
            "latency winner {} is not cycle-minimal",
            lat.plan.label()
        );

        let tra = best_plan(&cfg, &net, &w, Objective::Traffic).unwrap();
        assert_eq!(
            tra.traffic_bytes,
            feasible.iter().map(|p| p.traffic_bytes).min().unwrap()
        );
        assert_eq!(tra.plan.n_groups(), 1, "min traffic = spill nothing");

        let cap_pct = 10u8;
        let cap = cfg.platform.dsp * cap_pct as usize / 100;
        if let Some(capped) = best_plan(&cfg, &net, &w, Objective::LatencyUnderDspCap(cap_pct)) {
            assert!(capped.resources.dsp <= cap);
            let best_under_cap = feasible
                .iter()
                .filter(|p| p.resources.dsp <= cap)
                .map(|p| p.cycles)
                .min()
                .unwrap();
            assert_eq!(capped.cycles, best_under_cap);
        }
    }

    #[test]
    fn over_budget_plans_marked_unfit_and_never_selected() {
        // Shrink the board until heavy fusion stops fitting: every over-budget
        // plan must be costed with fits = false, and no objective may ever
        // return one.
        let mut cfg = AccelConfig::paper_default();
        cfg.platform.dsp = 700; // full 7-layer fusion needs ≈ 2333 DSPs
        let net = vgg16_prefix();
        let w = Weights::random(&net, 9);
        let all = cost_all_plans(&cfg, &net, &w);
        let n_unfit = all.iter().filter(|p| !p.fits).count();
        assert!(n_unfit > 0, "shrunken board must exclude some plans");
        for p in &all {
            assert_eq!(p.fits, p.resources.fits(&cfg), "{}", p.plan.label());
        }
        for objective in [
            Objective::Latency,
            Objective::Traffic,
            Objective::LatencyUnderDspCap(80),
        ] {
            if let Some(best) = best_plan(&cfg, &net, &w, objective) {
                assert!(best.fits, "{objective:?} selected an unfit plan");
                assert!(best.resources.fits(&cfg));
            }
        }
    }

    #[test]
    fn impossible_budget_yields_no_plan() {
        let mut cfg = AccelConfig::paper_default();
        cfg.platform.dsp = 10;
        cfg.platform.lut = 1000;
        cfg.platform.ff = 1000;
        cfg.platform.bram36 = 1;
        let net = tiny_vgg();
        let w = Weights::random(&net, 3);
        assert!(best_plan(&cfg, &net, &w, Objective::Latency).is_none());
        assert!(cost_all_plans(&cfg, &net, &w).iter().all(|p| !p.fits));
    }

    #[test]
    fn property_planner_respects_budget_and_partition() {
        let cfg = AccelConfig::paper_default();
        prop::check_default(
            "planner-budget",
            |r: &mut Rng| {
                // random cap between 10% and 100%
                (r.range_u64(10, 100) as u8, r.next_u64())
            },
            |&(pct, seed)| {
                let net = tiny_vgg();
                let w = Weights::random(&net, seed);
                match best_plan(&cfg, &net, &w, Objective::LatencyUnderDspCap(pct)) {
                    None => Ok(()), // nothing fits the cap — acceptable
                    Some(p) => {
                        if !p.plan.is_valid_partition() {
                            return Err("invalid partition".into());
                        }
                        if p.resources.dsp > cfg.platform.dsp * pct as usize / 100 {
                            return Err(format!(
                                "dsp {} over cap {}%",
                                p.resources.dsp, pct
                            ));
                        }
                        Ok(())
                    }
                }
            },
        );
    }
}
