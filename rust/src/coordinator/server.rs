//! The serving coordinator: a threaded inference server over the PJRT
//! runtime (tokio is unavailable offline; std::thread + mpsc own the event
//! loop, which for a CPU-bound executor is the right shape anyway).
//!
//! Topology: N client threads → `submit()` → request channel → executor
//! thread (owns the `Runtime`, which is not `Send`-safe to share) →
//! per-request response channels. The executor drives the
//! [`DynamicBatcher`]; each batch executes back-to-back on the compiled
//! plan, amortizing dispatch overhead exactly as the paper's pipeline
//! amortizes its fill latency.

use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::Runtime;
use crate::tensor::NdTensor;

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::metrics::Metrics;

/// An inference request.
pub struct Request {
    pub id: u64,
    pub input: NdTensor,
    /// Plan to execute ("fused", "unfused", ...); None = server default.
    pub plan: Option<String>,
    submitted: Instant,
    reply: Sender<Response>,
}

/// An inference response.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<NdTensor, String>,
    pub latency: Duration,
    pub batch_size: usize,
    pub plan: String,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifacts_dir: PathBuf,
    pub network: String,
    pub default_plan: String,
    pub batch: BatchPolicy,
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Request>,
    next_id: Arc<Mutex<u64>>,
    metrics: Arc<Mutex<Metrics>>,
}

/// A pending response.
pub struct Ticket {
    pub id: u64,
    rx: Receiver<Response>,
}

impl Ticket {
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().context("server dropped the response channel")
    }

    pub fn wait_timeout(self, d: Duration) -> Result<Response> {
        self.rx
            .recv_timeout(d)
            .context("timed out waiting for response")
    }
}

impl ServerHandle {
    /// Submit one input; returns a ticket to wait on.
    pub fn submit(&self, input: NdTensor, plan: Option<&str>) -> Ticket {
        let (reply, rx) = mpsc::channel();
        let id = {
            let mut n = self.next_id.lock().unwrap();
            *n += 1;
            *n
        };
        self.metrics.lock().unwrap().record_request();
        // Send failure means the server stopped; surface via the ticket.
        let _ = self.tx.send(Request {
            id,
            input,
            plan: plan.map(|s| s.to_string()),
            submitted: Instant::now(),
            reply,
        });
        Ticket { id, rx }
    }

    pub fn metrics_json(&self) -> String {
        self.metrics.lock().unwrap().to_json().to_string_pretty()
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }
}

/// The running server.
pub struct Server {
    pub handle: ServerHandle,
    worker: Option<JoinHandle<()>>,
    shutdown_tx: Sender<Request>, // kept so drop can close the channel last
}

impl Server {
    /// Start the executor thread. Loading + compiling the artifacts happens
    /// on that thread (the PJRT client is not shared across threads).
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let worker_metrics = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let wcfg = cfg.clone();

        let worker = std::thread::Builder::new()
            .name("decoilfnet-executor".into())
            .spawn(move || {
                executor_loop(wcfg, rx, worker_metrics, ready_tx);
            })
            .context("spawning executor thread")?;

        // Fail fast if the artifacts are missing/broken.
        ready_rx
            .recv()
            .context("executor thread died during startup")?
            .map_err(|e| anyhow::anyhow!("runtime startup: {e}"))?;

        let handle = ServerHandle {
            tx: tx.clone(),
            next_id: Arc::new(Mutex::new(0)),
            metrics,
        };
        Ok(Server {
            handle,
            worker: Some(worker),
            shutdown_tx: tx,
        })
    }

    /// Stop accepting work and join the executor (drains the queue first).
    pub fn shutdown(mut self) {
        drop(self.shutdown_tx); // close our copy
        let ServerHandle { tx, .. } = self.handle.clone();
        drop(tx);
        drop(self.handle);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn executor_loop(
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Mutex<Metrics>>,
    ready: Sender<Result<(), String>>,
) {
    let runtime = match Runtime::load(&cfg.artifacts_dir, &cfg.network) {
        Ok(rt) => {
            let _ = ready.send(Ok(()));
            rt
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e:#}")));
            return;
        }
    };

    let mut batcher: DynamicBatcher<Request> = DynamicBatcher::new(cfg.batch);
    loop {
        // Wait for work, bounded by the batcher's flush deadline.
        let req = match batcher.next_deadline() {
            None => match rx.recv() {
                Ok(r) => Some(r),
                Err(_) => break, // all senders gone
            },
            Some(deadline) => {
                let now = Instant::now();
                let timeout = deadline.saturating_duration_since(now);
                match rx.recv_timeout(timeout) {
                    Ok(r) => Some(r),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        };
        let now = Instant::now();
        let mut batch = match req {
            Some(r) => batcher.push(r, now),
            None => None,
        };
        if batch.is_none() {
            batch = batcher.poll(Instant::now());
        }
        if let Some(batch) = batch {
            execute_batch(&cfg, &runtime, batch, &metrics);
        }
    }
    // Drain anything still queued at shutdown.
    let rest = batcher.flush();
    if !rest.is_empty() {
        execute_batch(&cfg, &runtime, rest, &metrics);
    }
}

fn execute_batch(
    cfg: &ServerConfig,
    runtime: &Runtime,
    batch: Vec<Request>,
    metrics: &Arc<Mutex<Metrics>>,
) {
    let size = batch.len();
    metrics.lock().unwrap().record_batch(size);
    for req in batch {
        let plan_name = req.plan.as_deref().unwrap_or(&cfg.default_plan);
        let result = runtime
            .plan(plan_name)
            .and_then(|p| p.run(&req.input))
            .map_err(|e| format!("{e:#}"));
        let latency = req.submitted.elapsed();
        {
            let mut m = metrics.lock().unwrap();
            match &result {
                Ok(_) => m.record_response(latency),
                Err(_) => m.record_error(),
            }
        }
        let _ = req.reply.send(Response {
            id: req.id,
            result,
            latency,
            batch_size: size,
            plan: plan_name.to_string(),
        });
    }
}

/// Fleet-mode serving: the coordinator's second front end.
///
/// The threaded [`Server`] drives one board's compiled artifacts with real
/// clients; this entry point drives a *simulated* fleet of boards with an
/// open-loop workload — same planning stack (fusion planner → shard planner),
/// same batching policy semantics, closed-form service times. The fleet may
/// mix board generations (`ccfg.board_specs`), and with a re-shard policy
/// configured the dynamic controller migrates shards under load. It is how
/// capacity questions ("how many boards for this traffic?") are answered
/// without hardware.
pub fn simulate_cluster(
    cfg: &crate::config::AccelConfig,
    net: &crate::config::Network,
    ccfg: &crate::config::ClusterConfig,
) -> std::result::Result<crate::cluster::FleetReport, String> {
    crate::cluster::run_fleet(cfg, net, ccfg)
}

/// [`simulate_cluster`] with a telemetry sink: same fleet-mode front end,
/// but the caller keeps the event trace, window samples and latency
/// sketches the run produced (the CLI's `--trace`/dashboard path).
pub fn simulate_cluster_traced(
    cfg: &crate::config::AccelConfig,
    net: &crate::config::Network,
    ccfg: &crate::config::ClusterConfig,
    sink: &mut crate::cluster::TraceSink,
) -> std::result::Result<crate::cluster::FleetReport, String> {
    crate::cluster::run_fleet_traced(cfg, net, ccfg, sink)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            eprintln!("skipping server test: run `make artifacts` first");
            None
        }
    }

    fn server(dir: PathBuf) -> Server {
        Server::start(ServerConfig {
            artifacts_dir: dir,
            network: "paper-example".into(),
            default_plan: "fused".into(),
            batch: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
            },
        })
        .unwrap()
    }

    #[test]
    fn serves_golden_request() {
        let Some(dir) = artifacts() else { return };
        let srv = server(dir.clone());
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let (input, want) = rt.golden().unwrap();
        let resp = srv.handle.submit(input, None).wait().unwrap();
        let out = resp.result.unwrap();
        assert!(out.max_abs_diff(&want) < 1e-3);
        srv.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered_in_order_of_identity() {
        let Some(dir) = artifacts() else { return };
        let srv = server(dir.clone());
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let (input, want) = rt.golden().unwrap();

        let mut joins = Vec::new();
        for _ in 0..4 {
            let h = srv.handle.clone();
            let input = input.clone();
            let want = want.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..5 {
                    let resp = h.submit(input.clone(), None).wait().unwrap();
                    let out = resp.result.unwrap();
                    assert!(out.max_abs_diff(&want) < 1e-3);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let m = srv.handle.metrics();
        assert_eq!(m.requests, 20);
        assert_eq!(m.responses, 20);
        assert_eq!(m.errors, 0);
        assert!(m.batches <= 20, "batching must coalesce or match");
        srv.shutdown();
    }

    #[test]
    fn per_request_plan_override() {
        let Some(dir) = artifacts() else { return };
        let srv = server(dir.clone());
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let (input, _) = rt.golden().unwrap();
        let a = srv.handle.submit(input.clone(), Some("fused")).wait().unwrap();
        let b = srv.handle.submit(input, Some("unfused")).wait().unwrap();
        assert_eq!(a.plan, "fused");
        assert_eq!(b.plan, "unfused");
        let (ao, bo) = (a.result.unwrap(), b.result.unwrap());
        assert!(ao.max_abs_diff(&bo) < 1e-3, "plans must agree numerically");
        srv.shutdown();
    }

    #[test]
    fn unknown_plan_is_an_error_response_not_a_crash() {
        let Some(dir) = artifacts() else { return };
        let srv = server(dir.clone());
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let (input, _) = rt.golden().unwrap();
        let resp = srv.handle.submit(input.clone(), Some("bogus")).wait().unwrap();
        assert!(resp.result.is_err());
        // server still alive
        let ok = srv.handle.submit(input, None).wait().unwrap();
        assert!(ok.result.is_ok());
        srv.shutdown();
    }

    #[test]
    fn cluster_simulation_needs_no_artifacts() {
        let cfg = crate::config::AccelConfig::paper_default();
        let net = crate::config::vgg16_prefix();
        let mut ccfg = crate::config::ClusterConfig::fleet_default();
        ccfg.requests = 32;
        let r = simulate_cluster(&cfg, &net, &ccfg).unwrap();
        assert_eq!(r.completed, 32);
        assert!(r.throughput_rps > 0.0);
        assert_eq!(r.per_board.len(), ccfg.boards);
    }

    #[test]
    fn startup_failure_reported() {
        let err = Server::start(ServerConfig {
            artifacts_dir: PathBuf::from("/nonexistent"),
            network: "paper-example".into(),
            default_plan: "fused".into(),
            batch: BatchPolicy::default(),
        });
        assert!(err.is_err());
    }
}
