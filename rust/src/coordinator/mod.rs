//! The serving coordinator (L3): dynamic batching, fusion planning, and a
//! threaded inference server over the PJRT runtime, with metrics.
//!
//! vLLM-router-shaped, scaled to this paper: the fusion planner is the
//! paper's Fig 7 search made a first-class serving decision.
pub mod batcher;
pub mod metrics;
pub mod planner;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use planner::{best_plan, cost_all_plans, Objective, PlanCost};
pub use server::{simulate_cluster, simulate_cluster_traced, Server, ServerConfig, ServerHandle};
