//! Dynamic batcher: groups incoming requests into batches bounded by size
//! and queueing delay (the vLLM-router pattern scaled to this system).
//!
//! Pure decision logic — no threads, no clocks — so the policy is exhaustively
//! testable; the server drives it with real time.

use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// A queued item with its arrival time.
#[derive(Debug)]
struct Pending<T> {
    item: T,
    arrived: Instant,
}

/// The batcher: push items, poll for flushes.
#[derive(Debug)]
pub struct DynamicBatcher<T> {
    policy: BatchPolicy,
    queue: Vec<Pending<T>>,
    pub batches_emitted: u64,
    pub items_processed: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        DynamicBatcher {
            policy,
            queue: Vec::new(),
            batches_emitted: 0,
            items_processed: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Enqueue an item at time `now`; returns a full batch if the size bound
    /// tripped.
    pub fn push(&mut self, item: T, now: Instant) -> Option<Vec<T>> {
        self.queue.push(Pending { item, arrived: now });
        if self.queue.len() >= self.policy.max_batch {
            Some(self.flush())
        } else {
            None
        }
    }

    /// Time-based poll: flush if the oldest item exceeded max_wait.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<T>> {
        let oldest = self.queue.first()?.arrived;
        if now.duration_since(oldest) >= self.policy.max_wait {
            Some(self.flush())
        } else {
            None
        }
    }

    /// Deadline the server should wake at to honor max_wait (None if idle).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.first().map(|p| p.arrived + self.policy.max_wait)
    }

    /// Unconditional flush (server shutdown).
    pub fn flush(&mut self) -> Vec<T> {
        self.batches_emitted += 1;
        self.items_processed += self.queue.len() as u64;
        self.queue.drain(..).map(|p| p.item).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn size_bound_flushes() {
        let mut b = DynamicBatcher::new(policy(3, 1000));
        let t = Instant::now();
        assert!(b.push(1, t).is_none());
        assert!(b.push(2, t).is_none());
        let batch = b.push(3, t).unwrap();
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn time_bound_flushes() {
        let mut b = DynamicBatcher::new(policy(100, 10));
        let t0 = Instant::now();
        b.push("a", t0);
        b.push("b", t0 + Duration::from_millis(4));
        assert!(b.poll(t0 + Duration::from_millis(9)).is_none());
        let batch = b.poll(t0 + Duration::from_millis(10)).unwrap();
        assert_eq!(batch, vec!["a", "b"]);
        assert!(b.poll(t0 + Duration::from_secs(1)).is_none(), "empty queue");
    }

    #[test]
    fn deadline_tracks_oldest() {
        let mut b = DynamicBatcher::new(policy(10, 50));
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.push(1, t0);
        b.push(2, t0 + Duration::from_millis(30));
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(50)));
    }

    #[test]
    fn order_preserved_across_flushes() {
        let mut b = DynamicBatcher::new(policy(2, 1000));
        let t = Instant::now();
        let mut out = Vec::new();
        for i in 0..7 {
            if let Some(batch) = b.push(i, t) {
                out.extend(batch);
            }
        }
        out.extend(b.flush());
        assert_eq!(out, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn property_no_loss_no_duplication_order_kept() {
        prop::check_default(
            "batcher-conservation",
            |r: &mut Rng| {
                let n = r.range_usize(0, 50);
                let max_batch = r.range_usize(1, 10);
                // per-item: 0 = push, 1 = push+poll-later
                let polls: Vec<bool> = (0..n).map(|_| r.chance(0.3)).collect();
                (n, max_batch, polls)
            },
            |(n, max_batch, polls)| {
                let mut b = DynamicBatcher::new(policy(*max_batch, 1));
                let t0 = Instant::now();
                let mut out = Vec::new();
                for i in 0..*n {
                    if let Some(batch) = b.push(i, t0) {
                        out.extend(batch);
                    }
                    if polls[i] {
                        // far-future poll forces a time flush
                        if let Some(batch) = b.poll(t0 + Duration::from_secs(10)) {
                            out.extend(batch);
                        }
                    }
                }
                out.extend(b.flush());
                if out == (0..*n).collect::<Vec<_>>() {
                    Ok(())
                } else {
                    Err(format!("got {out:?}"))
                }
            },
        );
    }

    #[test]
    fn counters() {
        let mut b = DynamicBatcher::new(policy(2, 1000));
        let t = Instant::now();
        b.push(1, t);
        b.push(2, t);
        b.push(3, t);
        b.flush();
        assert_eq!(b.batches_emitted, 2);
        assert_eq!(b.items_processed, 3);
    }
}
