//! Serving metrics: counters + latency histogram, exported as JSON.

use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::{percentile_sorted, Summary};

/// Rolling metrics for the serving path.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub errors: u64,
    /// Per-request end-to-end latencies (seconds). Bounded ring.
    latencies: Vec<f64>,
    /// Batch sizes observed.
    batch_sizes: Vec<usize>,
    cap: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            cap: 4096,
            ..Default::default()
        }
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        if self.batch_sizes.len() >= self.cap {
            self.batch_sizes.remove(0);
        }
        self.batch_sizes.push(size);
    }

    pub fn record_response(&mut self, latency: Duration) {
        self.responses += 1;
        if self.latencies.len() >= self.cap {
            self.latencies.remove(0);
        }
        self.latencies.push(latency.as_secs_f64());
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies))
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("requests", self.requests)
            .set("responses", self.responses)
            .set("batches", self.batches)
            .set("errors", self.errors)
            .set("mean_batch_size", self.mean_batch_size());
        if !self.latencies.is_empty() {
            let mut xs = self.latencies.clone();
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            j = j
                .set("latency_p50_ms", percentile_sorted(&xs, 50.0) * 1e3)
                .set("latency_p95_ms", percentile_sorted(&xs, 95.0) * 1e3)
                .set("latency_max_ms", xs[xs.len() - 1] * 1e3);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_summary() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2);
        m.record_response(Duration::from_millis(10));
        m.record_response(Duration::from_millis(20));
        assert_eq!(m.requests, 2);
        assert_eq!(m.responses, 2);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.latency_summary().unwrap();
        assert!((s.median - 0.015).abs() < 1e-9);
    }

    #[test]
    fn json_shape() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_batch(1);
        m.record_response(Duration::from_millis(5));
        let j = m.to_json();
        assert_eq!(j.get("requests").as_u64(), Some(1));
        assert!(j.get("latency_p50_ms").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn ring_is_bounded() {
        let mut m = Metrics::new();
        m.cap = 4;
        for i in 0..10 {
            m.record_response(Duration::from_millis(i));
        }
        assert_eq!(m.responses, 10);
        assert!(m.latency_summary().unwrap().n <= 4);
    }

    #[test]
    fn empty_summary_none() {
        assert!(Metrics::new().latency_summary().is_none());
    }
}
