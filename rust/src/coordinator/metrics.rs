//! Serving metrics: counters + latency histogram, exported as JSON.

use std::time::Duration;

use crate::cluster::telemetry::QuantileSketch;
use crate::util::json::Json;
use crate::util::stats::{percentile_sorted, Summary};

/// Rolling metrics for the serving path.
///
/// The latency/batch-size windows are cursor-based rings: once full, the
/// next sample overwrites the oldest slot in O(1) (the previous
/// `Vec::remove(0)` shifted the whole window per sample). The ring holds
/// the *recent* window for p50/p95/max; the [`QuantileSketch`] runs over
/// *every* response since start, so `latency_p99_ms` reflects the full
/// history at bounded memory.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub errors: u64,
    /// Per-request end-to-end latencies (seconds). Bounded ring.
    latencies: Vec<f64>,
    lat_cursor: usize,
    /// Batch sizes observed. Bounded ring.
    batch_sizes: Vec<usize>,
    batch_cursor: usize,
    cap: usize,
    /// Full-history latency sketch (ms), mergeable across servers.
    sketch: QuantileSketch,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            cap: 4096,
            ..Default::default()
        }
    }

    pub fn record_request(&mut self) {
        self.requests += 1;
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        ring_push(&mut self.batch_sizes, &mut self.batch_cursor, self.cap, size);
    }

    pub fn record_response(&mut self, latency: Duration) {
        self.responses += 1;
        let secs = latency.as_secs_f64();
        ring_push(&mut self.latencies, &mut self.lat_cursor, self.cap, secs);
        self.sketch.record(secs * 1e3);
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies))
        }
    }

    /// p99 over every response since start (sketch estimate, ≤1% relative
    /// error) — not just the ring window.
    pub fn latency_p99_ms(&self) -> Option<f64> {
        if self.sketch.total() == 0 {
            None
        } else {
            Some(self.sketch.quantile(99.0))
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("requests", self.requests)
            .set("responses", self.responses)
            .set("batches", self.batches)
            .set("errors", self.errors)
            .set("mean_batch_size", self.mean_batch_size());
        if !self.latencies.is_empty() {
            let mut xs = self.latencies.clone();
            // NaN-safe total order — a single bad latency sample must not
            // panic metrics serialization (order identical on finite data).
            xs.sort_by(f64::total_cmp);
            j = j
                .set("latency_p50_ms", percentile_sorted(&xs, 50.0) * 1e3)
                .set("latency_p95_ms", percentile_sorted(&xs, 95.0) * 1e3)
                .set("latency_max_ms", xs[xs.len() - 1] * 1e3);
        }
        if let Some(p99) = self.latency_p99_ms() {
            j = j.set("latency_p99_ms", p99);
        }
        j
    }
}

/// O(1) bounded-window insert: grow until `cap`, then overwrite the oldest
/// slot. A `cap` of zero keeps the window empty (counters still advance).
fn ring_push<T>(buf: &mut Vec<T>, cursor: &mut usize, cap: usize, v: T) {
    if cap == 0 {
        buf.clear();
        return;
    }
    if buf.len() > cap {
        // The cap shrank after samples landed: drop down to the new bound
        // once, keeping the most recent tail.
        let excess = buf.len() - cap;
        buf.drain(..excess);
        *cursor = 0;
    }
    if buf.len() < cap {
        buf.push(v);
    } else {
        buf[*cursor] = v;
        *cursor = (*cursor + 1) % cap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_summary() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2);
        m.record_response(Duration::from_millis(10));
        m.record_response(Duration::from_millis(20));
        assert_eq!(m.requests, 2);
        assert_eq!(m.responses, 2);
        assert_eq!(m.mean_batch_size(), 2.0);
        let s = m.latency_summary().unwrap();
        assert!((s.median - 0.015).abs() < 1e-9);
    }

    #[test]
    fn json_shape() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_batch(1);
        m.record_response(Duration::from_millis(5));
        let j = m.to_json();
        assert_eq!(j.get("requests").as_u64(), Some(1));
        assert!(j.get("latency_p50_ms").as_f64().unwrap() > 0.0);
        assert!(j.get("latency_p99_ms").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn ring_is_bounded() {
        let mut m = Metrics::new();
        m.cap = 4;
        for i in 0..10 {
            m.record_response(Duration::from_millis(i));
        }
        assert_eq!(m.responses, 10);
        assert!(m.latency_summary().unwrap().n <= 4);
    }

    #[test]
    fn ring_keeps_the_most_recent_window() {
        let mut m = Metrics::new();
        m.cap = 4;
        for i in 0..10 {
            m.record_response(Duration::from_millis(i));
        }
        // Survivors are the last four samples (6..=9 ms), in ring order.
        let mut win = m.latencies.clone();
        win.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f64> = (6..10).map(|i| i as f64 * 1e-3).collect();
        for (w, e) in win.iter().zip(&want) {
            assert!((w - e).abs() < 1e-12, "window {win:?} != {want:?}");
        }
    }

    #[test]
    fn sketch_p99_covers_evicted_history() {
        let mut m = Metrics::new();
        m.cap = 4;
        // An early 100 ms tail decile, then a flood of 1 ms responses
        // evicts it from the ring — the sketch remembers the full history.
        for _ in 0..10 {
            m.record_response(Duration::from_millis(100));
        }
        for _ in 0..90 {
            m.record_response(Duration::from_millis(1));
        }
        let p99 = m.latency_p99_ms().unwrap();
        assert!(p99 > 50.0, "full-history p99 {p99} must see the outlier");
        let win = m.latency_summary().unwrap();
        assert!(win.n <= 4, "ring stays bounded");
    }

    #[test]
    fn empty_summary_none() {
        assert!(Metrics::new().latency_summary().is_none());
        assert!(Metrics::new().latency_p99_ms().is_none());
    }
}
