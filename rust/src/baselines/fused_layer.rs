//! Baseline [3]: Alwani et al., "Fused-Layer CNN Accelerators" (MICRO 2016).
//!
//! Fused-layer keeps Zhang'15's tiled compute engine but evaluates a fused
//! *pyramid* of early layers: a tile of the final fused output is traced back
//! through the stack, and all intermediate values inside the pyramid stay on
//! chip. Costs: traffic collapses to input + weights + output of the fused
//! stack; compute gains a recomputation overhead on the pyramid's overlapping
//! halos (their Table 3 reports single-digit-% for early VGG layers); BRAM
//! grows to hold the pyramid's intermediate tiles.

use crate::accel::engine::Weights;
use crate::accel::kernels::{forward_network_fx, KernelScratch};
use crate::config::{AccelConfig, Layer, Network};
use crate::fpga::bram::bram18_for;
use crate::tensor::FxTensor;

use super::optimized::{run as run_optimized, OptimizedConfig, OptimizedResult};

/// Result of the fused-layer model.
#[derive(Debug, Clone)]
pub struct FusedLayerResult {
    pub total_cycles: u64,
    pub total_traffic_bytes: u64,
    pub recompute_overhead: f64,
    pub dsp: usize,
    pub bram18: usize,
}

impl FusedLayerResult {
    pub fn total_mb(&self) -> f64 {
        self.total_traffic_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// Recomputation factor of fusing the network into one pyramid with an
/// output tile of `tile × tile`: each conv layer's halo of (K−1)/2 per side
/// widens toward the input and overlapping halo regions between adjacent
/// tiles are recomputed (their alternative caches them; the paper's VGG
/// evaluation recomputes). Regions clip at image borders, so a single tile
/// covering the whole output has zero overhead.
pub fn pyramid_overhead(net: &Network, tile: usize) -> f64 {
    let shapes = net.shapes();
    let final_sh = shapes[net.layers.len()];
    // Per-dimension tile intervals in final-output coordinates.
    let mut ys: Vec<(i64, i64)> = (0..final_sh.h.div_ceil(tile))
        .map(|t| ((t * tile) as i64, (((t + 1) * tile).min(final_sh.h)) as i64))
        .collect();
    let mut xs: Vec<(i64, i64)> = (0..final_sh.w.div_ceil(tile))
        .map(|t| ((t * tile) as i64, (((t + 1) * tile).min(final_sh.w)) as i64))
        .collect();

    let mut extra_work = 0.0f64;
    let mut total_work = 0.0f64;
    for (i, layer) in net.layers.iter().enumerate().rev() {
        match layer {
            Layer::Conv { kernel, .. } => {
                // Back-propagate intervals: a conv output range [a,b) needs
                // input [a-pad, b-pad+k-1) → length grows by k-1; clip to
                // the layer's input extent.
                let in_sh = shapes[i];
                let grow = (kernel - 1) as i64;
                for (a, b) in ys.iter_mut() {
                    *b += grow;
                    *a = (*a).max(0);
                    *b = (*b).min(in_sh.h as i64 + grow); // clipped at output level below
                }
                for (a, b) in xs.iter_mut() {
                    *b += grow;
                    *a = (*a).max(0);
                    *b = (*b).min(in_sh.w as i64 + grow);
                }
                // Work of this conv layer: traced output positions per tile
                // (the conv's own output extent is shapes[i+1]).
                let out = shapes[i + 1];
                let sum_y: i64 = ys.iter().map(|(a, b)| (b - a).clamp(0, out.h as i64)).sum();
                let sum_x: i64 = xs.iter().map(|(a, b)| (b - a).clamp(0, out.w as i64)).sum();
                let traced = (sum_y * sum_x) as f64;
                let exact = (out.h * out.w) as f64;
                let work_scale = (out.d * kernel * kernel * shapes[i].d) as f64;
                extra_work += (traced - exact).max(0.0) * work_scale;
                total_work += exact * work_scale;
            }
            Layer::MaxPool { stride, window, .. } => {
                let s = *stride as i64;
                let g = (*window as i64) - s;
                for (a, b) in ys.iter_mut() {
                    *a *= s;
                    *b = *b * s + g;
                }
                for (a, b) in xs.iter_mut() {
                    *a *= s;
                    *b = *b * s + g;
                }
            }
        }
    }
    if total_work == 0.0 {
        0.0
    } else {
        extra_work / total_work
    }
}

/// Run the fused-layer model: compute from the Zhang engine scaled by the
/// pyramid recompute overhead; traffic = stack input + all weights + stack
/// output; BRAM = engine tiles + pyramid intermediate storage.
pub fn run(
    cfg: &OptimizedConfig,
    accel: &AccelConfig,
    net: &Network,
    tile: usize,
) -> FusedLayerResult {
    let base: OptimizedResult = run_optimized(cfg, accel, net);
    let overhead = pyramid_overhead(net, tile);
    let cycles = (base.total_cycles as f64 * (1.0 + overhead)).round() as u64;

    let shapes = net.shapes();
    let wb = cfg.word_bytes;
    let in_bytes = (shapes[0].elems() * wb) as u64;
    let out_bytes = (shapes[net.layers.len()].elems() * wb) as u64;
    let weight_bytes: u64 = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| match l {
            Layer::Conv { kernel, filters, .. } => {
                ((kernel * kernel * shapes[i].d * filters + filters) * wb) as u64
            }
            _ => 0,
        })
        .sum();
    let traffic = in_bytes + weight_bytes + out_bytes;

    // Pyramid intermediate tiles: per layer, a (field × field × d) halo tile.
    let mut bram = base.bram18;
    let mut field = tile;
    for (i, layer) in net.layers.iter().enumerate().rev() {
        if let Layer::Conv { kernel, .. } = layer {
            field += kernel - 1;
            bram += bram18_for(field * field, shapes[i].d * wb * 8) / 4;
        }
    }

    FusedLayerResult {
        total_cycles: cycles,
        total_traffic_bytes: traffic,
        recompute_overhead: overhead,
        dsp: base.dsp,
        bram18: bram,
    }
}

/// Functional forward of the fused-layer engine. The pyramid *recomputes*
/// overlapping halos — pure extra movement and duplicated arithmetic on
/// identical inputs — so its values equal a straight layer-by-layer
/// evaluation; like every other functional path in this repo it routes
/// through the one shared kernel
/// ([`crate::accel::kernels::forward_network_fx`]). The cost model above is
/// where the fused-layer-specific behavior (recompute overhead, collapsed
/// traffic, pyramid BRAM) lives.
pub fn forward_fx(net: &Network, weights: &Weights, input: &FxTensor) -> FxTensor {
    let mut scratch = KernelScratch::new();
    forward_network_fx(
        net,
        weights,
        input,
        crate::accel::kernels::default_threads(),
        &mut scratch,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{vgg16_prefix, AccelConfig};

    fn setup() -> (OptimizedConfig, AccelConfig, crate::config::Network) {
        (
            OptimizedConfig::zhang2015(),
            AccelConfig::paper_default(),
            vgg16_prefix(),
        )
    }

    #[test]
    fn traffic_collapses_vs_optimized() {
        // Paper Table IV: Fused 3.64 MB vs Optimized 77.14 MB for VGG-7.
        let (cfg, accel, net) = setup();
        let fused = run(&cfg, &accel, &net, 32);
        let opt = run_optimized(&cfg, &accel, &net);
        assert!(
            fused.total_mb() < opt.total_mb() / 5.0,
            "fused {} MB vs optimized {} MB",
            fused.total_mb(),
            opt.total_mb()
        );
        // input 0.57 + weights 2.2 + output 3.06 ≈ 5.9 MB (the paper's 3.64
        // excludes the final output write; same band).
        assert!((3.0..8.0).contains(&fused.total_mb()));
    }

    #[test]
    fn cycles_in_table4_band() {
        // Paper Table IV: Fused = 11,655k cycles (≈ 6% over Optimized).
        let (cfg, accel, net) = setup();
        let fused = run(&cfg, &accel, &net, 32);
        let opt = run_optimized(&cfg, &accel, &net);
        assert!(fused.total_cycles >= opt.total_cycles);
        let ratio = fused.total_cycles as f64 / opt.total_cycles as f64;
        assert!(
            ratio < 1.35,
            "recompute overhead {ratio} too large for tile=32"
        );
    }

    #[test]
    fn overhead_shrinks_with_tile_size() {
        let (_, _, net) = setup();
        let small = pyramid_overhead(&net, 8);
        let mid = pyramid_overhead(&net, 32);
        let large = pyramid_overhead(&net, 112);
        assert!(small > mid && mid > large, "{small} {mid} {large}");
        assert!(large < 0.2);
    }

    #[test]
    fn functional_forward_is_bit_exact_vs_engine() {
        use crate::accel::Engine;
        use crate::config::paper_test_example;
        use crate::tensor::NdTensor;
        let net = paper_test_example();
        let w = Weights::random(&net, 41);
        let input = NdTensor::random(&net.input.as_slice(), 19, -1.0, 1.0);
        let fused = forward_fx(&net, &w, &input.to_fixed());
        let engine = Engine::new(AccelConfig::paper_default()).forward_fx(&net, &w, &input);
        assert_eq!(fused, engine);
    }

    #[test]
    fn bram_grows_vs_optimized() {
        let (cfg, accel, net) = setup();
        let fused = run(&cfg, &accel, &net, 32);
        assert!(fused.bram18 > cfg.bram18_budget);
    }
}
