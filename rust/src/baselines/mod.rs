//! Baseline systems the paper compares against: the Zhang FPGA'15 tiled
//! accelerator ("Optimized"), the Alwani MICRO'16 fused-layer accelerator,
//! and a measured CPU software reference (im2col + blocked GEMM).
pub mod cpu_ref;
pub mod fused_layer;
pub mod optimized;
