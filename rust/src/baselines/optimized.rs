//! Baseline [2]: Zhang et al., "Optimizing FPGA-based Accelerator Design for
//! Deep Convolutional Neural Networks" (FPGA 2015) — the paper's "Optimized"
//! comparison column in Table IV.
//!
//! Their accelerator processes the network layer by layer with a tiled
//! compute engine (unroll factors ⟨Tm, Tn⟩ over output/input feature maps,
//! tile sizes ⟨Tr, Tc⟩ over rows/cols), all intermediate volumes spilled to
//! DDR, and per-layer tiling chosen by a roofline search. We implement that
//! cost model faithfully: compute cycles, external traffic (with their
//! local-memory-promotion trip counts), BRAM for double-buffered tiles, and
//! DSPs for the ⟨Tm, Tn⟩ MAC array.

use crate::accel::engine::Weights;
use crate::accel::kernels::{conv2d_fx_rows, ConvGeom, KernelScratch};
use crate::accel::pool::PoolUnit;
use crate::config::{AccelConfig, Layer, Network};
use crate::fpga::bram::bram18_for;
use crate::tensor::FxTensor;

/// One layer's chosen tiling and its costs.
#[derive(Debug, Clone)]
pub struct LayerTiling {
    pub name: String,
    pub tm: usize,
    pub tn: usize,
    pub tr: usize,
    pub tc: usize,
    pub cycles: u64,
    pub traffic_bytes: u64,
}

/// Whole-network result of the baseline model.
#[derive(Debug, Clone)]
pub struct OptimizedResult {
    pub per_layer: Vec<LayerTiling>,
    pub total_cycles: u64,
    pub total_traffic_bytes: u64,
    pub dsp: usize,
    pub bram18: usize,
}

impl OptimizedResult {
    pub fn total_mb(&self) -> f64 {
        self.total_traffic_bytes as f64 / (1024.0 * 1024.0)
    }
}

/// Configuration of the baseline engine.
#[derive(Debug, Clone)]
pub struct OptimizedConfig {
    /// DSPs available to the MAC array. Zhang'15 used 32-bit float MACs at
    /// 5 DSPs each on the same XC7V690T (their reported 2880 DSP usage).
    pub dsp_budget: usize,
    pub dsps_per_mac: usize,
    /// BRAM18 budget for tile buffers (double-buffered).
    pub bram18_budget: usize,
    pub word_bytes: usize,
}

impl OptimizedConfig {
    pub fn zhang2015() -> OptimizedConfig {
        OptimizedConfig {
            dsp_budget: 2880,
            dsps_per_mac: 5,
            bram18_budget: 2 * 2085, // their Table: 2085 BRAM36
            word_bytes: 4,
        }
    }
}

/// Evaluate one candidate tiling for a conv layer; returns (cycles, traffic,
/// bram18) or None if the tile buffers do not fit.
#[allow(clippy::too_many_arguments)]
fn evaluate_tiling(
    cfg: &OptimizedConfig,
    m: usize, // output channels
    n: usize, // input channels
    r: usize, // output rows
    c: usize, // output cols
    k: usize, // kernel
    tm: usize,
    tn: usize,
    tr: usize,
    tc: usize,
) -> Option<(u64, u64, usize)> {
    let (tm, tn, tr, tc) = (tm.min(m), tn.min(n), tr.min(r), tc.min(c));
    // On-chip tile buffers (double-buffered, as in the paper):
    // input  : Tn × (Tr+K−1) × (Tc+K−1)
    // weights: Tm × Tn × K × K
    // output : Tm × Tr × Tc
    let wbits = cfg.word_bytes * 8;
    let in_words = (tr + k - 1) * (tc + k - 1);
    let bram = 2
        * (tn * bram18_for(in_words, wbits)
            + tm * tn * bram18_for(k * k, wbits)
            + tm * bram18_for(tr * tc, wbits));
    if bram > cfg.bram18_budget {
        return None;
    }

    let trips_m = m.div_ceil(tm) as u64;
    let trips_n = n.div_ceil(tn) as u64;
    let trips_r = r.div_ceil(tr) as u64;
    let trips_c = c.div_ceil(tc) as u64;

    // Compute: the ⟨Tm,Tn⟩ array performs Tm·Tn MACs/cycle over the tile's
    // Tr·Tc·K·K positions (their eq. for execution cycles).
    let cycles = trips_m * trips_n * trips_r * trips_c * (tr * tc * k * k) as u64;

    // Traffic (local memory promotion, their §4.2): with output stationary
    // across the n loop, outputs move once; inputs and weights move once per
    // (m, n, r, c) trip.
    let b_in = trips_m * trips_n * trips_r * trips_c * (tn * (tr + k - 1) * (tc + k - 1)) as u64;
    let b_w = trips_m * trips_n * trips_r * trips_c * (tm * tn * k * k) as u64;
    // Output written once (the next layer's read-back is counted as *its*
    // input traffic).
    let b_out = (m * r * c) as u64;
    let traffic = (b_in + b_w + b_out) * cfg.word_bytes as u64;
    Some((cycles, traffic, bram))
}

/// Roofline tiling search for one conv layer: minimize cycles, tie-break on
/// traffic (their "lowest bandwidth among highest-throughput designs").
fn search_layer(
    cfg: &OptimizedConfig,
    name: &str,
    m: usize,
    n: usize,
    r: usize,
    c: usize,
    k: usize,
) -> LayerTiling {
    let max_macs = cfg.dsp_budget / cfg.dsps_per_mac;
    // Pass 1: best cycle count. Pass 2 (below): among tilings within 5% of
    // it, minimum traffic — Zhang's "highest throughput, then lowest
    // bandwidth requirement" roofline selection.
    let mut candidates: Vec<(u64, u64, LayerTiling)> = Vec::new();
    // Tm/Tn over divisor-ish candidates; Tr/Tc over a coarse grid (the cost
    // model is smooth in Tr/Tc — full enumeration is unnecessary). The
    // layer's own extents ride along so small nets (tiny-vgg's 8×8 tail,
    // the 5×5 paper example) always have at least the whole-extent tile;
    // for the paper-scale nets r/c are already on the grid, so this adds
    // nothing there.
    let tm_cands: Vec<usize> = (1..=m.min(max_macs)).filter(|t| m % t == 0 || *t == m).collect();
    let tr_cands: Vec<usize> = [4usize, 8, 14, 16, 28, 32, 56, 64, 112, 224]
        .into_iter()
        .chain([r])
        .filter(|&t| t <= r)
        .collect();
    let tc_cands: Vec<usize> = [14usize, 28, 32, 56, 64, 112, 224]
        .into_iter()
        .chain([c])
        .filter(|&t| t <= c)
        .collect();
    for &tm in &tm_cands {
        let tn_max = (max_macs / tm).min(n);
        if tn_max == 0 {
            continue;
        }
        let tn_cands: Vec<usize> =
            (1..=tn_max).filter(|t| n % t == 0 || *t == tn_max).collect();
        for &tn in &tn_cands {
            for &tr in &tr_cands {
                for &tc in &tc_cands {
                    if let Some((cycles, traffic, _)) =
                        evaluate_tiling(cfg, m, n, r, c, k, tm, tn, tr, tc)
                    {
                        candidates.push((
                            cycles,
                            traffic,
                            LayerTiling {
                                name: name.to_string(),
                                tm,
                                tn,
                                tr,
                                tc,
                                cycles,
                                traffic_bytes: traffic,
                            },
                        ));
                    }
                }
            }
        }
    }
    let best_cycles = candidates
        .iter()
        .map(|(c, _, _)| *c)
        .min()
        .expect("no feasible tiling");
    let threshold = best_cycles + best_cycles / 20; // within 5%
    candidates
        .into_iter()
        .filter(|(c, _, _)| *c <= threshold)
        .min_by_key(|(_, t, _)| *t)
        .map(|(_, _, tiling)| tiling)
        .unwrap()
}

/// Run the Zhang'15 model over a network.
pub fn run(cfg: &OptimizedConfig, accel: &AccelConfig, net: &Network) -> OptimizedResult {
    let shapes = net.shapes();
    let mut per_layer = Vec::new();
    let mut total_cycles = 0u64;
    let mut traffic = 0u64;
    let mut max_tm_tn = (1usize, 1usize);
    for (i, layer) in net.layers.iter().enumerate() {
        match layer {
            Layer::Conv { name, kernel, filters, .. } => {
                let in_sh = shapes[i];
                let out_sh = shapes[i + 1];
                let t = search_layer(
                    cfg,
                    name,
                    *filters,
                    in_sh.d,
                    out_sh.h,
                    out_sh.w,
                    *kernel,
                );
                total_cycles += t.cycles;
                traffic += t.traffic_bytes;
                if t.tm * t.tn > max_tm_tn.0 * max_tm_tn.1 {
                    max_tm_tn = (t.tm, t.tn);
                }
                per_layer.push(t);
            }
            Layer::MaxPool { name, window, stride } => {
                // Pooling on their engine: one pass over the input volume,
                // one MAC-array lane per comparison; traffic = in + out.
                let in_sh = shapes[i];
                let out_sh = shapes[i + 1];
                let cycles = (out_sh.elems() * window * window) as u64 / 16;
                let bytes =
                    ((in_sh.elems() + out_sh.elems()) * cfg.word_bytes) as u64;
                total_cycles += cycles;
                traffic += bytes;
                per_layer.push(LayerTiling {
                    name: name.clone(),
                    tm: 1,
                    tn: 1,
                    tr: *stride,
                    tc: *stride,
                    cycles,
                    traffic_bytes: bytes,
                });
            }
        }
    }
    // The first layer's input arrives once; last output leaves once — both
    // already counted in the per-layer traffic above (b_out counts write +
    // read-back; the final layer's read-back never happens, subtract it).
    if let Some(last) = net.layers.len().checked_sub(1) {
        let out_sh = shapes[last + 1];
        traffic -= (out_sh.elems() * cfg.word_bytes) as u64;
    }
    let _ = accel;
    OptimizedResult {
        per_layer,
        total_cycles,
        total_traffic_bytes: traffic,
        dsp: max_tm_tn.0 * max_tm_tn.1 * cfg.dsps_per_mac,
        bram18: cfg.bram18_budget,
    }
}

/// Functional forward of the Zhang'15 engine: every conv layer is evaluated
/// in the roofline-chosen `Tr` output-row tiles, each tile running through
/// the repo's one shared compute kernel
/// ([`crate::accel::kernels::conv2d_fx_rows`]). Tiling is pure data
/// movement — the widened Q16.16 accumulator makes the math
/// order-independent — so this is bit-identical to
/// [`crate::accel::Engine::forward_fx`]; only the cost model above differs.
pub fn forward_fx(
    cfg: &OptimizedConfig,
    accel: &AccelConfig,
    net: &Network,
    weights: &Weights,
    input: &FxTensor,
) -> FxTensor {
    let tilings = run(cfg, accel, net);
    let mut scratch = KernelScratch::new();
    let mut cur = input.clone();
    for (li, layer) in net.layers.iter().enumerate() {
        cur = match layer {
            Layer::Conv { padding, relu, .. } => {
                let banks = weights.banks[li].as_ref().expect("conv layer needs weights");
                let geom = ConvGeom::for_input(&cur, banks, *padding);
                let mut out = FxTensor::zeros(&[geom.out_h(), geom.out_w(), geom.filters]);
                scratch.pack_filters(banks);
                let tr = tilings.per_layer[li].tr.max(1);
                let mut r = 0;
                while r < geom.out_h() {
                    let r1 = (r + tr).min(geom.out_h());
                    conv2d_fx_rows(&cur, banks, *padding, *relu, r..r1, &mut scratch, &mut out);
                    r = r1;
                }
                out
            }
            Layer::MaxPool { window, stride, .. } => PoolUnit::new(*window, *stride).forward(&cur),
        };
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{vgg16_prefix, AccelConfig};

    #[test]
    fn vgg7_cycles_in_table4_band() {
        // Paper Table IV: "Optimized" = 10,951k cycles at 100 MHz for the
        // first 7 VGG-16 layers. Our re-derivation of their roofline model
        // must land in the same band (their exact tile choices differ).
        let r = run(
            &OptimizedConfig::zhang2015(),
            &AccelConfig::paper_default(),
            &vgg16_prefix(),
        );
        let kcycles = r.total_cycles / 1000;
        assert!(
            (8_000..16_000).contains(&kcycles),
            "got {kcycles}k cycles, paper: 10,951k"
        );
    }

    #[test]
    fn vgg7_traffic_tens_of_mb() {
        // Paper Table IV: 77.14 MB per input for [2].
        let r = run(
            &OptimizedConfig::zhang2015(),
            &AccelConfig::paper_default(),
            &vgg16_prefix(),
        );
        let mb = r.total_mb();
        assert!((30.0..120.0).contains(&mb), "got {mb} MB, paper: 77.14");
    }

    #[test]
    fn dsp_within_budget() {
        let cfg = OptimizedConfig::zhang2015();
        let r = run(&cfg, &AccelConfig::paper_default(), &vgg16_prefix());
        assert!(r.dsp <= cfg.dsp_budget);
        assert!(r.dsp >= cfg.dsp_budget / 2, "search should use the array");
    }

    #[test]
    fn compute_bound_lower_limit() {
        // Cycles can never beat total MACs / MAC-array size.
        let cfg = OptimizedConfig::zhang2015();
        let net = vgg16_prefix();
        let r = run(&cfg, &AccelConfig::paper_default(), &net);
        let min_cycles = net.total_macs() / (cfg.dsp_budget / cfg.dsps_per_mac) as u64;
        assert!(r.total_cycles >= min_cycles);
    }

    #[test]
    fn tiled_forward_is_bit_exact_vs_engine() {
        // The baseline's Tr-tiled functional forward and the engine's
        // banded forward share one kernel; tiling must not change a bit.
        use crate::accel::{Engine, Weights};
        use crate::config::tiny_vgg;
        use crate::tensor::NdTensor;
        let net = tiny_vgg();
        let w = Weights::random(&net, 31);
        let input = NdTensor::random(&net.input.as_slice(), 17, -1.0, 1.0);
        let accel = AccelConfig::paper_default();
        let tiled =
            forward_fx(&OptimizedConfig::zhang2015(), &accel, &net, &w, &input.to_fixed());
        let engine = Engine::new(accel).forward_fx(&net, &w, &input);
        assert_eq!(tiled, engine);
    }

    #[test]
    fn tilings_are_feasible() {
        let cfg = OptimizedConfig::zhang2015();
        let r = run(&cfg, &AccelConfig::paper_default(), &vgg16_prefix());
        for t in &r.per_layer {
            assert!(t.tm * t.tn * cfg.dsps_per_mac <= cfg.dsp_budget, "{}", t.name);
            assert!(t.cycles > 0);
        }
    }
}
