//! CPU software baseline — the paper's "CPU-caffe" role.
//!
//! A straightforward but non-strawman CNN forward pass on the host CPU:
//! im2col lowering + a blocked f32 GEMM with a 4×4 register micro-kernel
//! (the same structure caffe/OpenBLAS use, minus vendor-tuned assembly).
//! Wallclock is *measured* on this machine, exactly as the paper measured
//! its Xeon; EXPERIMENTS.md reports the shape (accelerator ≫ CPU), not the
//! paper's absolute Xeon numbers.

use std::time::Instant;

use crate::config::{Layer, Network};
use crate::tensor::NdTensor;

/// im2col: lower the `[h, w, d]` input into a `[out_h*out_w, k*k*d]` matrix
/// for a k×k same/valid conv with zero padding.
pub fn im2col(input: &NdTensor, kernel: usize, padding: usize) -> NdTensor {
    let (h, w, d) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let out_h = h + 2 * padding - kernel + 1;
    let out_w = w + 2 * padding - kernel + 1;
    let cols = kernel * kernel * d;
    let mut out = NdTensor::zeros(&[out_h * out_w, cols]);
    let odata = out.data_mut();
    let idata = input.data();
    for oy in 0..out_h {
        for ox in 0..out_w {
            let row_off = (oy * out_w + ox) * cols;
            for ky in 0..kernel {
                let iy = oy + ky;
                if iy < padding || iy - padding >= h {
                    continue; // stays zero
                }
                let ry = iy - padding;
                for kx in 0..kernel {
                    let ix = ox + kx;
                    if ix < padding || ix - padding >= w {
                        continue;
                    }
                    let rx = ix - padding;
                    let src = (ry * w + rx) * d;
                    let dst = row_off + (ky * kernel + kx) * d;
                    odata[dst..dst + d].copy_from_slice(&idata[src..src + d]);
                }
            }
        }
    }
    out
}

/// Blocked GEMM: `C[m,n] = A[m,k] · B[k,n]`, row-major, with a 4×4
/// register-tiled micro-kernel and k-panel blocking for cache reuse.
pub fn gemm(a: &NdTensor, b: &NdTensor) -> NdTensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "gemm inner dims");
    let mut c = NdTensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    const KC: usize = 256; // k-panel

    let mut kp = 0;
    while kp < k {
        let kend = (kp + KC).min(k);
        let mut i = 0;
        while i < m {
            let mi = (i + 4).min(m);
            let mut j = 0;
            while j < n {
                let nj = (j + 4).min(n);
                // 4×4 micro-kernel over the k-panel, accumulators in regs.
                let mut acc = [[0.0f32; 4]; 4];
                for p in kp..kend {
                    let mut avals = [0.0f32; 4];
                    for (ii, av) in avals.iter_mut().enumerate().take(mi - i) {
                        *av = ad[(i + ii) * k + p];
                    }
                    let brow = &bd[p * n + j..p * n + nj];
                    for ii in 0..mi - i {
                        let av = avals[ii];
                        for (jj, &bv) in brow.iter().enumerate() {
                            acc[ii][jj] += av * bv;
                        }
                    }
                }
                for ii in 0..mi - i {
                    for jj in 0..nj - j {
                        cd[(i + ii) * n + (j + jj)] += acc[ii][jj];
                    }
                }
                j = nj;
            }
            i = mi;
        }
        kp = kend;
    }
    c
}

/// Conv layer via im2col + GEMM. `filters` is `[k_out, kh, kw, d]` (same
/// layout as the accelerator's weights), `bias` is `[k_out]`.
pub fn conv2d(
    input: &NdTensor,
    filters: &NdTensor,
    bias: &NdTensor,
    padding: usize,
    relu: bool,
) -> NdTensor {
    let kf = filters.shape()[0];
    let kernel = filters.shape()[1];
    let d = filters.shape()[3];
    assert_eq!(input.shape()[2], d);
    let (h, w) = (input.shape()[0], input.shape()[1]);
    let out_h = h + 2 * padding - kernel + 1;
    let out_w = w + 2 * padding - kernel + 1;

    let lowered = im2col(input, kernel, padding); // [oh*ow, k*k*d]
    // Weight matrix: [k*k*d, kf]
    let cols = kernel * kernel * d;
    let mut wmat = NdTensor::zeros(&[cols, kf]);
    {
        let wd = wmat.data_mut();
        for f in 0..kf {
            for ky in 0..kernel {
                for kx in 0..kernel {
                    for c in 0..d {
                        wd[((ky * kernel + kx) * d + c) * kf + f] = filters.at4(f, ky, kx, c);
                    }
                }
            }
        }
    }
    let mut prod = gemm(&lowered, &wmat); // [oh*ow, kf]
    {
        let pd = prod.data_mut();
        for row in 0..out_h * out_w {
            for f in 0..kf {
                let v = pd[row * kf + f] + bias.get(&[f]);
                pd[row * kf + f] = if relu { v.max(0.0) } else { v };
            }
        }
    }
    prod.reshape(&[out_h, out_w, kf])
}

/// Max-pool reference.
pub fn maxpool(input: &NdTensor, window: usize, stride: usize) -> NdTensor {
    let (h, w, d) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let (oh, ow) = ((h - window) / stride + 1, (w - window) / stride + 1);
    let mut out = NdTensor::zeros(&[oh, ow, d]);
    for oy in 0..oh {
        for ox in 0..ow {
            for c in 0..d {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..window {
                    for dx in 0..window {
                        m = m.max(input.at3(oy * stride + dy, ox * stride + dx, c));
                    }
                }
                out.set3(oy, ox, c, m);
            }
        }
    }
    out
}

/// Float weights for the CPU path (mirrors `accel::Weights::random` — same
/// seed ⇒ numerically identical parameters before quantization).
#[derive(Debug, Clone)]
pub struct CpuWeights {
    pub tensors: Vec<Option<(NdTensor, NdTensor)>>,
}

impl CpuWeights {
    pub fn random(net: &Network, seed: u64) -> CpuWeights {
        let shapes = net.shapes();
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut tensors = Vec::new();
        for (i, layer) in net.layers.iter().enumerate() {
            match layer {
                Layer::Conv { kernel, filters, .. } => {
                    let d = shapes[i].d;
                    let fan_in = (kernel * kernel * d) as f32;
                    let scale = (2.0 / fan_in).sqrt();
                    let filt = NdTensor::random(
                        &[*filters, *kernel, *kernel, d],
                        rng.next_u64(),
                        -scale,
                        scale,
                    );
                    let bias = NdTensor::random(&[*filters], rng.next_u64(), -0.01, 0.01);
                    tensors.push(Some((filt, bias)));
                }
                Layer::MaxPool { .. } => tensors.push(None),
            }
        }
        CpuWeights { tensors }
    }
}

/// Forward pass; returns per-layer cumulative wallclock (the paper's Table II
/// "time after every layer" format) and the final output.
pub fn forward_timed(
    net: &Network,
    weights: &CpuWeights,
    input: &NdTensor,
) -> (NdTensor, Vec<(String, f64)>) {
    let mut cur = input.clone();
    let mut cum = Vec::new();
    let t0 = Instant::now();
    for (i, layer) in net.layers.iter().enumerate() {
        cur = match layer {
            Layer::Conv { padding, relu, .. } => {
                let (f, b) = weights.tensors[i].as_ref().unwrap();
                conv2d(&cur, f, b, *padding, *relu)
            }
            Layer::MaxPool { window, stride, .. } => maxpool(&cur, *window, *stride),
        };
        cum.push((layer.name().to_string(), t0.elapsed().as_secs_f64() * 1e3));
    }
    (cur, cum)
}

/// Forward without timing.
pub fn forward(net: &Network, weights: &CpuWeights, input: &NdTensor) -> NdTensor {
    forward_timed(net, weights, input).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{paper_test_example, tiny_vgg};
    use crate::util::prng::Rng;
    use crate::util::prop;

    /// Direct (non-im2col) conv reference for cross-checking.
    fn conv2d_direct(
        input: &NdTensor,
        filters: &NdTensor,
        bias: &NdTensor,
        padding: usize,
        relu: bool,
    ) -> NdTensor {
        let (h, w, d) = (input.shape()[0], input.shape()[1], input.shape()[2]);
        let kf = filters.shape()[0];
        let kernel = filters.shape()[1];
        let (oh, ow) = (h + 2 * padding - kernel + 1, w + 2 * padding - kernel + 1);
        let mut out = NdTensor::zeros(&[oh, ow, kf]);
        for oy in 0..oh {
            for ox in 0..ow {
                for f in 0..kf {
                    let mut s = bias.get(&[f]);
                    for ky in 0..kernel {
                        for kx in 0..kernel {
                            let (iy, ix) = (oy + ky, ox + kx);
                            if iy < padding || ix < padding {
                                continue;
                            }
                            let (ry, rx) = (iy - padding, ix - padding);
                            if ry >= h || rx >= w {
                                continue;
                            }
                            for c in 0..d {
                                s += input.at3(ry, rx, c) * filters.at4(f, ky, kx, c);
                            }
                        }
                    }
                    out.set3(oy, ox, f, if relu { s.max(0.0) } else { s });
                }
            }
        }
        out
    }

    #[test]
    fn gemm_small_exact() {
        let a = NdTensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = NdTensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = gemm(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn gemm_matches_naive_property() {
        prop::check_default(
            "gemm-vs-naive",
            |r: &mut Rng| {
                (
                    r.range_usize(1, 17),
                    r.range_usize(1, 17),
                    r.range_usize(1, 17),
                    r.next_u64(),
                )
            },
            |&(m, k, n, seed)| {
                let a = NdTensor::random(&[m, k], seed, -1.0, 1.0);
                let b = NdTensor::random(&[k, n], seed ^ 1, -1.0, 1.0);
                let c = gemm(&a, &b);
                for i in 0..m {
                    for j in 0..n {
                        let want: f32 =
                            (0..k).map(|p| a.get(&[i, p]) * b.get(&[p, j])).sum();
                        let got = c.get(&[i, j]);
                        if (got - want).abs() > 1e-3 {
                            return Err(format!("C[{i},{j}] {got} vs {want}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn im2col_conv_matches_direct() {
        prop::check_default(
            "im2col-conv-vs-direct",
            |r: &mut Rng| {
                let h = r.range_usize(3, 9);
                let w = r.range_usize(3, 9);
                let d = r.range_usize(1, 4);
                let kf = r.range_usize(1, 4);
                let pad = r.range_usize(0, 1);
                (h, w, d, kf, pad, r.next_u64())
            },
            |&(h, w, d, kf, pad, seed)| {
                let input = NdTensor::random(&[h, w, d], seed, -1.0, 1.0);
                let filt = NdTensor::random(&[kf, 3, 3, d], seed ^ 2, -1.0, 1.0);
                let bias = NdTensor::random(&[kf], seed ^ 3, -0.5, 0.5);
                let got = conv2d(&input, &filt, &bias, pad, false);
                let want = conv2d_direct(&input, &filt, &bias, pad, false);
                let diff = got.max_abs_diff(&want);
                if diff < 1e-4 {
                    Ok(())
                } else {
                    Err(format!("diff {diff}"))
                }
            },
        );
    }

    #[test]
    fn network_forward_shapes_and_relu() {
        let net = tiny_vgg();
        let w = CpuWeights::random(&net, 11);
        let input = NdTensor::random(&net.input.as_slice(), 7, -1.0, 1.0);
        let (out, cum) = forward_timed(&net, &w, &input);
        assert_eq!(out.shape(), &net.shape_after(6).as_slice());
        assert_eq!(cum.len(), 7);
        // cumulative times monotone
        for pair in cum.windows(2) {
            assert!(pair[1].1 >= pair[0].1);
        }
        assert!(out.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn cpu_matches_fixed_point_engine() {
        // The CPU f32 path and the accelerator's Q16.16 path must agree to
        // quantization tolerance on the paper's test example (same seed ⇒
        // same weights).
        use crate::accel::{Engine, Weights};
        use crate::config::AccelConfig;
        let net = paper_test_example();
        let seed = 21;
        let wf = CpuWeights::random(&net, seed);
        let wx = Weights::random(&net, seed);
        let input = NdTensor::random(&net.input.as_slice(), 5, -1.0, 1.0);
        let cpu_out = forward(&net, &wf, &input);
        let fx_out = Engine::new(AccelConfig::paper_default())
            .forward_fx(&net, &wx, &input)
            .to_f32();
        let diff = cpu_out.max_abs_diff(&fx_out);
        assert!(diff < 5e-3, "fixed vs float diff {diff}");
    }
}
