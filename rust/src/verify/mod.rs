//! Simulator ↔ runtime cross-verification (DESIGN.md §Validation-chain #5).
//!
//! The rust engine computes the network in the Q16.16 datapath; the PJRT
//! runtime executes the JAX-lowered float32 HLO. Both consume the *same*
//! weights (the aot.py binaries), so agreement within quantization tolerance
//! verifies the entire stack end to end — kernels, lowering, the runtime's
//! buffer plumbing, and the simulator's arithmetic.

use anyhow::Result;

use crate::accel::{Engine, Weights};
use crate::config::AccelConfig;
use crate::runtime::Runtime;
use crate::tensor::NdTensor;

/// Outcome of one verification run.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub network: String,
    pub plan: String,
    /// max |simulator − runtime| over the final output.
    pub max_abs_diff: f32,
    /// mean |runtime| — scale reference for the tolerance.
    pub mean_abs: f32,
    pub tolerance: f32,
    pub passed: bool,
    /// runtime output vs the aot.py golden output (python-side reference).
    pub golden_diff: f32,
}

/// Default tolerance: the fixed-point datapath quantizes inputs, weights and
/// every layer boundary to Q16.16; with ReLU networks of this depth the
/// accumulated error stays well under 1e-2 absolute for unit-scale data.
pub const DEFAULT_TOLERANCE: f32 = 2e-2;

/// Verify one plan of one network.
pub fn verify_plan(
    rt: &Runtime,
    cfg: &AccelConfig,
    plan_name: &str,
    input: &NdTensor,
    tolerance: f32,
) -> Result<VerifyReport> {
    // Runtime (float HLO) path.
    let plan = rt.plan(plan_name)?;
    let runtime_out = plan.run(input)?;

    // Golden check (python reference, only valid for the golden input).
    let (golden_in, golden_out) = rt.golden()?;
    let golden_diff = if golden_in == *input {
        runtime_out.max_abs_diff(&golden_out)
    } else {
        f32::NAN
    };

    // Simulator (fixed-point) path with the same weights.
    let weights = Weights::from_tensors(&rt.entry.network, rt.weights_tensors()?);
    let engine = Engine::new(cfg.clone());
    let sim_out = engine
        .forward_fx(&rt.entry.network, &weights, input)
        .to_f32();

    let max_abs_diff = sim_out.max_abs_diff(&runtime_out);
    Ok(VerifyReport {
        network: rt.network_name.clone(),
        plan: plan_name.to_string(),
        max_abs_diff,
        mean_abs: runtime_out.mean_abs(),
        tolerance,
        passed: max_abs_diff <= tolerance,
        golden_diff,
    })
}

/// Verify every plan of a network against the golden input.
pub fn verify_all(rt: &Runtime, cfg: &AccelConfig) -> Result<Vec<VerifyReport>> {
    let (input, _) = rt.golden()?;
    rt.plan_names()
        .into_iter()
        .map(|p| verify_plan(rt, cfg, p, &input, DEFAULT_TOLERANCE))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            eprintln!("skipping verify test: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn simulator_matches_runtime_paper_example() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let reports = verify_all(&rt, &AccelConfig::paper_default()).unwrap();
        assert!(!reports.is_empty());
        for r in reports {
            assert!(
                r.passed,
                "{} / {}: diff {} > tol {}",
                r.network, r.plan, r.max_abs_diff, r.tolerance
            );
            assert!(r.golden_diff < 1e-3, "runtime vs golden: {}", r.golden_diff);
        }
    }

    #[test]
    fn simulator_matches_runtime_tiny_vgg() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::load(&dir, "tiny-vgg").unwrap();
        let reports = verify_all(&rt, &AccelConfig::paper_default()).unwrap();
        for r in reports {
            assert!(
                r.passed,
                "{} / {}: diff {} > tol {} (mean |y| {})",
                r.network, r.plan, r.max_abs_diff, r.tolerance, r.mean_abs
            );
        }
    }
}
