//! Line-buffer windowing (paper §III-A, Figs 2–3).
//!
//! The input arrives as a serial row-major stream of (depth-concatenated)
//! pixels. A line buffer of `win` rows plus a `win × win` window register
//! chain yields one valid convolution window per cycle after an initial fill,
//! including the zero-padding windows at the borders.
//!
//! Two views are provided:
//!  * [`LineBuffer`] — a functional component that stores pixels and emits
//!    complete windows in output order as the stream advances (used by
//!    fine-grained tests and the component-level demos);
//!  * [`WindowSchedule`] — the pure index arithmetic (which input pixel
//!    triggers which window, which window last uses which pixel) that the
//!    fast timestamp engine uses without materializing data.

/// Index arithmetic for same/valid convolution windows over an `h × w` image
/// streamed row-major, kernel `win`, zero padding `pad` (output is
/// `out_h × out_w` with the standard formula, stride 1 — the paper's conv
/// layers are all stride 1; pooling handles subsampling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowSchedule {
    pub h: usize,
    pub w: usize,
    pub win: usize,
    pub pad: usize,
}

impl WindowSchedule {
    pub fn new(h: usize, w: usize, win: usize, pad: usize) -> WindowSchedule {
        assert!(win >= 1 && h + 2 * pad >= win && w + 2 * pad >= win);
        WindowSchedule { h, w, win, pad }
    }

    pub fn out_h(&self) -> usize {
        self.h + 2 * self.pad - self.win + 1
    }

    pub fn out_w(&self) -> usize {
        self.w + 2 * self.pad - self.win + 1
    }

    pub fn n_windows(&self) -> usize {
        self.out_h() * self.out_w()
    }

    pub fn n_pixels(&self) -> usize {
        self.h * self.w
    }

    /// The row-major index of the last *real* input pixel a window needs.
    /// Window `(r, c)` (output coordinates) covers input rows
    /// `r-pad .. r-pad+win-1` and the analogous columns, clipped to the real
    /// image; the trigger is the bottom-right clipped corner.
    pub fn trigger_pixel(&self, out_r: usize, out_c: usize) -> usize {
        let last_row = (out_r + self.win - 1).saturating_sub(self.pad).min(self.h - 1);
        let last_col = (out_c + self.win - 1).saturating_sub(self.pad).min(self.w - 1);
        last_row * self.w + last_col
    }

    /// The row-major output index of the last window that reads input pixel
    /// `(r, c)` — after that window issues, the pixel's buffer slot is dead
    /// and may be overwritten (the paper's "input can be discarded" insight).
    pub fn last_window_of_pixel(&self, r: usize, c: usize) -> usize {
        let wr = (r + self.pad).min(self.out_h() - 1);
        let wc = (c + self.pad).min(self.out_w() - 1);
        wr * self.out_w() + wc
    }

    /// Line-buffer capacity in pixels: `win` rows (win−1 stored lines plus
    /// the line being filled, as in Fig 2's structure).
    pub fn capacity_pixels(&self) -> usize {
        self.win * self.w
    }

    /// Gather the window values for output position `(r, c)` directly from a
    /// row-major image accessor, zero-padding outside. `get(row, col)` reads
    /// a real pixel. Returns `win*win` values in row-major window order.
    pub fn gather<T: Copy + Default>(
        &self,
        out_r: usize,
        out_c: usize,
        get: impl Fn(usize, usize) -> T,
    ) -> Vec<T> {
        let mut out = Vec::with_capacity(self.win * self.win);
        for dy in 0..self.win {
            for dx in 0..self.win {
                let iy = out_r + dy;
                let ix = out_c + dx;
                // real coords = out + offset - pad; negative or ≥ extent → pad
                if iy < self.pad
                    || ix < self.pad
                    || iy - self.pad >= self.h
                    || ix - self.pad >= self.w
                {
                    out.push(T::default());
                } else {
                    out.push(get(iy - self.pad, ix - self.pad));
                }
            }
        }
        out
    }
}

/// A functional line buffer: push pixels in row-major order; complete padded
/// windows are emitted in output row-major order as soon as their trigger
/// pixel arrives — one `push` may emit several windows (at image edges where
/// padding completes multiple windows at once; steady-state is 1:1, which is
/// how the hardware achieves a window per cycle).
#[derive(Debug, Clone)]
pub struct LineBuffer<T: Copy + Default> {
    sched: WindowSchedule,
    /// Ring of `win` rows; row `r` of the image lives at `r % win`.
    rows: Vec<Vec<T>>,
    pushed: usize,
    next_window: usize,
}

/// An emitted window: output position + the `win × win` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Window<T> {
    pub out_r: usize,
    pub out_c: usize,
    pub values: Vec<T>,
}

impl<T: Copy + Default> LineBuffer<T> {
    pub fn new(sched: WindowSchedule) -> LineBuffer<T> {
        LineBuffer {
            sched,
            rows: vec![vec![T::default(); sched.w]; sched.win],
            pushed: 0,
            next_window: 0,
        }
    }

    pub fn schedule(&self) -> WindowSchedule {
        self.sched
    }

    /// Push the next pixel of the serial stream; returns the windows that
    /// became valid.
    pub fn push(&mut self, value: T) -> Vec<Window<T>> {
        let idx = self.pushed;
        assert!(idx < self.sched.n_pixels(), "pushed past end of image");
        let (r, c) = (idx / self.sched.w, idx % self.sched.w);
        self.rows[r % self.sched.win][c] = value;
        self.pushed += 1;

        let mut out = Vec::new();
        let ow = self.sched.out_w();
        while self.next_window < self.sched.n_windows() {
            let (wr, wc) = (self.next_window / ow, self.next_window % ow);
            if self.sched.trigger_pixel(wr, wc) > idx {
                break;
            }
            out.push(self.extract(wr, wc));
            self.next_window += 1;
        }
        out
    }

    fn extract(&self, out_r: usize, out_c: usize) -> Window<T> {
        let s = self.sched;
        let values = s.gather(out_r, out_c, |r, c| {
            debug_assert!(
                r * s.w + c < self.pushed,
                "window read of un-pushed pixel ({r},{c})"
            );
            // The ring only holds `win` rows; assert the row is still live.
            debug_assert!(
                self.pushed.div_ceil(s.w).saturating_sub(r) <= s.win + 1,
                "window read of overwritten row {r}"
            );
            self.rows[r % s.win][c]
        });
        Window {
            out_r,
            out_c,
            values,
        }
    }

    /// All windows emitted so far.
    pub fn windows_emitted(&self) -> usize {
        self.next_window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    /// Reference: gather directly from a fully materialized image.
    fn naive_windows(img: &[Vec<f32>], sched: WindowSchedule) -> Vec<Window<f32>> {
        let mut out = Vec::new();
        for r in 0..sched.out_h() {
            for c in 0..sched.out_w() {
                out.push(Window {
                    out_r: r,
                    out_c: c,
                    values: sched.gather(r, c, |y, x| img[y][x]),
                });
            }
        }
        out
    }

    fn run_line_buffer(img: &[Vec<f32>], sched: WindowSchedule) -> Vec<Window<f32>> {
        let mut lb = LineBuffer::new(sched);
        let mut got = Vec::new();
        for row in img {
            for &v in row {
                got.extend(lb.push(v));
            }
        }
        got
    }

    fn random_image(rng: &mut Rng, h: usize, w: usize) -> Vec<Vec<f32>> {
        (0..h)
            .map(|_| (0..w).map(|_| rng.range_f32(-1.0, 1.0)).collect())
            .collect()
    }

    #[test]
    fn paper_example_5x5_padded() {
        // The paper's §III test case: 5×5, 3×3 window, padding 1 → 25 windows.
        let mut rng = Rng::new(1);
        let img = random_image(&mut rng, 5, 5);
        let sched = WindowSchedule::new(5, 5, 3, 1);
        assert_eq!(sched.n_windows(), 25);
        assert_eq!(run_line_buffer(&img, sched), naive_windows(&img, sched));
    }

    #[test]
    fn valid_conv_no_padding() {
        let mut rng = Rng::new(2);
        let img = random_image(&mut rng, 6, 4);
        let sched = WindowSchedule::new(6, 4, 3, 0);
        assert_eq!(sched.out_h(), 4);
        assert_eq!(sched.out_w(), 2);
        assert_eq!(run_line_buffer(&img, sched), naive_windows(&img, sched));
    }

    #[test]
    fn property_line_buffer_equals_naive() {
        prop::check_default(
            "line-buffer-vs-naive",
            |r: &mut Rng| {
                let h = r.range_usize(3, 12);
                let w = r.range_usize(3, 12);
                let win = *[1usize, 3, 5].get(r.range_usize(0, 2)).unwrap();
                let win = win.min(h).min(w);
                let pad = r.range_usize(0, win / 2);
                (h, w, win, pad, r.next_u64())
            },
            |&(h, w, win, pad, seed)| {
                let mut rng = Rng::new(seed);
                let img = random_image(&mut rng, h, w);
                let sched = WindowSchedule::new(h, w, win, pad);
                let got = run_line_buffer(&img, sched);
                let want = naive_windows(&img, sched);
                if got == want {
                    Ok(())
                } else {
                    Err(format!(
                        "mismatch for h={h} w={w} win={win} pad={pad}: {} vs {} windows",
                        got.len(),
                        want.len()
                    ))
                }
            },
        );
    }

    #[test]
    fn windows_arrive_in_output_order_with_steady_rate() {
        // Steady state: away from edges, each push yields exactly one window
        // (the paper's "new window at each clock cycle").
        let sched = WindowSchedule::new(8, 8, 3, 1);
        let mut lb = LineBuffer::<f32>::new(sched);
        let mut per_push = Vec::new();
        for i in 0..64 {
            per_push.push(lb.push(i as f32).len());
        }
        assert_eq!(per_push.iter().sum::<usize>(), sched.n_windows());
        // Interior pushes yield exactly 1; allow >1 only at row boundaries.
        for (i, &n) in per_push.iter().enumerate() {
            let (r, c) = (i / 8, i % 8);
            if (2..7).contains(&r) && (1..7).contains(&c) {
                assert_eq!(n, 1, "push ({r},{c}) emitted {n}");
            }
        }
    }

    #[test]
    fn trigger_pixel_monotone_within_rows_and_bounded() {
        // Trigger indices are monotone along each output row; across rows the
        // bottom padded rows legitimately regress (their windows burst out
        // after the final pixel and are serialized by the conv II) — the
        // timestamp engine takes a running max, so only within-row
        // monotonicity and boundedness are required.
        let sched = WindowSchedule::new(7, 5, 3, 1);
        for r in 0..sched.out_h() {
            let mut last = 0usize;
            for c in 0..sched.out_w() {
                let t = sched.trigger_pixel(r, c);
                assert!(t < sched.n_pixels());
                assert!(c == 0 || t >= last, "trigger not monotone at ({r},{c})");
                last = t;
            }
        }
    }

    #[test]
    fn ring_reuse_is_safe() {
        // The engine's ring-buffer invariant: by the time pixel i + capacity
        // arrives (and wants pixel i's slot), the last window reading pixel i
        // must already be schedulable — trigger(last_window(i)) ≤ i + C.
        for (h, w, win, pad) in [(6, 6, 3, 1), (8, 5, 3, 1), (9, 9, 5, 2), (7, 4, 3, 0)] {
            let sched = WindowSchedule::new(h, w, win, pad);
            let cap = sched.capacity_pixels();
            for r in 0..h {
                for c in 0..w {
                    let i = r * w + c;
                    if i + cap >= sched.n_pixels() {
                        continue; // slot never reused
                    }
                    let wi = sched.last_window_of_pixel(r, c);
                    assert!(wi < sched.n_windows());
                    let (wr, wc) = (wi / sched.out_w(), wi % sched.out_w());
                    assert!(
                        sched.trigger_pixel(wr, wc) <= i + cap,
                        "pixel ({r},{c}) still live when its slot is reused (win={win} pad={pad})"
                    );
                }
            }
        }
    }

    #[test]
    fn window_1x1_is_identity() {
        let mut rng = Rng::new(3);
        let img = random_image(&mut rng, 4, 4);
        let sched = WindowSchedule::new(4, 4, 1, 0);
        let got = run_line_buffer(&img, sched);
        assert_eq!(got.len(), 16);
        for w in &got {
            assert_eq!(w.values, vec![img[w.out_r][w.out_c]]);
        }
    }

    #[test]
    fn capacity_is_win_rows() {
        let sched = WindowSchedule::new(10, 7, 3, 1);
        assert_eq!(sched.capacity_pixels(), 21);
    }

    #[test]
    #[should_panic(expected = "pushed past end")]
    fn over_push_panics() {
        let sched = WindowSchedule::new(2, 2, 1, 0);
        let mut lb = LineBuffer::<f32>::new(sched);
        for _ in 0..5 {
            lb.push(0.0);
        }
    }
}
