//! Pipeline timing algebra: latency / initiation-interval composition and
//! timestamp propagation.
//!
//! Every hardware module in the paper is characterized by two numbers — an
//! initial latency `L` (cycles from first input to first output) and an
//! initiation interval `II` (cycles between successive outputs once primed).
//! The whole DeCoILFNet pipeline is a composition of such stages; this module
//! provides the algebra and the per-element timestamp propagation the
//! streaming engine uses.

/// A pipelined stage: output appears `latency` cycles after its input, and
/// the stage accepts a new input at most every `ii` cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stage {
    pub latency: u64,
    pub ii: u64,
}

impl Stage {
    pub fn new(latency: u64, ii: u64) -> Stage {
        assert!(ii >= 1, "initiation interval must be ≥ 1");
        Stage { latency, ii }
    }

    /// Fully pipelined stage (II = 1).
    pub fn pipelined(latency: u64) -> Stage {
        Stage { latency, ii: 1 }
    }

    /// Sequential composition: total latency adds; the composite's II is the
    /// max of the two (the slower stage throttles the pipe).
    pub fn then(self, next: Stage) -> Stage {
        Stage {
            latency: self.latency + next.latency,
            ii: self.ii.max(next.ii),
        }
    }

    /// Cycles to process `n` elements through this stage alone, first input
    /// at cycle 0: latency of the first + (n-1) intervals + 1 (the output
    /// cycle itself counts).
    pub fn cycles_for(self, n: u64) -> u64 {
        if n == 0 {
            0
        } else {
            self.latency + (n - 1) * self.ii + 1
        }
    }
}

/// Per-element timestamp propagation through a stage with bounded skid:
/// tracks when each successive element leaves the stage given when it
/// arrived, enforcing the II. This is the exact streaming semantics the
/// engine uses for line-buffer/conv/pool chains.
#[derive(Debug, Clone)]
pub struct StageTracker {
    stage: Stage,
    last_issue: Option<u64>,
}

impl StageTracker {
    pub fn new(stage: Stage) -> StageTracker {
        StageTracker {
            stage,
            last_issue: None,
        }
    }

    /// Element arrives at `t_in`; returns the cycle its result is available.
    /// Issue slot = max(arrival, previous issue + II); result = issue + latency.
    pub fn push(&mut self, t_in: u64) -> u64 {
        let issue = match self.last_issue {
            None => t_in,
            Some(prev) => t_in.max(prev + self.stage.ii),
        };
        self.last_issue = Some(issue);
        issue + self.stage.latency
    }

    /// The issue time of the most recent element (for backpressure coupling).
    pub fn last_issue(&self) -> Option<u64> {
        self.last_issue
    }
}

/// Bounded-capacity FIFO coupling between producer and consumer timestamps —
/// models a line/stream buffer of `capacity` elements: the producer cannot
/// write element `i` until element `i - capacity` has been consumed.
#[derive(Debug, Clone)]
pub struct CapacityGate {
    capacity: usize,
    consumed_at: Vec<u64>,
}

impl CapacityGate {
    pub fn new(capacity: usize) -> CapacityGate {
        assert!(capacity > 0);
        CapacityGate {
            capacity,
            consumed_at: Vec::new(),
        }
    }

    /// Earliest time element `idx` may be accepted, given it was produced at
    /// `t_prod`.
    pub fn accept_time(&self, idx: usize, t_prod: u64) -> u64 {
        if idx >= self.capacity {
            t_prod.max(self.consumed_at[idx - self.capacity])
        } else {
            t_prod
        }
    }

    /// Record that element `idx` was consumed at `t`.
    pub fn mark_consumed(&mut self, idx: usize, t: u64) {
        debug_assert_eq!(idx, self.consumed_at.len(), "consume in order");
        self.consumed_at.push(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn composition_adds_latency_maxes_ii() {
        let mult = Stage::pipelined(9);
        let adder = Stage::pipelined(36);
        let c = mult.then(adder);
        assert_eq!(c.latency, 45);
        assert_eq!(c.ii, 1);

        let slow = Stage::new(5, 3);
        let c2 = c.then(slow);
        assert_eq!(c2.latency, 50);
        assert_eq!(c2.ii, 3);
    }

    #[test]
    fn cycles_for_pipelined() {
        // Paper §III-C: after latency 63, one output per cycle: n outputs in
        // 63 + n cycles.
        let conv = Stage::pipelined(63);
        assert_eq!(conv.cycles_for(1), 64);
        assert_eq!(conv.cycles_for(100), 163);
        assert_eq!(conv.cycles_for(0), 0);
    }

    #[test]
    fn tracker_back_to_back() {
        let mut t = StageTracker::new(Stage::pipelined(10));
        // Inputs arriving every cycle flow through unimpeded.
        assert_eq!(t.push(0), 10);
        assert_eq!(t.push(1), 11);
        assert_eq!(t.push(2), 12);
    }

    #[test]
    fn tracker_enforces_ii() {
        let mut t = StageTracker::new(Stage::new(4, 3));
        assert_eq!(t.push(0), 4); // issue 0
        assert_eq!(t.push(1), 7); // issue max(1, 0+3)=3
        assert_eq!(t.push(2), 10); // issue 6
        assert_eq!(t.push(100), 104); // long gap: issue 100
    }

    #[test]
    fn tracker_stall_propagates() {
        let mut t = StageTracker::new(Stage::pipelined(5));
        assert_eq!(t.push(0), 5);
        assert_eq!(t.push(0), 6); // same-cycle arrival queues behind II=1
        assert_eq!(t.push(0), 7);
    }

    #[test]
    fn capacity_gate_blocks_when_full() {
        let mut g = CapacityGate::new(2);
        // Elements 0,1 accepted immediately.
        assert_eq!(g.accept_time(0, 10), 10);
        g.mark_consumed(0, 50);
        assert_eq!(g.accept_time(1, 11), 11);
        g.mark_consumed(1, 60);
        // Element 2 must wait for element 0's consumption (t=50).
        assert_eq!(g.accept_time(2, 12), 50);
        g.mark_consumed(2, 70);
        // Element 3 waits for element 1 (t=60).
        assert_eq!(g.accept_time(3, 65), 65); // produced later than the gate
    }

    #[test]
    #[should_panic]
    fn zero_ii_rejected() {
        Stage::new(1, 0);
    }
}
