//! Block-RAM model: structural capacity accounting + a functional banked
//! memory with port-conflict checking.
//!
//! Virtex-7 BRAMs come as 18 Kb blocks (pairable into 36 Kb). A BRAM18
//! configures as 16K×1, 8K×2, 4K×4, 2K×9, 1K×18 or 512×36; wider words
//! cascade multiple blocks in parallel. The paper's Table I/IV resource
//! numbers count these blocks, so the resource model needs the same mapping
//! Vivado's inference uses.

/// Capacity of one BRAM18 in data bits (18 Kb including parity; we count the
/// full 18 Kb because the 9/18/36-wide configs use parity bits as data).
pub const BRAM18_BITS: usize = 18 * 1024;

/// Number of BRAM18 blocks needed for a memory of `words` entries of
/// `width_bits` each, mirroring Vivado's width-splitting inference:
/// the word is split across ceil(width/36) physical 36-bit-wide columns
/// (each column as deep as needed), except narrow/shallow cases that fit a
/// single block.
pub fn bram18_for(words: usize, width_bits: usize) -> usize {
    if words == 0 || width_bits == 0 {
        return 0;
    }
    // A single block covers it if total bits fit and width ≤ 36 (a BRAM18's
    // widest port).
    if width_bits <= 36 && words * width_bits <= BRAM18_BITS {
        return 1;
    }
    // Wide words: parallel columns of ≤36 bits.
    let columns = width_bits.div_ceil(36);
    let col_width = width_bits.div_ceil(columns);
    let blocks_per_column = words.div_ceil(bram18_depth_for_width(col_width));
    columns * blocks_per_column
}

/// Depth of one BRAM18 at a given port width, using the discrete Xilinx
/// configs: 16K×1, 8K×2, 4K×4, 2K×9, 1K×18, 512×36.
fn bram18_depth_for_width(width_bits: usize) -> usize {
    match width_bits {
        0 => usize::MAX,
        1 => 16 * 1024,
        2 => 8 * 1024,
        3..=4 => 4 * 1024,
        5..=9 => 2 * 1024,
        10..=18 => 1024,
        _ => 512,
    }
}

/// BRAM36 count (what the paper's tables report) for the same memory.
pub fn bram36_for(words: usize, width_bits: usize) -> usize {
    bram18_for(words, width_bits).div_ceil(2)
}

/// A functional single-bank BRAM with bounded capacity and dual ports:
/// at most one write and one read per cycle (true dual-port simple model).
/// Used by fine-grained component tests; the streaming engine uses the
/// structural accounting only.
#[derive(Debug, Clone)]
pub struct Bram<T: Copy + Default> {
    data: Vec<T>,
    /// Last cycle a write/read port was used (for conflict assertions).
    last_write_cycle: Option<u64>,
    last_read_cycle: Option<u64>,
    pub write_conflicts: u64,
    pub read_conflicts: u64,
}

impl<T: Copy + Default> Bram<T> {
    pub fn new(words: usize) -> Bram<T> {
        Bram {
            data: vec![T::default(); words],
            last_write_cycle: None,
            last_read_cycle: None,
            write_conflicts: 0,
            read_conflicts: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.data.len()
    }

    /// Write port: one write per cycle; extra writes in the same cycle are
    /// counted as conflicts (a real design would have lost data).
    pub fn write(&mut self, cycle: u64, addr: usize, value: T) {
        if self.last_write_cycle == Some(cycle) {
            self.write_conflicts += 1;
        }
        self.last_write_cycle = Some(cycle);
        self.data[addr] = value;
    }

    /// Read port: one read per cycle, data returned same-cycle (the paper's
    /// line buffers use registered outputs — the extra cycle is part of the
    /// module latency constants, not modeled per-access).
    pub fn read(&mut self, cycle: u64, addr: usize) -> T {
        if self.last_read_cycle == Some(cycle) {
            self.read_conflicts += 1;
        }
        self.last_read_cycle = Some(cycle);
        self.data[addr]
    }

    pub fn conflict_free(&self) -> bool {
        self.write_conflicts == 0 && self.read_conflicts == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn single_block_cases() {
        assert_eq!(bram18_for(512, 36), 1);
        assert_eq!(bram18_for(1024, 18), 1);
        assert_eq!(bram18_for(2048, 9), 1);
        assert_eq!(bram18_for(16 * 1024, 1), 1);
    }

    #[test]
    fn zero_cases() {
        assert_eq!(bram18_for(0, 32), 0);
        assert_eq!(bram18_for(100, 0), 0);
    }

    #[test]
    fn wide_word_uses_parallel_columns() {
        // 96-bit depth-concatenated word (3 × 32-bit channels): 3 columns.
        let n = bram18_for(512, 96);
        assert_eq!(n, 3);
        // 64 channels × 32 bits = 2048-bit word: 57 columns of ≤36 bits.
        let n = bram18_for(224, 2048);
        assert_eq!(n, 57);
    }

    #[test]
    fn deep_memory_cascades() {
        // 32-bit × 8192 words = 256 Kb ≥ 15 blocks.
        let n = bram18_for(8192, 32);
        assert!(n >= 15 && n <= 16, "got {n}");
    }

    #[test]
    fn bram36_is_half_rounded_up() {
        assert_eq!(bram36_for(512, 36), 1);
        assert_eq!(bram36_for(512, 96), 2); // 3 BRAM18 → 2 BRAM36
    }

    #[test]
    fn monotone_in_words_and_width() {
        prop::check_default(
            "bram-monotone",
            |r: &mut Rng| {
                (
                    r.range_usize(1, 4096),
                    r.range_usize(1, 256),
                )
            },
            |&(words, width)| {
                let base = bram18_for(words, width);
                if bram18_for(words + 64, width) < base {
                    return Err("more words needed fewer blocks".into());
                }
                if bram18_for(words, width + 8) < base {
                    return Err("wider word needed fewer blocks".into());
                }
                // capacity sanity: blocks must cover the raw bits
                if base * BRAM18_BITS < words * width / 2 {
                    return Err(format!("blocks {base} can't hold {words}x{width}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn functional_bram_rw() {
        let mut b: Bram<u32> = Bram::new(16);
        b.write(0, 3, 99);
        assert_eq!(b.read(1, 3), 99);
        assert_eq!(b.read(2, 0), 0);
        assert!(b.conflict_free());
    }

    #[test]
    fn port_conflicts_detected() {
        let mut b: Bram<u32> = Bram::new(4);
        b.write(5, 0, 1);
        b.write(5, 1, 2); // same-cycle second write
        assert_eq!(b.write_conflicts, 1);
        b.read(6, 0);
        b.read(6, 1);
        assert_eq!(b.read_conflicts, 1);
        assert!(!b.conflict_free());
    }
}
