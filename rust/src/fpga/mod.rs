//! Cycle-level FPGA substrate components: BRAM, line buffers, DSP
//! multiplier pipelines, LUT adder trees, pipeline timing algebra, and the
//! DDR channel. These are the building blocks the DeCoILFNet model in
//! `crate::accel` composes; each is independently tested against naive
//! references.
pub mod bram;
pub mod ddr;
pub mod dsp;
pub mod line_buffer;
pub mod pipeline;
