//! External-memory (DDR) channel model.
//!
//! The paper's whole argument is about off-chip traffic: fused execution
//! moves only group inputs/outputs and weights across DDR, unfused execution
//! moves every intermediate volume. This model tracks bytes per direction and
//! the cycle cost of transfers under a fixed bytes/cycle bandwidth, with the
//! channel serializing requests (one shared bus, as on the paper's board).

/// Transfer direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
}

/// A DDR transfer record (for traces / debugging).
#[derive(Debug, Clone, PartialEq)]
pub struct Transfer {
    pub label: String,
    pub dir: Dir,
    pub bytes: u64,
    pub start_cycle: u64,
    pub end_cycle: u64,
}

/// Shared DDR channel with fixed sustained bandwidth.
#[derive(Debug, Clone)]
pub struct DdrChannel {
    bytes_per_cycle: f64,
    busy_until: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub transfers: Vec<Transfer>,
}

impl DdrChannel {
    pub fn new(bytes_per_cycle: f64) -> DdrChannel {
        assert!(bytes_per_cycle > 0.0);
        DdrChannel {
            bytes_per_cycle,
            busy_until: 0,
            read_bytes: 0,
            write_bytes: 0,
            transfers: Vec::new(),
        }
    }

    /// Issue a transfer of `bytes` no earlier than `earliest`; returns the
    /// completion cycle. The channel is serializing: a transfer begins when
    /// both the requester is ready and the bus is free.
    pub fn transfer(&mut self, label: &str, dir: Dir, bytes: u64, earliest: u64) -> u64 {
        let start = earliest.max(self.busy_until);
        let dur = (bytes as f64 / self.bytes_per_cycle).ceil() as u64;
        let end = start + dur;
        self.busy_until = end;
        match dir {
            Dir::Read => self.read_bytes += bytes,
            Dir::Write => self.write_bytes += bytes,
        }
        self.transfers.push(Transfer {
            label: label.to_string(),
            dir,
            bytes,
            start_cycle: start,
            end_cycle: end,
        });
        end
    }

    /// Account bytes without occupying the bus timeline — used by analytic
    /// baseline models that already fold transfer time into their formulas
    /// but still must report total traffic.
    pub fn account_only(&mut self, label: &str, dir: Dir, bytes: u64) {
        match dir {
            Dir::Read => self.read_bytes += bytes,
            Dir::Write => self.write_bytes += bytes,
        }
        self.transfers.push(Transfer {
            label: label.to_string(),
            dir,
            bytes,
            start_cycle: 0,
            end_cycle: 0,
        });
    }

    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes() as f64 / (1024.0 * 1024.0)
    }

    /// Pure transfer time of `bytes` at this bandwidth (no queueing).
    pub fn cycles_for(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }
}

/// Shared off-chip bandwidth across co-located boards (the cluster model).
///
/// Each board is provisioned with `per_board_bytes_per_cycle` of DDR
/// bandwidth, but boards mounted on one host/backplane draw from an
/// `aggregate_bytes_per_cycle` pool. While fewer boards are active than the
/// pool covers, every board streams at its full provisioned rate; once
/// `n_active · per_board > aggregate`, the memory controller time-slices and
/// every board's off-chip phases stretch by the oversubscription ratio.
/// `aggregate = None` disables the contention model entirely (private
/// channels per board — the idealized scaling baseline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SharedDdr {
    pub per_board_bytes_per_cycle: f64,
    pub aggregate_bytes_per_cycle: Option<f64>,
}

impl SharedDdr {
    pub fn new(per_board: f64, aggregate: Option<f64>) -> SharedDdr {
        assert!(per_board > 0.0);
        if let Some(a) = aggregate {
            assert!(a > 0.0, "aggregate bandwidth must be positive");
        }
        SharedDdr {
            per_board_bytes_per_cycle: per_board,
            aggregate_bytes_per_cycle: aggregate,
        }
    }

    /// Multiplier applied to off-chip phase durations when `n_active` boards
    /// contend. ≥ 1; exactly 1 when contention is disabled or the pool
    /// covers the demand.
    pub fn slowdown(&self, n_active: usize) -> f64 {
        self.slowdown_of(n_active as f64 * self.per_board_bytes_per_cycle)
    }

    /// Slowdown for an explicit aggregate demand in bytes per reference
    /// cycle — the heterogeneous-fleet form, where active boards draw
    /// different provisioned rates and demand is their sum rather than
    /// `n_active · per_board`. ≥ 1 always; exactly 1 when contention is
    /// disabled or the pool covers the demand (exact saturation included).
    pub fn slowdown_of(&self, demand_bytes_per_cycle: f64) -> f64 {
        match self.aggregate_bytes_per_cycle {
            None => 1.0,
            Some(agg) => (demand_bytes_per_cycle / agg).max(1.0),
        }
    }

    /// Extra stall cycles contention adds on top of an off-chip phase that
    /// moves `bytes`. Uncontended, the phase overlaps compute and costs
    /// nothing extra; contended, the stretch beyond the provisioned-rate
    /// duration is pure added stall.
    pub fn stall_cycles(&self, bytes: u64, n_active: usize) -> u64 {
        self.stall_cycles_of(
            bytes,
            self.per_board_bytes_per_cycle,
            n_active as f64 * self.per_board_bytes_per_cycle,
        )
    }

    /// Heterogeneous form of [`SharedDdr::stall_cycles`]: the stall added to
    /// a phase moving `bytes` on a board provisioned at `own_rate` (bytes
    /// per reference cycle) while the fleet draws `demand` in total.
    pub fn stall_cycles_of(&self, bytes: u64, own_rate: f64, demand: f64) -> u64 {
        assert!(own_rate > 0.0);
        let base = bytes as f64 / own_rate;
        ((self.slowdown_of(demand) - 1.0) * base).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_determines_duration() {
        let mut ddr = DdrChannel::new(4.0);
        let end = ddr.transfer("in", Dir::Read, 400, 0);
        assert_eq!(end, 100);
        assert_eq!(ddr.read_bytes, 400);
    }

    #[test]
    fn channel_serializes() {
        let mut ddr = DdrChannel::new(4.0);
        let e1 = ddr.transfer("a", Dir::Read, 40, 0); // 0..10
        assert_eq!(e1, 10);
        let e2 = ddr.transfer("b", Dir::Write, 40, 5); // queued behind a
        assert_eq!(e2, 20);
        let e3 = ddr.transfer("c", Dir::Read, 4, 100); // idle gap
        assert_eq!(e3, 101);
    }

    #[test]
    fn byte_accounting_by_direction() {
        let mut ddr = DdrChannel::new(8.0);
        ddr.transfer("w", Dir::Write, 100, 0);
        ddr.transfer("r", Dir::Read, 50, 0);
        ddr.account_only("extra", Dir::Read, 25);
        assert_eq!(ddr.write_bytes, 100);
        assert_eq!(ddr.read_bytes, 75);
        assert_eq!(ddr.total_bytes(), 175);
        assert_eq!(ddr.transfers.len(), 3);
    }

    #[test]
    fn rounding_up_partial_cycles() {
        let ddr = DdrChannel::new(4.0);
        assert_eq!(ddr.cycles_for(1), 1);
        assert_eq!(ddr.cycles_for(4), 1);
        assert_eq!(ddr.cycles_for(5), 2);
        assert_eq!(ddr.cycles_for(0), 0);
    }

    #[test]
    fn shared_ddr_slowdown_kicks_in_past_the_pool() {
        let s = SharedDdr::new(64.0, Some(128.0));
        assert_eq!(s.slowdown(1), 1.0);
        assert_eq!(s.slowdown(2), 1.0); // 2·64 = 128 exactly covered
        assert_eq!(s.slowdown(4), 2.0); // 4·64 / 128
        assert_eq!(s.slowdown(8), 4.0);
    }

    #[test]
    fn shared_ddr_disabled_never_stalls() {
        let s = SharedDdr::new(64.0, None);
        assert_eq!(s.slowdown(16), 1.0);
        assert_eq!(s.stall_cycles(1 << 20, 16), 0);
    }

    #[test]
    fn shared_ddr_stall_is_the_stretch_beyond_provisioned() {
        let s = SharedDdr::new(64.0, Some(128.0));
        // 4 boards → 2× slowdown → stall equals one extra base duration.
        let bytes = 64 * 1000;
        assert_eq!(s.stall_cycles(bytes, 4), 1000);
        assert_eq!(s.stall_cycles(bytes, 2), 0);
    }

    #[test]
    fn shared_ddr_exact_saturation_is_free() {
        // demand == aggregate exactly: the pool is fully used but nobody
        // waits — the stretch factor must be exactly 1.0, not 1.0 + ε.
        let s = SharedDdr::new(64.0, Some(256.0));
        assert_eq!(s.slowdown(4), 1.0);
        assert_eq!(s.stall_cycles(1 << 24, 4), 0);
        assert_eq!(s.slowdown_of(256.0), 1.0);
        // One byte/cycle past the pool starts stretching.
        assert!(s.slowdown_of(257.0) > 1.0);
    }

    #[test]
    fn shared_ddr_heavy_oversubscription_scales_linearly() {
        let s = SharedDdr::new(64.0, Some(64.0));
        assert_eq!(s.slowdown(64), 64.0);
        assert_eq!(s.slowdown(1024), 1024.0);
        // Stall at 64× is 63 extra base durations.
        assert_eq!(s.stall_cycles(64 * 100, 64), 63 * 100);
    }

    #[test]
    fn shared_ddr_stretch_monotone_and_never_below_one() {
        let s = SharedDdr::new(64.0, Some(160.0));
        let mut last = 0.0f64;
        for n in 1..=64 {
            let sd = s.slowdown(n);
            assert!(sd >= 1.0, "n={n}: slowdown {sd} < 1");
            assert!(sd >= last, "n={n}: slowdown fell {sd} < {last}");
            last = sd;
        }
        // Heterogeneous form: monotone in demand too.
        let mut last = 0.0f64;
        for d in 0..200 {
            let sd = s.slowdown_of(d as f64 * 2.0);
            assert!(sd >= 1.0);
            assert!(sd >= last);
            last = sd;
        }
    }

    #[test]
    fn shared_ddr_hetero_matches_homogeneous_when_uniform() {
        let s = SharedDdr::new(64.0, Some(128.0));
        for n in 1..=8 {
            assert_eq!(s.slowdown(n), s.slowdown_of(n as f64 * 64.0));
            assert_eq!(
                s.stall_cycles(10_000, n),
                s.stall_cycles_of(10_000, 64.0, n as f64 * 64.0)
            );
        }
    }

    #[test]
    fn mb_conversion() {
        let mut ddr = DdrChannel::new(4.0);
        ddr.account_only("x", Dir::Read, 2 * 1024 * 1024);
        assert!((ddr.total_mb() - 2.0).abs() < 1e-9);
    }
}
