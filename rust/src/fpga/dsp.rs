//! DSP multiplier and LUT adder-tree models (paper §III-C).
//!
//! The paper instantiates DSP48 slices only for multipliers and builds adders
//! out of LUTs "so that more computations can be performed in parallel". Both
//! are deeply pipelined: the multiplier has a 9-cycle latency; an n-input
//! adder tree has ceil(log2 n) levels, and the paper charges 9 cycles per
//! level pair — its constant `9*(1 + 2*ceil(log2 w))` for a w×w window
//! breaks down as 9 (multiplier) + 9*2*ceil(log2 3) (the 9-input adder tree
//! folded as two levels of ternary adds of 9-deep pipelines).
//!
//! Functionally both operate on Q16.16 with widened accumulators
//! (`tensor::fixed::MacAcc`).

use crate::fpga::pipeline::Stage;
use crate::tensor::fixed::{Fx, MacAcc};

/// Pipelined multiplier bank: `lanes` parallel DSP multipliers, each with
/// `latency` stages, II = 1.
#[derive(Debug, Clone, Copy)]
pub struct MultiplierBank {
    pub lanes: usize,
    pub latency: u64,
}

impl MultiplierBank {
    pub fn new(lanes: usize, latency: u64) -> MultiplierBank {
        MultiplierBank { lanes, latency }
    }

    /// Timing stage of the bank (parallel lanes share the same latency).
    pub fn stage(&self) -> Stage {
        Stage::pipelined(self.latency)
    }

    /// DSP slices consumed. One 32×32 fixed-point multiplier consumes 4
    /// DSP48E1s when fully hardened (25×18 base multipliers composed);
    /// the paper's Table I count (605 DSPs for two 3-filter... see
    /// resources.rs) is consistent with partially LUT-assisted multipliers —
    /// the resource model owns that policy; here we only report lanes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Functional: elementwise products of two equal-length slices
    /// (one per lane; callers tile longer inputs over lanes).
    pub fn multiply(&self, a: &[Fx], b: &[Fx]) -> Vec<Fx> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| x.mul(*y)).collect()
    }
}

/// LUT adder tree reducing `fan_in` values, pipelined. The paper's latency
/// accounting charges `stage_latency` cycles per reduction *level-pair* —
/// see the module docs; we expose the generic `levels()` and keep the paper's
/// constant via `paper_latency()`.
#[derive(Debug, Clone, Copy)]
pub struct AdderTree {
    pub fan_in: usize,
    /// Cycles charged per ceil(log2) level (paper: 9·2 per level ⇒ use 18
    /// with `levels = ceil(log2 w)` for a w×w window — matching its
    /// `9*(1+2*ceil(log2 w))` total with the multiplier's 9).
    pub cycles_per_level: u64,
}

impl AdderTree {
    pub fn new(fan_in: usize, cycles_per_level: u64) -> AdderTree {
        assert!(fan_in >= 1);
        AdderTree {
            fan_in,
            cycles_per_level,
        }
    }

    /// Reduction levels: ceil(log2(fan_in)).
    pub fn levels(&self) -> u64 {
        (self.fan_in as f64).log2().ceil() as u64
    }

    pub fn stage(&self) -> Stage {
        Stage::pipelined(self.levels() * self.cycles_per_level)
    }

    /// Functional: reduce lanes of widened accumulators into one.
    pub fn reduce(&self, accs: &[MacAcc]) -> MacAcc {
        let mut total = MacAcc::new();
        for a in accs {
            total.add_acc(*a);
        }
        total
    }

    /// Functional over raw products (tests convenience).
    pub fn reduce_fx(&self, vals: &[Fx]) -> Fx {
        let mut acc = MacAcc::new();
        for v in vals {
            acc.mac(*v, Fx::ONE);
        }
        acc.finish()
    }

    /// LUT cost estimate: a W-bit carry-chain adder is ~W LUTs; a tree over
    /// `fan_in` inputs has `fan_in - 1` adders. Accumulator width grows with
    /// depth; we charge the full guard width (48 bits, DSP-accumulator
    /// class) for every node, which upper-bounds Vivado's packing.
    pub fn lut_cost(&self, word_bits: usize) -> usize {
        let adder_bits = word_bits + 16; // guard bits
        (self.fan_in.saturating_sub(1)) * adder_bits
    }

    /// FF cost: each pipeline level registers its partial sums.
    pub fn ff_cost(&self, word_bits: usize) -> usize {
        let adder_bits = word_bits + 16;
        let mut ffs = 0usize;
        let mut nodes = self.fan_in;
        for _ in 0..self.levels() {
            nodes = nodes.div_ceil(2);
            ffs += nodes * adder_bits;
        }
        ffs
    }
}

/// The paper's 2-D convolution arithmetic unit for a w×w window:
/// w² multipliers + a w²-input adder tree. Latency constant per §III-C:
/// `9 * (1 + 2*ceil(log2 w))` — 45 cycles for w = 3.
pub fn conv2d_unit_stage(w: usize, mult_latency: u64) -> Stage {
    let mult = Stage::pipelined(mult_latency);
    let levels = (w as f64).log2().ceil() as u64;
    let adder = Stage::pipelined(mult_latency * 2 * levels);
    mult.then(adder)
}

/// Depth-combination adder stage: summing `d` 2-D conv results costs
/// `9 * ceil(log2 d)` more cycles (paper: 63 total for w=3, d=3).
pub fn depth_sum_stage(d: usize, mult_latency: u64) -> Stage {
    let levels = (d as f64).log2().ceil() as u64;
    Stage::pipelined(mult_latency * levels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn paper_latency_constants() {
        // §III-C: 2-D conv unit for w=3 primes in 45 cycles…
        assert_eq!(conv2d_unit_stage(3, 9).latency, 45);
        // …and the full 3-D conv with d=3 in 45 + 18 = 63.
        let total = conv2d_unit_stage(3, 9).then(depth_sum_stage(3, 9));
        assert_eq!(total.latency, 63);
        assert_eq!(total.ii, 1);
    }

    #[test]
    fn latency_scales_with_window_and_depth() {
        assert_eq!(conv2d_unit_stage(1, 9).latency, 9); // 1×1 conv: mult only
        assert_eq!(conv2d_unit_stage(5, 9).latency, 9 + 18 * 3); // ceil(log2 5)=3
        assert_eq!(depth_sum_stage(64, 9).latency, 54); // log2 64 = 6
        assert_eq!(depth_sum_stage(1, 9).latency, 0);
    }

    #[test]
    fn multiplier_functional() {
        let bank = MultiplierBank::new(9, 9);
        let a: Vec<Fx> = [1.0f32, -2.0, 0.5].iter().map(|&v| Fx::from_f32(v)).collect();
        let b: Vec<Fx> = [3.0f32, 4.0, -8.0].iter().map(|&v| Fx::from_f32(v)).collect();
        let p = bank.multiply(&a, &b);
        let got: Vec<f32> = p.iter().map(|v| v.to_f32()).collect();
        assert_eq!(got, vec![3.0, -8.0, -4.0]);
    }

    #[test]
    fn adder_tree_levels() {
        assert_eq!(AdderTree::new(9, 18).levels(), 4);
        assert_eq!(AdderTree::new(8, 18).levels(), 3);
        assert_eq!(AdderTree::new(2, 18).levels(), 1);
        assert_eq!(AdderTree::new(1, 18).levels(), 0);
    }

    #[test]
    fn adder_tree_reduce_matches_scalar_sum() {
        prop::check_default(
            "adder-tree-sum",
            |r: &mut Rng| {
                let n = r.range_usize(1, 32);
                (0..n).map(|_| r.range_f32(-10.0, 10.0)).collect::<Vec<f32>>()
            },
            |vals| {
                let tree = AdderTree::new(vals.len(), 18);
                let fx: Vec<Fx> = vals.iter().map(|&v| Fx::from_f32(v)).collect();
                let got = tree.reduce_fx(&fx) .to_f64();
                let want: f64 = fx.iter().map(|v| v.to_f64()).sum();
                if (got - want).abs() <= Fx::epsilon() {
                    Ok(())
                } else {
                    Err(format!("sum {got} vs {want}"))
                }
            },
        );
    }

    #[test]
    fn costs_positive_and_scale() {
        let small = AdderTree::new(9, 18);
        let big = AdderTree::new(81, 18);
        assert!(small.lut_cost(32) > 0);
        assert!(big.lut_cost(32) > small.lut_cost(32));
        assert!(big.ff_cost(32) > small.ff_cost(32));
    }
}
