//! Parsing of `artifacts/manifest.json` (written by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::Network;
use crate::util::json::{parse, Json};

/// One compiled fusion group.
#[derive(Debug, Clone)]
pub struct GroupEntry {
    pub index: usize,
    pub lo: usize,
    pub hi: usize,
    pub hlo: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
}

/// One fusion plan (ordered groups).
#[derive(Debug, Clone)]
pub struct PlanEntry {
    pub group_sizes: Vec<usize>,
    pub groups: Vec<GroupEntry>,
}

/// Weight files of one conv layer.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub layer: usize,
    pub name: String,
    pub filter: String,
    pub filter_shape: Vec<usize>,
    pub bias: String,
    pub bias_shape: Vec<usize>,
}

/// Golden verification vectors.
#[derive(Debug, Clone)]
pub struct GoldenEntry {
    pub input: String,
    pub input_shape: Vec<usize>,
    pub output: String,
    pub output_shape: Vec<usize>,
}

/// One network's artifact set.
#[derive(Debug, Clone)]
pub struct NetworkEntry {
    pub network: Network,
    pub weight_seed: u64,
    pub weights: Vec<WeightEntry>,
    pub plans: BTreeMap<String, PlanEntry>,
    pub golden: GoldenEntry,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u64,
    pub networks: BTreeMap<String, NetworkEntry>,
}

fn usize_vec(j: &Json, what: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .with_context(|| format!("{what}: expected array"))?
        .iter()
        .map(|v| v.as_usize().with_context(|| format!("{what}: expected integers")))
        .collect()
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Manifest::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Manifest> {
        let j = parse(text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let mut networks = BTreeMap::new();
        let nets = j
            .get("networks")
            .as_obj()
            .context("manifest missing 'networks'")?;
        for (name, nj) in nets {
            networks.insert(name.clone(), parse_network_entry(nj)?);
        }
        Ok(Manifest {
            version: j.get("version").as_u64().unwrap_or(1),
            networks,
        })
    }
}

fn parse_network_entry(j: &Json) -> Result<NetworkEntry> {
    let network = Network::from_json(j.get("network"))
        .map_err(|e| anyhow::anyhow!("manifest network spec: {e}"))?;

    let mut weights = Vec::new();
    for wj in j.get("weights").as_arr().context("weights")? {
        weights.push(WeightEntry {
            layer: wj.get("layer").as_usize().context("weight.layer")?,
            name: wj.get("name").as_str().context("weight.name")?.to_string(),
            filter: wj.get("filter").as_str().context("weight.filter")?.to_string(),
            filter_shape: usize_vec(wj.get("filter_shape"), "filter_shape")?,
            bias: wj.get("bias").as_str().context("weight.bias")?.to_string(),
            bias_shape: usize_vec(wj.get("bias_shape"), "bias_shape")?,
        });
    }

    let mut plans = BTreeMap::new();
    for (pname, pj) in j.get("plans").as_obj().context("plans")? {
        let mut groups = Vec::new();
        for gj in pj.get("groups").as_arr().context("plan.groups")? {
            groups.push(GroupEntry {
                index: gj.get("index").as_usize().context("group.index")?,
                lo: gj.get("lo").as_usize().context("group.lo")?,
                hi: gj.get("hi").as_usize().context("group.hi")?,
                hlo: gj.get("hlo").as_str().context("group.hlo")?.to_string(),
                in_shape: usize_vec(gj.get("in_shape"), "in_shape")?,
                out_shape: usize_vec(gj.get("out_shape"), "out_shape")?,
            });
        }
        plans.insert(
            pname.clone(),
            PlanEntry {
                group_sizes: usize_vec(pj.get("group_sizes"), "group_sizes")?,
                groups,
            },
        );
    }

    let gj = j.get("golden");
    Ok(NetworkEntry {
        network,
        weight_seed: j.get("weight_seed").as_u64().unwrap_or(0),
        weights,
        plans,
        golden: GoldenEntry {
            input: gj.get("input").as_str().context("golden.input")?.to_string(),
            input_shape: usize_vec(gj.get("input_shape"), "golden.input_shape")?,
            output: gj.get("output").as_str().context("golden.output")?.to_string(),
            output_shape: usize_vec(gj.get("output_shape"), "golden.output_shape")?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "networks": {
        "paper-example": {
          "network": {
            "name": "paper-example",
            "input": {"h": 5, "w": 5, "d": 3},
            "layers": [
              {"type":"conv","name":"conv_a","kernel":3,"filters":3,"stride":1,"padding":1,"relu":true},
              {"type":"conv","name":"conv_b","kernel":3,"filters":3,"stride":1,"padding":1,"relu":true},
              {"type":"maxpool","name":"pool","window":2,"stride":2}
            ]
          },
          "weight_seed": 20180101,
          "weights": [
            {"layer":0,"name":"conv_a","filter":"weights/w0_filter.bin",
             "filter_shape":[3,3,3,3],"bias":"weights/w0_bias.bin","bias_shape":[3]}
          ],
          "plans": {
            "fused": {
              "group_sizes": [3],
              "groups": [
                {"index":0,"lo":0,"hi":3,"hlo":"g0_0_3.hlo.txt",
                 "in_shape":[5,5,3],"out_shape":[2,2,3]}
              ]
            }
          },
          "golden": {
            "input":"golden_input.bin","input_shape":[5,5,3],
            "output":"golden_output.bin","output_shape":[2,2,3]
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_str(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let e = &m.networks["paper-example"];
        assert_eq!(e.network.layers.len(), 3);
        assert_eq!(e.weight_seed, 20180101);
        assert_eq!(e.weights[0].filter_shape, vec![3, 3, 3, 3]);
        let plan = &e.plans["fused"];
        assert_eq!(plan.group_sizes, vec![3]);
        assert_eq!(plan.groups[0].out_shape, vec![2, 2, 3]);
        assert_eq!(e.golden.input_shape, vec![5, 5, 3]);
    }

    #[test]
    fn hand_written_manifest_matches_shape_inference() {
        // Satellite check: a manifest written by hand (as aot.py would emit)
        // round-trips, and every group's lo/hi range and in/out shapes agree
        // with `Network::shapes()` computed independently from the spec.
        let text = r#"{
          "version": 1,
          "networks": {
            "tiny-vgg": {
              "network": {
                "name": "tiny-vgg",
                "input": {"h": 32, "w": 32, "d": 3},
                "layers": [
                  {"type":"conv","name":"conv1_1","kernel":3,"filters":8,"stride":1,"padding":1,"relu":true},
                  {"type":"conv","name":"conv1_2","kernel":3,"filters":8,"stride":1,"padding":1,"relu":true},
                  {"type":"maxpool","name":"pool1","window":2,"stride":2},
                  {"type":"conv","name":"conv2_1","kernel":3,"filters":16,"stride":1,"padding":1,"relu":true},
                  {"type":"conv","name":"conv2_2","kernel":3,"filters":16,"stride":1,"padding":1,"relu":true},
                  {"type":"maxpool","name":"pool2","window":2,"stride":2},
                  {"type":"conv","name":"conv3_1","kernel":3,"filters":32,"stride":1,"padding":1,"relu":true}
                ]
              },
              "weight_seed": 42,
              "weights": [],
              "plans": {
                "fused": {
                  "group_sizes": [7],
                  "groups": [
                    {"index":0,"lo":0,"hi":7,"hlo":"g0_0_7.hlo.txt",
                     "in_shape":[32,32,3],"out_shape":[8,8,32]}
                  ]
                },
                "split322": {
                  "group_sizes": [3,2,2],
                  "groups": [
                    {"index":0,"lo":0,"hi":3,"hlo":"g0_0_3.hlo.txt",
                     "in_shape":[32,32,3],"out_shape":[16,16,8]},
                    {"index":1,"lo":3,"hi":5,"hlo":"g1_3_5.hlo.txt",
                     "in_shape":[16,16,8],"out_shape":[16,16,16]},
                    {"index":2,"lo":5,"hi":7,"hlo":"g2_5_7.hlo.txt",
                     "in_shape":[16,16,16],"out_shape":[8,8,32]}
                  ]
                }
              },
              "golden": {
                "input":"golden_input.bin","input_shape":[32,32,3],
                "output":"golden_output.bin","output_shape":[8,8,32]
              }
            }
          }
        }"#;
        let m = Manifest::from_json_str(text).unwrap();
        let e = &m.networks["tiny-vgg"];
        // The embedded spec equals the builtin tiny-vgg.
        assert_eq!(e.network, crate::config::tiny_vgg());
        let shapes = e.network.shapes();
        for (pname, plan) in &e.plans {
            // Group ranges tile the layer list contiguously.
            let mut cursor = 0usize;
            for g in &plan.groups {
                assert_eq!(g.lo, cursor, "{pname}: group {} lo", g.index);
                assert!(g.hi > g.lo);
                // Boundary shapes match shape inference exactly.
                assert_eq!(g.in_shape, shapes[g.lo].as_slice().to_vec(), "{pname} in");
                assert_eq!(g.out_shape, shapes[g.hi].as_slice().to_vec(), "{pname} out");
                cursor = g.hi;
            }
            assert_eq!(cursor, e.network.layers.len(), "{pname}: full coverage");
            assert_eq!(
                plan.group_sizes.iter().sum::<usize>(),
                e.network.layers.len()
            );
        }
        // Golden vectors carry the network's input/output shapes.
        assert_eq!(e.golden.input_shape, shapes[0].as_slice().to_vec());
        assert_eq!(
            e.golden.output_shape,
            shapes.last().unwrap().as_slice().to_vec()
        );
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::from_json_str("{}").is_err());
        assert!(Manifest::from_json_str(r#"{"networks":{"x":{}}}"#).is_err());
    }

    #[test]
    fn network_spec_validated() {
        // Layer type typo must be caught by Network::from_json.
        let bad = SAMPLE.replace("maxpool", "avgpool");
        assert!(Manifest::from_json_str(&bad).is_err());
    }
}
