//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the request path. Python is never involved here.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! HLO text → `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `PjRtLoadedExecutable::execute`.

pub mod manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::NdTensor;
use self::manifest::{Manifest, NetworkEntry, PlanEntry};

/// A compiled fusion-group executable.
pub struct GroupExecutable {
    pub lo: usize,
    pub hi: usize,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    exe: xla::PjRtLoadedExecutable,
}

impl GroupExecutable {
    /// Execute the group on one input volume.
    pub fn run(&self, input: &NdTensor) -> Result<NdTensor> {
        if input.shape() != self.in_shape.as_slice() {
            bail!(
                "group [{},{}) expects shape {:?}, got {:?}",
                self.lo,
                self.hi,
                self.in_shape,
                input.shape()
            );
        }
        let lit = xla::Literal::vec1(input.data()).reshape(
            &self
                .in_shape
                .iter()
                .map(|&d| d as i64)
                .collect::<Vec<_>>(),
        )?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        Ok(NdTensor::from_vec(&self.out_shape, values))
    }
}

/// A loaded plan: the ordered chain of group executables for one network.
pub struct PlanRuntime {
    pub plan_name: String,
    pub group_sizes: Vec<usize>,
    pub groups: Vec<GroupExecutable>,
}

impl PlanRuntime {
    /// Run the full network: feed each group's output to the next.
    pub fn run(&self, input: &NdTensor) -> Result<NdTensor> {
        let mut cur = input.clone();
        for g in &self.groups {
            cur = g.run(&cur).with_context(|| {
                format!("{} group [{},{})", self.plan_name, g.lo, g.hi)
            })?;
        }
        Ok(cur)
    }

    /// Run and collect each group's boundary output (for layer-level
    /// verification against the simulator).
    pub fn run_traced(&self, input: &NdTensor) -> Result<Vec<NdTensor>> {
        let mut outs = Vec::new();
        let mut cur = input.clone();
        for g in &self.groups {
            cur = g.run(&cur)?;
            outs.push(cur.clone());
        }
        Ok(outs)
    }
}

/// The runtime engine: a PJRT CPU client plus every compiled plan of one
/// network from the artifacts directory.
pub struct Runtime {
    pub network_name: String,
    pub artifacts_dir: PathBuf,
    pub entry: NetworkEntry,
    client: xla::PjRtClient,
    plans: BTreeMap<String, PlanRuntime>,
}

impl Runtime {
    /// Load `artifacts_dir/manifest.json` and compile every plan of
    /// `network`. Compilation happens once at startup (the serving path only
    /// executes).
    pub fn load(artifacts_dir: &Path, network: &str) -> Result<Runtime> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.json"))
            .context("loading manifest.json — run `make artifacts` first")?;
        let entry = manifest
            .networks
            .get(network)
            .with_context(|| format!("network '{network}' not in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu()?;
        let net_dir = artifacts_dir.join(network);
        let mut plans = BTreeMap::new();
        for (plan_name, plan) in &entry.plans {
            plans.insert(
                plan_name.clone(),
                Self::compile_plan(&client, &net_dir, plan_name, plan)?,
            );
        }
        Ok(Runtime {
            network_name: network.to_string(),
            artifacts_dir: artifacts_dir.to_path_buf(),
            entry,
            client,
            plans,
        })
    }

    fn compile_plan(
        client: &xla::PjRtClient,
        net_dir: &Path,
        plan_name: &str,
        plan: &PlanEntry,
    ) -> Result<PlanRuntime> {
        let mut groups = Vec::new();
        for g in &plan.groups {
            let path = net_dir.join(&g.hlo);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            groups.push(GroupExecutable {
                lo: g.lo,
                hi: g.hi,
                in_shape: g.in_shape.clone(),
                out_shape: g.out_shape.clone(),
                exe,
            });
        }
        Ok(PlanRuntime {
            plan_name: plan_name.to_string(),
            group_sizes: plan.group_sizes.clone(),
            groups,
        })
    }

    pub fn plan(&self, name: &str) -> Result<&PlanRuntime> {
        self.plans
            .get(name)
            .with_context(|| format!("plan '{name}' not compiled"))
    }

    pub fn plan_names(&self) -> Vec<&str> {
        self.plans.keys().map(|s| s.as_str()).collect()
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load the golden input/output pair exported by aot.py.
    pub fn golden(&self) -> Result<(NdTensor, NdTensor)> {
        let net_dir = self.artifacts_dir.join(&self.network_name);
        let g = &self.entry.golden;
        let input = read_f32_bin(&net_dir.join(&g.input), &g.input_shape)?;
        let output = read_f32_bin(&net_dir.join(&g.output), &g.output_shape)?;
        Ok((input, output))
    }

    /// Load the network's weights (filters + biases) for the simulator.
    pub fn weights_tensors(&self) -> Result<Vec<(NdTensor, NdTensor)>> {
        let net_dir = self.artifacts_dir.join(&self.network_name);
        let mut out = Vec::new();
        for w in &self.entry.weights {
            let filt = read_f32_bin(&net_dir.join(&w.filter), &w.filter_shape)?;
            let bias = read_f32_bin(&net_dir.join(&w.bias), &w.bias_shape)?;
            out.push((filt, bias));
        }
        Ok(out)
    }
}

/// Read a raw little-endian f32 binary into a tensor of the given shape.
pub fn read_f32_bin(path: &Path, shape: &[usize]) -> Result<NdTensor> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let n: usize = shape.iter().product();
    if bytes.len() != n * 4 {
        bail!(
            "{}: expected {} f32 values ({} bytes), found {} bytes",
            path.display(),
            n,
            n * 4,
            bytes.len()
        );
    }
    let mut vals = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        vals.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Ok(NdTensor::from_vec(shape, vals))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            eprintln!("skipping runtime test: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn load_and_run_paper_example_golden() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let (input, want) = rt.golden().unwrap();
        for plan_name in rt.plan_names() {
            let got = rt.plan(plan_name).unwrap().run(&input).unwrap();
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-3, "plan {plan_name}: diff {diff}");
        }
    }

    #[test]
    fn fused_and_unfused_plans_agree() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::load(&dir, "tiny-vgg").unwrap();
        let (input, _) = rt.golden().unwrap();
        let a = rt.plan("fused").unwrap().run(&input).unwrap();
        let b = rt.plan("unfused").unwrap().run(&input).unwrap();
        let diff = a.max_abs_diff(&b);
        assert!(diff < 1e-3, "plans disagree by {diff}");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::load(&dir, "paper-example").unwrap();
        let bad = NdTensor::zeros(&[4, 4, 3]);
        assert!(rt.plan("fused").unwrap().run(&bad).is_err());
    }

    #[test]
    fn weights_load_with_declared_shapes() {
        let Some(dir) = artifacts() else { return };
        let rt = Runtime::load(&dir, "tiny-vgg").unwrap();
        let ws = rt.weights_tensors().unwrap();
        assert_eq!(ws.len(), 5); // 5 conv layers
        assert_eq!(ws[0].0.shape(), &[8, 3, 3, 3]);
        assert_eq!(ws[0].1.shape(), &[8]);
    }
}
