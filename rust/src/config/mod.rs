//! Configuration: network topology specs, accelerator platform knobs, and
//! cluster (multi-board fleet) parameters.
pub mod accel;
pub mod cluster;
pub mod network;

pub use accel::{AccelConfig, Platform};
pub use cluster::{
    BoardSpec, ClusterConfig, FabricSpec, FabricTopology, FaultEvent, FaultScript, LoadStep,
    OverloadPolicy, PreemptMode, ReshardPolicy, RetryPolicy, ShardMode, SloPolicy, TenantSpec,
};
pub use network::{custom_4conv, paper_test_example, tiny_vgg, vgg16_full, vgg16_prefix, Layer, Network, VolShape};
