//! Configuration: network topology specs and accelerator platform knobs.
pub mod accel;
pub mod network;

pub use accel::{AccelConfig, Platform};
pub use network::{custom_4conv, paper_test_example, tiny_vgg, vgg16_full, vgg16_prefix, Layer, Network, VolShape};
