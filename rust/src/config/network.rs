//! Network topology specification: layers, shape inference, builtin nets.
//!
//! The paper evaluates on (a) the first seven layers of VGG-16 (conv1_1,
//! conv1_2, pool1, conv2_1, conv2_2, pool2, conv3_1) and (b) a custom network
//! of four consecutive 64-filter 3×3 convolutions (Table III). Both are
//! provided as builders here; arbitrary VGG-like nets load from JSON.

use crate::util::json::{parse, Json};

/// One layer of a VGG-like network.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Layer {
    /// 2-D convolution over an `[h, w, d]` volume with `k` filters of
    /// `kernel × kernel × d`, given stride/padding, optional fused ReLU.
    Conv {
        name: String,
        kernel: usize,
        filters: usize,
        stride: usize,
        padding: usize,
        relu: bool,
    },
    /// Max-pool with `window × window` and stride.
    MaxPool {
        name: String,
        window: usize,
        stride: usize,
    },
}

impl Layer {
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv { name, .. } => name,
            Layer::MaxPool { name, .. } => name,
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, Layer::Conv { .. })
    }

    pub fn conv3x3(name: &str, filters: usize) -> Layer {
        Layer::Conv {
            name: name.to_string(),
            kernel: 3,
            filters,
            stride: 1,
            padding: 1,
            relu: true,
        }
    }

    pub fn pool2x2(name: &str) -> Layer {
        Layer::MaxPool {
            name: name.to_string(),
            window: 2,
            stride: 2,
        }
    }
}

/// Shape of a feature volume `[h, w, d]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VolShape {
    pub h: usize,
    pub w: usize,
    pub d: usize,
}

impl VolShape {
    pub fn new(h: usize, w: usize, d: usize) -> VolShape {
        VolShape { h, w, d }
    }

    pub fn elems(&self) -> usize {
        self.h * self.w * self.d
    }

    pub fn as_slice(&self) -> [usize; 3] {
        [self.h, self.w, self.d]
    }
}

/// A network: input shape + ordered layers.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    pub input: VolShape,
    pub layers: Vec<Layer>,
}

/// Error type for spec validation / JSON loading.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecError(pub String);

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "network spec error: {}", self.0)
    }
}
impl std::error::Error for SpecError {}

impl Network {
    /// Output shape of layer `i` (and input shape of layer `i+1`).
    /// `shape_after(layers.len()-1)` is the network output.
    pub fn shape_after(&self, i: usize) -> VolShape {
        let mut s = self.input;
        for layer in &self.layers[..=i] {
            s = layer_out_shape(layer, s);
        }
        s
    }

    /// Input shape seen by layer `i`.
    pub fn shape_before(&self, i: usize) -> VolShape {
        if i == 0 {
            self.input
        } else {
            self.shape_after(i - 1)
        }
    }

    /// All shapes: `shapes()[0]` = input, `shapes()[i+1]` = after layer i.
    pub fn shapes(&self) -> Vec<VolShape> {
        let mut out = vec![self.input];
        let mut s = self.input;
        for layer in &self.layers {
            s = layer_out_shape(layer, s);
            out.push(s);
        }
        out
    }

    /// Total multiply-accumulate operations of the network (for roofline math).
    pub fn total_macs(&self) -> u64 {
        let shapes = self.shapes();
        let mut macs = 0u64;
        for (i, layer) in self.layers.iter().enumerate() {
            if let Layer::Conv { kernel, filters, .. } = layer {
                let out = shapes[i + 1];
                let d_in = shapes[i].d;
                macs += (out.h * out.w * filters * kernel * kernel * d_in) as u64;
            }
        }
        macs
    }

    /// Number of weight values (conv filters; the paper's nets have no FC).
    pub fn total_weights(&self) -> u64 {
        let shapes = self.shapes();
        let mut n = 0u64;
        for (i, layer) in self.layers.iter().enumerate() {
            if let Layer::Conv { kernel, filters, .. } = layer {
                n += (kernel * kernel * shapes[i].d * filters) as u64 + *filters as u64;
                // +filters for biases
            }
        }
        n
    }

    /// Validate structural invariants (positive dims, pool divisibility,
    /// sane magnitudes, etc.). Every loading path goes through here before
    /// shape inference or the engine ever touch the spec, so hostile or
    /// malformed JSON fails with a `SpecError` instead of a panic or an
    /// arithmetic overflow deep in the stack.
    pub fn validate(&self) -> Result<(), SpecError> {
        // Magnitude caps: far above anything a VGG-like net uses, low enough
        // that every downstream product stays inside 64 bits — the worst
        // per-layer MAC count is extent²·filters·kernel²·depth ≤
        // 2^24·2^16·2^10·2^12 = 2^62.
        const MAX_EXTENT: usize = 4096;
        const MAX_KERNEL: usize = 31;
        const MAX_FILTERS: usize = 1 << 16;
        const MAX_STRIDE: usize = 256;
        if self.layers.is_empty() {
            return Err(SpecError("network has no layers".into()));
        }
        if self.input.h == 0 || self.input.w == 0 || self.input.d == 0 {
            return Err(SpecError("input shape has zero extent".into()));
        }
        if self.input.h > MAX_EXTENT || self.input.w > MAX_EXTENT || self.input.d > MAX_EXTENT {
            return Err(SpecError(format!(
                "input shape exceeds the {MAX_EXTENT} extent cap"
            )));
        }
        let mut s = self.input;
        for layer in &self.layers {
            match layer {
                Layer::Conv {
                    name,
                    kernel,
                    filters,
                    stride,
                    padding,
                    ..
                } => {
                    if *kernel == 0 || *filters == 0 || *stride == 0 {
                        return Err(SpecError(format!("{name}: zero kernel/filters/stride")));
                    }
                    if *kernel > MAX_KERNEL {
                        return Err(SpecError(format!(
                            "{name}: kernel {kernel} exceeds the {MAX_KERNEL} cap"
                        )));
                    }
                    if *filters > MAX_FILTERS {
                        return Err(SpecError(format!(
                            "{name}: {filters} filters exceed the {MAX_FILTERS} cap"
                        )));
                    }
                    if *stride > MAX_STRIDE {
                        return Err(SpecError(format!(
                            "{name}: stride {stride} exceeds the {MAX_STRIDE} cap"
                        )));
                    }
                    if *padding >= *kernel {
                        return Err(SpecError(format!(
                            "{name}: padding {padding} must be smaller than kernel {kernel}"
                        )));
                    }
                    if s.h + 2 * padding < *kernel || s.w + 2 * padding < *kernel {
                        return Err(SpecError(format!(
                            "{name}: kernel {kernel} exceeds padded input {}x{}",
                            s.h + 2 * padding,
                            s.w + 2 * padding
                        )));
                    }
                }
                Layer::MaxPool { name, window, stride } => {
                    if *window == 0 || *stride == 0 {
                        return Err(SpecError(format!("{name}: zero window/stride")));
                    }
                    if *window > MAX_KERNEL || *stride > MAX_STRIDE {
                        return Err(SpecError(format!(
                            "{name}: pool window/stride exceed the caps"
                        )));
                    }
                    if s.h < *window || s.w < *window {
                        return Err(SpecError(format!(
                            "{name}: pool window {window} exceeds input {}x{}",
                            s.h, s.w
                        )));
                    }
                }
            }
            s = layer_out_shape(layer, s);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // JSON I/O
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut layers = Json::Arr(vec![]);
        for l in &self.layers {
            let j = match l {
                Layer::Conv {
                    name,
                    kernel,
                    filters,
                    stride,
                    padding,
                    relu,
                } => Json::obj()
                    .set("type", "conv")
                    .set("name", name.as_str())
                    .set("kernel", *kernel)
                    .set("filters", *filters)
                    .set("stride", *stride)
                    .set("padding", *padding)
                    .set("relu", *relu),
                Layer::MaxPool { name, window, stride } => Json::obj()
                    .set("type", "maxpool")
                    .set("name", name.as_str())
                    .set("window", *window)
                    .set("stride", *stride),
            };
            layers = layers.push(j);
        }
        Json::obj()
            .set("name", self.name.as_str())
            .set(
                "input",
                Json::obj()
                    .set("h", self.input.h)
                    .set("w", self.input.w)
                    .set("d", self.input.d),
            )
            .set("layers", layers)
    }

    pub fn from_json(j: &Json) -> Result<Network, SpecError> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| SpecError("missing 'name'".into()))?
            .to_string();
        let input = VolShape::new(
            j.get("input").get("h").as_usize().ok_or_else(|| SpecError("input.h".into()))?,
            j.get("input").get("w").as_usize().ok_or_else(|| SpecError("input.w".into()))?,
            j.get("input").get("d").as_usize().ok_or_else(|| SpecError("input.d".into()))?,
        );
        let mut layers = Vec::new();
        for lj in j
            .get("layers")
            .as_arr()
            .ok_or_else(|| SpecError("missing 'layers'".into()))?
        {
            let lname = lj
                .get("name")
                .as_str()
                .ok_or_else(|| SpecError("layer missing 'name'".into()))?
                .to_string();
            match lj.get("type").as_str() {
                Some("conv") => layers.push(Layer::Conv {
                    name: lname,
                    kernel: lj.get("kernel").as_usize().ok_or_else(|| SpecError("conv.kernel".into()))?,
                    filters: lj.get("filters").as_usize().ok_or_else(|| SpecError("conv.filters".into()))?,
                    stride: lj.get("stride").as_usize().unwrap_or(1),
                    padding: lj.get("padding").as_usize().unwrap_or(0),
                    relu: lj.get("relu").as_bool().unwrap_or(true),
                }),
                Some("maxpool") => layers.push(Layer::MaxPool {
                    name: lname,
                    window: lj.get("window").as_usize().ok_or_else(|| SpecError("maxpool.window".into()))?,
                    stride: lj.get("stride").as_usize().ok_or_else(|| SpecError("maxpool.stride".into()))?,
                }),
                other => {
                    return Err(SpecError(format!("unknown layer type {:?}", other)));
                }
            }
        }
        let net = Network { name, input, layers };
        net.validate()?;
        Ok(net)
    }

    pub fn from_json_str(s: &str) -> Result<Network, SpecError> {
        let j = parse(s).map_err(|e| SpecError(format!("json: {e}")))?;
        Network::from_json(&j)
    }
}

fn conv_out(extent: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    (extent + 2 * padding - kernel) / stride + 1
}

fn layer_out_shape(layer: &Layer, s: VolShape) -> VolShape {
    match layer {
        Layer::Conv {
            kernel,
            filters,
            stride,
            padding,
            ..
        } => VolShape::new(
            conv_out(s.h, *kernel, *stride, *padding),
            conv_out(s.w, *kernel, *stride, *padding),
            *filters,
        ),
        Layer::MaxPool { window, stride, .. } => {
            VolShape::new((s.h - window) / stride + 1, (s.w - window) / stride + 1, s.d)
        }
    }
}

// ----------------------------------------------------------------------
// Builtin networks
// ----------------------------------------------------------------------

/// First seven layers of VGG-16 (5 conv + 2 pool) — the paper's main workload
/// (Tables I, II, IV; Figs 6, 7).
pub fn vgg16_prefix() -> Network {
    Network {
        name: "vgg16-prefix7".to_string(),
        input: VolShape::new(224, 224, 3),
        layers: vec![
            Layer::conv3x3("conv1_1", 64),
            Layer::conv3x3("conv1_2", 64),
            Layer::pool2x2("pool1"),
            Layer::conv3x3("conv2_1", 128),
            Layer::conv3x3("conv2_2", 128),
            Layer::pool2x2("pool2"),
            Layer::conv3x3("conv3_1", 256),
        ],
    }
}

/// All thirteen conv layers (+ five pools) of VGG-16 — the paper's §V
/// later-layers discussion: depths reach 512, forcing iterative
/// decomposition, and the fusion-vs-depth-parallelism trade-off flips.
pub fn vgg16_full() -> Network {
    Network {
        name: "vgg16-full13".to_string(),
        input: VolShape::new(224, 224, 3),
        layers: vec![
            Layer::conv3x3("conv1_1", 64),
            Layer::conv3x3("conv1_2", 64),
            Layer::pool2x2("pool1"),
            Layer::conv3x3("conv2_1", 128),
            Layer::conv3x3("conv2_2", 128),
            Layer::pool2x2("pool2"),
            Layer::conv3x3("conv3_1", 256),
            Layer::conv3x3("conv3_2", 256),
            Layer::conv3x3("conv3_3", 256),
            Layer::pool2x2("pool3"),
            Layer::conv3x3("conv4_1", 512),
            Layer::conv3x3("conv4_2", 512),
            Layer::conv3x3("conv4_3", 512),
            Layer::pool2x2("pool4"),
            Layer::conv3x3("conv5_1", 512),
            Layer::conv3x3("conv5_2", 512),
            Layer::conv3x3("conv5_3", 512),
            Layer::pool2x2("pool5"),
        ],
    }
}

/// The paper's custom benchmark: four consecutive 64-filter 3×3 convolutions
/// (Table III) at 224×224×3 input.
pub fn custom_4conv() -> Network {
    Network {
        name: "custom-4conv64".to_string(),
        input: VolShape::new(224, 224, 3),
        layers: vec![
            Layer::conv3x3("conv_1", 64),
            Layer::conv3x3("conv_2", 64),
            Layer::conv3x3("conv_3", 64),
            Layer::conv3x3("conv_4", 64),
        ],
    }
}

/// The paper's running "test example" (§III): 5×5×3 input, two fused 3-filter
/// convolutions, then 2×2/2 pooling. Used heavily by unit tests.
pub fn paper_test_example() -> Network {
    Network {
        name: "paper-example".to_string(),
        input: VolShape::new(5, 5, 3),
        layers: vec![
            Layer::conv3x3("conv_a", 3),
            Layer::conv3x3("conv_b", 3),
            Layer::pool2x2("pool"),
        ],
    }
}

/// A scaled-down VGG-like net for fast integration tests and the e2e example:
/// same 7-layer structure as `vgg16_prefix` at 32×32 input with thin depths.
pub fn tiny_vgg() -> Network {
    Network {
        name: "tiny-vgg".to_string(),
        input: VolShape::new(32, 32, 3),
        layers: vec![
            Layer::conv3x3("conv1_1", 8),
            Layer::conv3x3("conv1_2", 8),
            Layer::pool2x2("pool1"),
            Layer::conv3x3("conv2_1", 16),
            Layer::conv3x3("conv2_2", 16),
            Layer::pool2x2("pool2"),
            Layer::conv3x3("conv3_1", 32),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_prefix_shapes() {
        let net = vgg16_prefix();
        net.validate().unwrap();
        let shapes = net.shapes();
        assert_eq!(shapes[0], VolShape::new(224, 224, 3));
        assert_eq!(shapes[1], VolShape::new(224, 224, 64)); // conv1_1
        assert_eq!(shapes[2], VolShape::new(224, 224, 64)); // conv1_2
        assert_eq!(shapes[3], VolShape::new(112, 112, 64)); // pool1
        assert_eq!(shapes[4], VolShape::new(112, 112, 128)); // conv2_1
        assert_eq!(shapes[5], VolShape::new(112, 112, 128)); // conv2_2
        assert_eq!(shapes[6], VolShape::new(56, 56, 128)); // pool2
        assert_eq!(shapes[7], VolShape::new(56, 56, 256)); // conv3_1
    }

    #[test]
    fn paper_example_shapes() {
        let net = paper_test_example();
        let shapes = net.shapes();
        assert_eq!(shapes[1], VolShape::new(5, 5, 3));
        assert_eq!(shapes[2], VolShape::new(5, 5, 3));
        assert_eq!(shapes[3], VolShape::new(2, 2, 3));
    }

    #[test]
    fn macs_vgg_conv1_1() {
        // conv1_1: 224*224*64 outputs × 3*3*3 macs = 86,704,128.
        let net = vgg16_prefix();
        let only_first = Network {
            name: "c11".into(),
            input: net.input,
            layers: vec![net.layers[0].clone()],
        };
        assert_eq!(only_first.total_macs(), 224 * 224 * 64 * 27);
    }

    #[test]
    fn weights_count() {
        let net = custom_4conv();
        // layer1: 3*3*3*64 + 64; layers 2-4: 3*3*64*64 + 64 each.
        let expect = (3 * 3 * 3 * 64 + 64) + 3 * (3 * 3 * 64 * 64 + 64);
        assert_eq!(net.total_weights(), expect as u64);
    }

    #[test]
    fn shape_before_after_consistency() {
        let net = vgg16_prefix();
        for i in 0..net.layers.len() {
            if i > 0 {
                assert_eq!(net.shape_before(i), net.shape_after(i - 1));
            }
        }
        assert_eq!(net.shape_before(0), net.input);
    }

    #[test]
    fn vgg_full_shapes() {
        let net = vgg16_full();
        net.validate().unwrap();
        let shapes = net.shapes();
        assert_eq!(shapes.last().unwrap(), &VolShape::new(7, 7, 512));
        // 13 convs, 5 pools.
        assert_eq!(net.layers.iter().filter(|l| l.is_conv()).count(), 13);
        assert_eq!(net.layers.len(), 18);
        // VGG-16's conv MACs ≈ 15.3 GMACs.
        let gmacs = net.total_macs() as f64 / 1e9;
        assert!((15.0..15.8).contains(&gmacs), "got {gmacs}");
    }

    #[test]
    fn json_roundtrip() {
        for net in [
            vgg16_prefix(),
            vgg16_full(),
            custom_4conv(),
            paper_test_example(),
            tiny_vgg(),
        ] {
            let s = net.to_json().to_string_pretty();
            let back = Network::from_json_str(&s).unwrap();
            assert_eq!(net, back);
        }
    }

    #[test]
    fn validate_catches_bad_specs() {
        let mut net = vgg16_prefix();
        net.layers.clear();
        assert!(net.validate().is_err());

        let bad = Network {
            name: "bad".into(),
            input: VolShape::new(1, 1, 3),
            layers: vec![Layer::pool2x2("p")],
        };
        assert!(bad.validate().is_err());

        let bad2 = Network {
            name: "bad2".into(),
            input: VolShape::new(8, 8, 3),
            layers: vec![Layer::Conv {
                name: "c".into(),
                kernel: 0,
                filters: 4,
                stride: 1,
                padding: 0,
                relu: true,
            }],
        };
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn from_json_rejects_zero_and_absurd_fields() {
        let base = |layers: &str| {
            format!(r#"{{"name":"x","input":{{"h":16,"w":16,"d":3}},"layers":[{layers}]}}"#)
        };
        for (what, layer) in [
            (
                "zero stride",
                r#"{"type":"conv","name":"c","kernel":3,"filters":4,"stride":0,"padding":1}"#,
            ),
            (
                "zero kernel",
                r#"{"type":"conv","name":"c","kernel":0,"filters":4,"stride":1}"#,
            ),
            (
                "zero filters",
                r#"{"type":"conv","name":"c","kernel":3,"filters":0,"stride":1}"#,
            ),
            (
                "zero pool stride",
                r#"{"type":"maxpool","name":"p","window":2,"stride":0}"#,
            ),
            (
                "padding >= kernel",
                r#"{"type":"conv","name":"c","kernel":3,"filters":4,"stride":1,"padding":3}"#,
            ),
            (
                "huge kernel",
                r#"{"type":"conv","name":"c","kernel":999,"filters":4,"stride":1}"#,
            ),
            (
                "huge filters",
                r#"{"type":"conv","name":"c","kernel":3,"filters":9999999,"stride":1}"#,
            ),
            (
                "huge padding (overflow bait)",
                r#"{"type":"conv","name":"c","kernel":3,"filters":4,"stride":1,"padding":4503599627370496}"#,
            ),
        ] {
            assert!(
                Network::from_json_str(&base(layer)).is_err(),
                "{what} must be rejected"
            );
        }
        // Empty layer list.
        assert!(
            Network::from_json_str(r#"{"name":"x","input":{"h":8,"w":8,"d":3},"layers":[]}"#)
                .is_err()
        );
        // Zero input extent.
        assert!(Network::from_json_str(
            r#"{"name":"x","input":{"h":0,"w":8,"d":3},
                "layers":[{"type":"conv","name":"c","kernel":3,"filters":4,"stride":1,"padding":1}]}"#
        )
        .is_err());
        // A valid spec still parses.
        assert!(Network::from_json_str(&base(
            r#"{"type":"conv","name":"c","kernel":3,"filters":4,"stride":1,"padding":1}"#
        ))
        .is_ok());
    }

    #[test]
    fn caps_keep_downstream_products_in_range() {
        // A spec sitting exactly at the validation caps must not overflow
        // the derived quantities (debug builds would panic on wraparound).
        let net = Network {
            name: "caps-edge".into(),
            input: VolShape::new(4096, 4096, 4096),
            layers: vec![Layer::Conv {
                name: "c".into(),
                kernel: 31,
                filters: 1 << 16,
                stride: 1,
                padding: 0,
                relu: true,
            }],
        };
        net.validate().unwrap();
        assert!(net.total_macs() > 0);
        assert!(net.total_weights() > 0);
        // One past the extent cap is rejected.
        let mut big = net;
        big.input = VolShape::new(4097, 4096, 4096);
        assert!(big.validate().is_err());
    }

    #[test]
    fn from_json_rejects_unknown_type() {
        let s = r#"{"name":"x","input":{"h":8,"w":8,"d":3},
                    "layers":[{"type":"avgpool","name":"p","window":2,"stride":2}]}"#;
        assert!(Network::from_json_str(s).is_err());
    }

    #[test]
    fn conv_output_formula() {
        assert_eq!(conv_out(224, 3, 1, 1), 224); // same-conv
        assert_eq!(conv_out(5, 3, 1, 0), 3); // valid conv
        assert_eq!(conv_out(224, 3, 2, 1), 112); // strided
    }
}
