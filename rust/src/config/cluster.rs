//! Cluster (multi-board) configuration: fleet size, sharding mode,
//! inter-board link, shared off-chip bandwidth, and the open-loop workload
//! driven at the fleet. Parsed from JSON like the other configs.

use crate::util::json::{parse, Json};

/// How the network is distributed across boards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Data parallel: every board hosts the whole network; requests are
    /// load-balanced across boards.
    Replicated,
    /// Model parallel: each board hosts a contiguous range of fusion
    /// groups; activations cross inter-board links at the cuts.
    Pipelined,
}

impl ShardMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardMode::Replicated => "replicated",
            ShardMode::Pipelined => "pipelined",
        }
    }

    pub fn from_name(s: &str) -> Result<ShardMode, String> {
        match s {
            "replicated" => Ok(ShardMode::Replicated),
            "pipelined" => Ok(ShardMode::Pipelined),
            other => Err(format!(
                "unknown shard mode '{other}' (expected 'replicated' or 'pipelined')"
            )),
        }
    }
}

/// Configuration of a simulated multi-accelerator serving fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of boards provisioned. Pipelined mode may leave boards idle
    /// when the network has fewer fusion groups than boards.
    pub boards: usize,
    pub mode: ShardMode,
    /// Inter-board link bandwidth (bytes per accelerator cycle). Only
    /// pipelined mode moves activations across links.
    pub link_bytes_per_cycle: f64,
    /// Fixed per-transfer link latency (serialization + switch hop).
    pub link_latency_cycles: u64,
    /// Aggregate off-chip bandwidth shared by all co-located boards, in
    /// bytes/cycle. `None` disables the contention model (each board keeps
    /// its full private `Platform::ddr_bytes_per_cycle`).
    pub aggregate_ddr_bytes_per_cycle: Option<f64>,
    /// Open-loop arrival rate in requests/second. `f64::INFINITY` (JSON:
    /// field absent or `null`) means a saturating burst: every request
    /// arrives at t = 0, which measures fleet capacity.
    pub arrival_rps: f64,
    /// Number of requests the workload generator fires.
    pub requests: usize,
    /// PRNG seed for arrival sampling.
    pub seed: u64,
    /// Per-board dynamic batching bounds (mirrors `BatchPolicy`).
    pub max_batch: usize,
    pub max_wait_us: f64,
}

impl ClusterConfig {
    /// A small default fleet: 4 replicated boards, PCIe-class links, shared
    /// DDR pool worth two boards, moderate open-loop load.
    pub fn fleet_default() -> ClusterConfig {
        ClusterConfig {
            boards: 4,
            mode: ShardMode::Replicated,
            link_bytes_per_cycle: 16.0,
            link_latency_cycles: 64,
            aggregate_ddr_bytes_per_cycle: Some(128.0),
            arrival_rps: f64::INFINITY,
            requests: 256,
            seed: 1,
            max_batch: 8,
            max_wait_us: 200.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.boards == 0 {
            return Err("cluster: boards must be >= 1".into());
        }
        if self.requests == 0 {
            return Err("cluster: requests must be >= 1".into());
        }
        if self.max_batch == 0 {
            return Err("cluster: max_batch must be >= 1".into());
        }
        if !(self.link_bytes_per_cycle > 0.0) {
            return Err("cluster: link_bytes_per_cycle must be > 0".into());
        }
        if let Some(a) = self.aggregate_ddr_bytes_per_cycle {
            if !(a > 0.0) {
                return Err("cluster: aggregate_ddr_bytes_per_cycle must be > 0".into());
            }
        }
        if !(self.arrival_rps > 0.0) {
            return Err("cluster: arrival_rps must be > 0 (or omitted for a burst)".into());
        }
        if !(self.max_wait_us >= 0.0) {
            return Err("cluster: max_wait_us must be >= 0".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("boards", self.boards)
            .set("mode", self.mode.as_str())
            .set("link_bytes_per_cycle", self.link_bytes_per_cycle)
            .set("link_latency_cycles", self.link_latency_cycles)
            .set("requests", self.requests)
            .set("seed", self.seed)
            .set("max_batch", self.max_batch)
            .set("max_wait_us", self.max_wait_us);
        if let Some(a) = self.aggregate_ddr_bytes_per_cycle {
            j = j.set("aggregate_ddr_bytes_per_cycle", a);
        }
        // JSON has no Infinity: a saturating burst is encoded by omission.
        if self.arrival_rps.is_finite() {
            j = j.set("arrival_rps", self.arrival_rps);
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<ClusterConfig, String> {
        let base = ClusterConfig::fleet_default();
        let cfg = ClusterConfig {
            boards: j
                .get("boards")
                .as_usize()
                .ok_or("cluster: missing/invalid 'boards'")?,
            mode: ShardMode::from_name(
                j.get("mode").as_str().ok_or("cluster: missing 'mode'")?,
            )?,
            link_bytes_per_cycle: j
                .get("link_bytes_per_cycle")
                .as_f64()
                .unwrap_or(base.link_bytes_per_cycle),
            link_latency_cycles: j
                .get("link_latency_cycles")
                .as_u64()
                .unwrap_or(base.link_latency_cycles),
            aggregate_ddr_bytes_per_cycle: match j.get("aggregate_ddr_bytes_per_cycle") {
                Json::Null => None,
                v => Some(
                    v.as_f64()
                        .ok_or("cluster: invalid 'aggregate_ddr_bytes_per_cycle'")?,
                ),
            },
            arrival_rps: match j.get("arrival_rps") {
                Json::Null => f64::INFINITY,
                v => v.as_f64().ok_or("cluster: invalid 'arrival_rps'")?,
            },
            requests: j.get("requests").as_usize().unwrap_or(base.requests),
            seed: j.get("seed").as_u64().unwrap_or(base.seed),
            max_batch: j.get("max_batch").as_usize().unwrap_or(base.max_batch),
            max_wait_us: j.get("max_wait_us").as_f64().unwrap_or(base.max_wait_us),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<ClusterConfig, String> {
        let j = parse(s).map_err(|e| format!("cluster json: {e}"))?;
        ClusterConfig::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_finite_rate() {
        let mut c = ClusterConfig::fleet_default();
        c.arrival_rps = 1500.0;
        c.mode = ShardMode::Pipelined;
        c.boards = 7;
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_roundtrip_burst_and_no_contention() {
        let mut c = ClusterConfig::fleet_default();
        c.aggregate_ddr_bytes_per_cycle = None; // contention disabled
        assert!(c.arrival_rps.is_infinite());
        let s = c.to_json().to_string_compact();
        assert!(!s.contains("arrival_rps"), "burst is encoded by omission");
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn rejects_invalid() {
        for (field, bad) in [
            ("boards", r#"{"boards":0,"mode":"replicated"}"#),
            ("mode", r#"{"boards":2,"mode":"sideways"}"#),
            ("requests", r#"{"boards":2,"mode":"replicated","requests":0}"#),
            ("batch", r#"{"boards":2,"mode":"replicated","max_batch":0}"#),
            (
                "aggregate",
                r#"{"boards":2,"mode":"replicated","aggregate_ddr_bytes_per_cycle":0}"#,
            ),
            ("rate", r#"{"boards":2,"mode":"replicated","arrival_rps":-5}"#),
        ] {
            assert!(
                ClusterConfig::from_json_str(bad).is_err(),
                "{field} should be rejected"
            );
        }
    }

    #[test]
    fn defaults_fill_optional_fields() {
        let c = ClusterConfig::from_json_str(r#"{"boards":3,"mode":"pipelined"}"#).unwrap();
        assert_eq!(c.boards, 3);
        assert_eq!(c.mode, ShardMode::Pipelined);
        assert!(c.arrival_rps.is_infinite());
        assert_eq!(c.max_batch, ClusterConfig::fleet_default().max_batch);
    }
}
