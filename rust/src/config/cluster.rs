//! Cluster (multi-board) configuration: fleet size and composition
//! (optionally heterogeneous board generations), sharding mode, inter-board
//! link, shared off-chip bandwidth, the open-loop workload driven at the
//! fleet (optionally with load steps), and the re-shard controller policy.
//! Parsed from JSON like the other configs.

use crate::util::json::{parse, Json};

use super::accel::{AccelConfig, Platform};

/// How the network is distributed across boards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Data parallel: every board hosts the whole network; requests are
    /// load-balanced across boards.
    Replicated,
    /// Model parallel: each board hosts a contiguous range of fusion
    /// groups; activations cross inter-board links at the cuts.
    Pipelined,
}

impl ShardMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardMode::Replicated => "replicated",
            ShardMode::Pipelined => "pipelined",
        }
    }

    pub fn from_name(s: &str) -> Result<ShardMode, String> {
        match s {
            "replicated" => Ok(ShardMode::Replicated),
            "pipelined" => Ok(ShardMode::Pipelined),
            other => Err(format!(
                "unknown shard mode '{other}' (expected 'replicated' or 'pipelined')"
            )),
        }
    }
}

/// One generation of boards in a heterogeneous fleet: `count` identical
/// boards sharing one resource envelope, clock, and provisioned DDR draw
/// (all carried by the [`Platform`]). Fleet order is the order of the specs —
/// the pipelined planner assigns stage *i* to board *i* in that order.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardSpec {
    pub count: usize,
    pub platform: Platform,
}

impl BoardSpec {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("platform", self.platform.to_json())
    }

    pub fn from_json(j: &Json) -> Result<BoardSpec, String> {
        Ok(BoardSpec {
            count: j
                .get("count")
                .as_usize()
                .ok_or("board_spec: missing/invalid 'count'")?,
            platform: Platform::from_json(j.get("platform"))
                .ok_or("board_spec: missing/invalid 'platform'")?,
        })
    }
}

/// A traffic shift: from request index `at_request` onward, arrivals come at
/// `rps` requests/second (infinite = the remaining requests arrive at once).
#[derive(Debug, Clone, PartialEq)]
pub struct LoadStep {
    pub at_request: usize,
    pub rps: f64,
}

/// Policy of the load-driven re-shard controller ([`crate::cluster`]'s
/// dynamic simulator). The controller watches completed requests in windows;
/// when the window p99 or the per-board utilization skew crosses a
/// threshold, it re-plans the shard and charges a migration cost.
#[derive(Debug, Clone, PartialEq)]
pub struct ReshardPolicy {
    /// Completed requests per observation window.
    pub window: usize,
    /// Trigger when (max − min) per-board utilization over the window
    /// exceeds this (0..1 scale).
    pub util_skew: f64,
    /// Trigger when the window p99 latency exceeds this many milliseconds.
    pub p99_ms: f64,
    /// Windows to wait after a re-shard before evaluating triggers again.
    pub cooldown_windows: usize,
    /// Scales the migration byte bill (weights that change boards plus
    /// in-flight activation state). 0 makes migration free.
    pub migration_factor: f64,
}

impl ReshardPolicy {
    /// Conservative defaults: 32-request windows, re-shard on >35 points of
    /// utilization skew or a 50 ms p99, two windows of cooldown, full
    /// migration billing.
    pub fn default_policy() -> ReshardPolicy {
        ReshardPolicy {
            window: 32,
            util_skew: 0.35,
            p99_ms: 50.0,
            cooldown_windows: 2,
            migration_factor: 1.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.window == 0 {
            return Err("reshard: window must be >= 1".into());
        }
        if !(self.util_skew > 0.0) {
            return Err("reshard: util_skew must be > 0".into());
        }
        if !(self.p99_ms > 0.0) {
            return Err("reshard: p99_ms must be > 0".into());
        }
        if !(self.migration_factor >= 0.0) {
            return Err("reshard: migration_factor must be >= 0".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("window", self.window)
            .set("util_skew", self.util_skew)
            .set("p99_ms", self.p99_ms)
            .set("cooldown_windows", self.cooldown_windows)
            .set("migration_factor", self.migration_factor)
    }

    pub fn from_json(j: &Json) -> Result<ReshardPolicy, String> {
        let base = ReshardPolicy::default_policy();
        Ok(ReshardPolicy {
            window: j.get("window").as_usize().unwrap_or(base.window),
            util_skew: j.get("util_skew").as_f64().unwrap_or(base.util_skew),
            p99_ms: j.get("p99_ms").as_f64().unwrap_or(base.p99_ms),
            cooldown_windows: j
                .get("cooldown_windows")
                .as_usize()
                .unwrap_or(base.cooldown_windows),
            migration_factor: j
                .get("migration_factor")
                .as_f64()
                .unwrap_or(base.migration_factor),
        })
    }
}

/// Configuration of a simulated multi-accelerator serving fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of boards provisioned. Pipelined mode may leave boards idle
    /// when the network has fewer fusion groups than boards.
    pub boards: usize,
    pub mode: ShardMode,
    /// Fleet composition for heterogeneous fleets. Empty means `boards`
    /// identical boards on the base config's platform; otherwise the counts
    /// must sum to `boards` and fleet order follows spec order.
    pub board_specs: Vec<BoardSpec>,
    /// Inter-board link bandwidth (bytes per reference-clock cycle). Links
    /// have finite capacity: concurrent boundary transfers serialize, so the
    /// link itself can become the bottleneck stage of a pipelined fleet.
    pub link_bytes_per_cycle: f64,
    /// Fixed per-transfer link latency (serialization + switch hop).
    pub link_latency_cycles: u64,
    /// Aggregate off-chip bandwidth shared by all co-located boards, in
    /// bytes/cycle at the reference clock. `None` disables the contention
    /// model (each board keeps its full private provisioned rate).
    pub aggregate_ddr_bytes_per_cycle: Option<f64>,
    /// Open-loop arrival rate in requests/second. `f64::INFINITY` (JSON:
    /// field absent or `null`) means a saturating burst: every request
    /// arrives at t = 0, which measures fleet capacity.
    pub arrival_rps: f64,
    /// Traffic shifts applied on top of `arrival_rps` (empty = constant
    /// rate). Steps must be ordered by `at_request`.
    pub load_steps: Vec<LoadStep>,
    /// Number of requests the workload generator fires.
    pub requests: usize,
    /// PRNG seed for arrival sampling.
    pub seed: u64,
    /// Per-board dynamic batching bounds (mirrors `BatchPolicy`).
    pub max_batch: usize,
    pub max_wait_us: f64,
    /// Load-driven re-shard controller; `None` keeps the initial shard for
    /// the whole run.
    pub reshard: Option<ReshardPolicy>,
}

impl ClusterConfig {
    /// A small default fleet: 4 replicated boards, PCIe-class links, shared
    /// DDR pool worth two boards, moderate open-loop load.
    pub fn fleet_default() -> ClusterConfig {
        ClusterConfig {
            boards: 4,
            mode: ShardMode::Replicated,
            board_specs: Vec::new(),
            link_bytes_per_cycle: 16.0,
            link_latency_cycles: 64,
            aggregate_ddr_bytes_per_cycle: Some(128.0),
            arrival_rps: f64::INFINITY,
            load_steps: Vec::new(),
            requests: 256,
            seed: 1,
            max_batch: 8,
            max_wait_us: 200.0,
            reshard: None,
        }
    }

    /// A copy of this config provisioned with `boards` boards (the sweep
    /// form). A homogeneous fleet just changes the count; a heterogeneous
    /// fleet keeps rack order and truncates the generation counts to fit —
    /// or extends the last generation when growing — so the copy always
    /// validates.
    pub fn with_boards(&self, boards: usize) -> ClusterConfig {
        let mut c = self.clone();
        c.boards = boards;
        if !c.board_specs.is_empty() {
            let mut specs: Vec<BoardSpec> = Vec::new();
            let mut left = boards;
            for s in &self.board_specs {
                if left == 0 {
                    break;
                }
                let take = s.count.min(left);
                specs.push(BoardSpec {
                    count: take,
                    platform: s.platform.clone(),
                });
                left -= take;
            }
            if left > 0 {
                if let Some(last) = specs.last_mut() {
                    last.count += left;
                }
            }
            c.board_specs = specs;
        }
        c
    }

    /// Expand the fleet into one `AccelConfig` per physical board, in rack
    /// order: each board inherits the base config's design knobs and swaps
    /// in its generation's platform (resource envelope, clock, DDR share).
    pub fn board_configs(&self, base: &AccelConfig) -> Vec<AccelConfig> {
        if self.board_specs.is_empty() {
            return vec![base.clone(); self.boards];
        }
        let mut fleet = Vec::with_capacity(self.boards);
        for spec in &self.board_specs {
            for _ in 0..spec.count {
                fleet.push(AccelConfig {
                    platform: spec.platform.clone(),
                    ..base.clone()
                });
            }
        }
        fleet
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.boards == 0 {
            return Err("cluster: boards must be >= 1".into());
        }
        if self.requests == 0 {
            return Err("cluster: requests must be >= 1".into());
        }
        if self.max_batch == 0 {
            return Err("cluster: max_batch must be >= 1".into());
        }
        if !(self.link_bytes_per_cycle > 0.0) {
            return Err("cluster: link_bytes_per_cycle must be > 0".into());
        }
        if let Some(a) = self.aggregate_ddr_bytes_per_cycle {
            if !(a > 0.0) {
                return Err("cluster: aggregate_ddr_bytes_per_cycle must be > 0".into());
            }
        }
        if !(self.arrival_rps > 0.0) {
            return Err("cluster: arrival_rps must be > 0 (or omitted for a burst)".into());
        }
        if !(self.max_wait_us >= 0.0) {
            return Err("cluster: max_wait_us must be >= 0".into());
        }
        if !self.board_specs.is_empty() {
            let total: usize = self.board_specs.iter().map(|s| s.count).sum();
            if total != self.boards {
                return Err(format!(
                    "cluster: board_specs counts sum to {total}, expected boards = {}",
                    self.boards
                ));
            }
            let wb = self.board_specs[0].platform.word_bytes;
            for (i, s) in self.board_specs.iter().enumerate() {
                if s.count == 0 {
                    return Err(format!("cluster: board_specs[{i}].count must be >= 1"));
                }
                let p = &s.platform;
                if !(p.freq_mhz > 0.0) || !(p.ddr_bytes_per_cycle > 0.0) || p.word_bytes == 0 {
                    return Err(format!(
                        "cluster: board_specs[{i}].platform needs freq_mhz > 0, \
                         ddr_bytes_per_cycle > 0, word_bytes >= 1"
                    ));
                }
                if p.word_bytes != wb {
                    return Err(
                        "cluster: all board generations must share one word size \
                         (mixed word_bytes would change boundary volumes mid-pipeline)"
                            .into(),
                    );
                }
            }
        }
        let mut last_at = None;
        for (i, st) in self.load_steps.iter().enumerate() {
            if !(st.rps > 0.0) {
                return Err(format!("cluster: load_steps[{i}].rps must be > 0"));
            }
            if let Some(prev) = last_at {
                if st.at_request <= prev {
                    return Err("cluster: load_steps must be ordered by at_request".into());
                }
            }
            last_at = Some(st.at_request);
        }
        if let Some(r) = &self.reshard {
            r.validate()?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("boards", self.boards)
            .set("mode", self.mode.as_str())
            .set("link_bytes_per_cycle", self.link_bytes_per_cycle)
            .set("link_latency_cycles", self.link_latency_cycles)
            .set("requests", self.requests)
            .set("seed", self.seed)
            .set("max_batch", self.max_batch)
            .set("max_wait_us", self.max_wait_us);
        if let Some(a) = self.aggregate_ddr_bytes_per_cycle {
            j = j.set("aggregate_ddr_bytes_per_cycle", a);
        }
        // JSON has no Infinity: a saturating burst is encoded by omission.
        if self.arrival_rps.is_finite() {
            j = j.set("arrival_rps", self.arrival_rps);
        }
        if !self.board_specs.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for s in &self.board_specs {
                arr = arr.push(s.to_json());
            }
            j = j.set("board_specs", arr);
        }
        if !self.load_steps.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for s in &self.load_steps {
                let mut o = Json::obj().set("at_request", s.at_request);
                if s.rps.is_finite() {
                    o = o.set("rps", s.rps);
                }
                arr = arr.push(o);
            }
            j = j.set("load_steps", arr);
        }
        if let Some(r) = &self.reshard {
            j = j.set("reshard", r.to_json());
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<ClusterConfig, String> {
        let base = ClusterConfig::fleet_default();
        let board_specs = match j.get("board_specs") {
            Json::Null => Vec::new(),
            v => v
                .as_arr()
                .ok_or("cluster: 'board_specs' must be an array")?
                .iter()
                .map(BoardSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?,
        };
        let load_steps = match j.get("load_steps") {
            Json::Null => Vec::new(),
            v => v
                .as_arr()
                .ok_or("cluster: 'load_steps' must be an array")?
                .iter()
                .map(|s| -> Result<LoadStep, String> {
                    Ok(LoadStep {
                        at_request: s
                            .get("at_request")
                            .as_usize()
                            .ok_or("cluster: load_step missing 'at_request'")?,
                        rps: match s.get("rps") {
                            Json::Null => f64::INFINITY,
                            v => v.as_f64().ok_or("cluster: invalid load_step 'rps'")?,
                        },
                    })
                })
                .collect::<Result<Vec<_>, String>>()?,
        };
        let reshard = match j.get("reshard") {
            Json::Null => None,
            v => Some(ReshardPolicy::from_json(v)?),
        };
        let cfg = ClusterConfig {
            boards: j
                .get("boards")
                .as_usize()
                .ok_or("cluster: missing/invalid 'boards'")?,
            mode: ShardMode::from_name(
                j.get("mode").as_str().ok_or("cluster: missing 'mode'")?,
            )?,
            board_specs,
            link_bytes_per_cycle: j
                .get("link_bytes_per_cycle")
                .as_f64()
                .unwrap_or(base.link_bytes_per_cycle),
            link_latency_cycles: j
                .get("link_latency_cycles")
                .as_u64()
                .unwrap_or(base.link_latency_cycles),
            aggregate_ddr_bytes_per_cycle: match j.get("aggregate_ddr_bytes_per_cycle") {
                Json::Null => None,
                v => Some(
                    v.as_f64()
                        .ok_or("cluster: invalid 'aggregate_ddr_bytes_per_cycle'")?,
                ),
            },
            arrival_rps: match j.get("arrival_rps") {
                Json::Null => f64::INFINITY,
                v => v.as_f64().ok_or("cluster: invalid 'arrival_rps'")?,
            },
            load_steps,
            requests: j.get("requests").as_usize().unwrap_or(base.requests),
            seed: j.get("seed").as_u64().unwrap_or(base.seed),
            max_batch: j.get("max_batch").as_usize().unwrap_or(base.max_batch),
            max_wait_us: j.get("max_wait_us").as_f64().unwrap_or(base.max_wait_us),
            reshard,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<ClusterConfig, String> {
        let j = parse(s).map_err(|e| format!("cluster json: {e}"))?;
        ClusterConfig::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_finite_rate() {
        let mut c = ClusterConfig::fleet_default();
        c.arrival_rps = 1500.0;
        c.mode = ShardMode::Pipelined;
        c.boards = 7;
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_roundtrip_burst_and_no_contention() {
        let mut c = ClusterConfig::fleet_default();
        c.aggregate_ddr_bytes_per_cycle = None; // contention disabled
        assert!(c.arrival_rps.is_infinite());
        let s = c.to_json().to_string_compact();
        assert!(!s.contains("arrival_rps"), "burst is encoded by omission");
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_roundtrip_hetero_steps_reshard() {
        let mut c = ClusterConfig::fleet_default();
        c.boards = 3;
        c.board_specs = vec![
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        c.arrival_rps = 400.0;
        c.load_steps = vec![
            LoadStep {
                at_request: 64,
                rps: 900.0,
            },
            LoadStep {
                at_request: 128,
                rps: f64::INFINITY,
            },
        ];
        c.reshard = Some(ReshardPolicy::default_policy());
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn board_configs_expand_in_rack_order() {
        let base = AccelConfig::paper_default();
        let mut c = ClusterConfig::fleet_default();
        c.boards = 3;
        c.board_specs = vec![
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        let fleet = c.board_configs(&base);
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[0].platform.freq_mhz, 120.0);
        assert_eq!(fleet[1].platform.freq_mhz, 100.0);
        assert_eq!(fleet[2].platform.freq_mhz, 100.0);
        // Design knobs come from the base config.
        assert_eq!(fleet[2].max_depth_parallel, base.max_depth_parallel);

        // Homogeneous fallback.
        let c2 = ClusterConfig::fleet_default();
        let fleet2 = c2.board_configs(&base);
        assert_eq!(fleet2.len(), 4);
        assert!(fleet2.iter().all(|f| *f == base));
    }

    #[test]
    fn with_boards_resizes_heterogeneous_fleets_validly() {
        let mut c = ClusterConfig::fleet_default();
        c.boards = 4;
        c.board_specs = vec![
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        c.validate().unwrap();
        for boards in 1..=8 {
            let s = c.with_boards(boards);
            assert_eq!(s.boards, boards);
            s.validate()
                .unwrap_or_else(|e| panic!("with_boards({boards}): {e}"));
            let total: usize = s.board_specs.iter().map(|b| b.count).sum();
            assert_eq!(total, boards);
        }
        // Truncation keeps rack order: 1 board → the first (fast) spec.
        assert_eq!(c.with_boards(1).board_specs[0].platform.freq_mhz, 120.0);
        // Growth extends the last generation.
        let grown = c.with_boards(6);
        assert_eq!(grown.board_specs.last().unwrap().count, 4);
        // Homogeneous configs just change the count.
        let homo = ClusterConfig::fleet_default().with_boards(9);
        assert_eq!(homo.boards, 9);
        assert!(homo.board_specs.is_empty());
    }

    #[test]
    fn rejects_invalid() {
        for (field, bad) in [
            ("boards", r#"{"boards":0,"mode":"replicated"}"#),
            ("mode", r#"{"boards":2,"mode":"sideways"}"#),
            ("requests", r#"{"boards":2,"mode":"replicated","requests":0}"#),
            ("batch", r#"{"boards":2,"mode":"replicated","max_batch":0}"#),
            (
                "aggregate",
                r#"{"boards":2,"mode":"replicated","aggregate_ddr_bytes_per_cycle":0}"#,
            ),
            ("rate", r#"{"boards":2,"mode":"replicated","arrival_rps":-5}"#),
            (
                "spec count sum",
                r#"{"boards":3,"mode":"replicated","board_specs":[
                    {"count":1,"platform":{"name":"a","dsp":10,"bram36":10,"lut":10,
                     "ff":10,"freq_mhz":100.0,"ddr_bytes_per_cycle":8.0,"word_bytes":4}}]}"#,
            ),
            (
                "step order",
                r#"{"boards":2,"mode":"replicated","arrival_rps":100,
                    "load_steps":[{"at_request":50,"rps":200},{"at_request":20,"rps":300}]}"#,
            ),
            (
                "reshard window",
                r#"{"boards":2,"mode":"replicated","reshard":{"window":0}}"#,
            ),
        ] {
            assert!(
                ClusterConfig::from_json_str(bad).is_err(),
                "{field} should be rejected"
            );
        }
    }

    #[test]
    fn rejects_mixed_word_sizes() {
        let mut small = Platform::virtex7_at_100mhz();
        small.word_bytes = 2;
        let mut c = ClusterConfig::fleet_default();
        c.boards = 2;
        c.board_specs = vec![
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 1,
                platform: small,
            },
        ];
        assert!(c.validate().is_err());
    }

    #[test]
    fn defaults_fill_optional_fields() {
        let c = ClusterConfig::from_json_str(r#"{"boards":3,"mode":"pipelined"}"#).unwrap();
        assert_eq!(c.boards, 3);
        assert_eq!(c.mode, ShardMode::Pipelined);
        assert!(c.arrival_rps.is_infinite());
        assert_eq!(c.max_batch, ClusterConfig::fleet_default().max_batch);
        assert!(c.board_specs.is_empty());
        assert!(c.load_steps.is_empty());
        assert!(c.reshard.is_none());
    }
}
