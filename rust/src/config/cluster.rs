//! Cluster (multi-board) configuration: fleet size and composition
//! (optionally heterogeneous board generations), sharding mode, inter-board
//! link, shared off-chip bandwidth, the open-loop workload driven at the
//! fleet (optionally with load steps), the re-shard controller policy, and
//! the multi-tenant workload description (several networks sharing one
//! fleet, each with its own SLO and priority class).
//! Parsed from JSON like the other configs.

use crate::util::json::{parse, Json};

use super::accel::{AccelConfig, Platform};
use super::network::Network;

/// How the network is distributed across boards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Data parallel: every board hosts the whole network; requests are
    /// load-balanced across boards.
    Replicated,
    /// Model parallel: each board hosts a contiguous range of fusion
    /// groups; activations cross inter-board links at the cuts.
    Pipelined,
}

impl ShardMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardMode::Replicated => "replicated",
            ShardMode::Pipelined => "pipelined",
        }
    }

    pub fn from_name(s: &str) -> Result<ShardMode, String> {
        match s {
            "replicated" => Ok(ShardMode::Replicated),
            "pipelined" => Ok(ShardMode::Pipelined),
            other => Err(format!(
                "unknown shard mode '{other}' (expected 'replicated' or 'pipelined')"
            )),
        }
    }
}

/// One generation of boards in a heterogeneous fleet: `count` identical
/// boards sharing one resource envelope, clock, and provisioned DDR draw
/// (all carried by the [`Platform`]). Fleet order is the order of the specs —
/// the pipelined planner assigns stage *i* to board *i* in that order.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardSpec {
    pub count: usize,
    pub platform: Platform,
}

impl BoardSpec {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("count", self.count)
            .set("platform", self.platform.to_json())
    }

    pub fn from_json(j: &Json) -> Result<BoardSpec, String> {
        Ok(BoardSpec {
            count: j
                .get("count")
                .as_usize()
                .ok_or("board_spec: missing/invalid 'count'")?,
            platform: Platform::from_json(j.get("platform"))
                .ok_or("board_spec: missing/invalid 'platform'")?,
        })
    }
}

/// A traffic shift: from request index `at_request` onward, arrivals come at
/// `rps` requests/second (infinite = the remaining requests arrive at once).
#[derive(Debug, Clone, PartialEq)]
pub struct LoadStep {
    pub at_request: usize,
    pub rps: f64,
}

/// Policy of the load-driven re-shard controller ([`crate::cluster`]'s
/// dynamic simulator). The controller watches completed requests in windows;
/// when the window p99 or the per-board utilization skew crosses a
/// threshold, it re-plans the shard and charges a migration cost.
#[derive(Debug, Clone, PartialEq)]
pub struct ReshardPolicy {
    /// Completed requests per observation window.
    pub window: usize,
    /// Trigger when (max − min) per-board utilization over the window
    /// exceeds this (0..1 scale).
    pub util_skew: f64,
    /// Trigger when the window p99 latency exceeds this many milliseconds.
    pub p99_ms: f64,
    /// Windows to wait after a re-shard before evaluating triggers again.
    pub cooldown_windows: usize,
    /// Scales the migration byte bill (weights that change boards plus
    /// in-flight activation state). 0 makes migration free.
    pub migration_factor: f64,
}

impl ReshardPolicy {
    /// Conservative defaults: 32-request windows, re-shard on >35 points of
    /// utilization skew or a 50 ms p99, two windows of cooldown, full
    /// migration billing.
    pub fn default_policy() -> ReshardPolicy {
        ReshardPolicy {
            window: 32,
            util_skew: 0.35,
            p99_ms: 50.0,
            cooldown_windows: 2,
            migration_factor: 1.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.window == 0 {
            return Err("reshard: window must be >= 1".into());
        }
        if !(self.util_skew > 0.0) || !self.util_skew.is_finite() {
            return Err("reshard: util_skew must be finite and > 0".into());
        }
        if !(self.p99_ms > 0.0) || !self.p99_ms.is_finite() {
            return Err("reshard: p99_ms must be finite and > 0".into());
        }
        // Finiteness matters: the controller bills `cycles * migration_factor`
        // through a checked u64 cast, so an infinite factor must die here,
        // not mid-simulation.
        if !(self.migration_factor >= 0.0) || !self.migration_factor.is_finite() {
            return Err("reshard: migration_factor must be finite and >= 0".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("window", self.window)
            .set("util_skew", self.util_skew)
            .set("p99_ms", self.p99_ms)
            .set("cooldown_windows", self.cooldown_windows)
            .set("migration_factor", self.migration_factor)
    }

    pub fn from_json(j: &Json) -> Result<ReshardPolicy, String> {
        let base = ReshardPolicy::default_policy();
        Ok(ReshardPolicy {
            window: j.get("window").as_usize().unwrap_or(base.window),
            util_skew: j.get("util_skew").as_f64().unwrap_or(base.util_skew),
            p99_ms: j.get("p99_ms").as_f64().unwrap_or(base.p99_ms),
            cooldown_windows: j
                .get("cooldown_windows")
                .as_usize()
                .unwrap_or(base.cooldown_windows),
            migration_factor: j
                .get("migration_factor")
                .as_f64()
                .unwrap_or(base.migration_factor),
        })
    }
}

/// One scripted fault. Times are wall-clock milliseconds on the simulated
/// timeline (converted to reference-clock cycles by the simulator), so a
/// script composes with any arrival rate without re-deriving cycle counts.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Board `board` dies at `at_ms`. In-flight items re-queue at the head
    /// of their tenant's queue reusing the [`PreemptMode::Resume`] prefix
    /// accounting (finished prefixes complete, the remainder re-bills);
    /// replicated tenants drain to surviving peers and a severed pipelined
    /// chain triggers an emergency re-shard excluding the dead board.
    /// `recover_ms` (`None` = permanent) re-admits the board: it rejoins
    /// the candidate set coolest-first at the next controller window.
    BoardDown {
        board: usize,
        at_ms: f64,
        recover_ms: Option<f64>,
    },
    /// The egress link of board `link` runs at `factor` × its nominal
    /// bandwidth between `at_ms` and `until_ms`. Back-to-back windows on
    /// one link model a flap. Applies to any boundary/migration transfer
    /// whose source board is `link`.
    LinkDegrade {
        link: usize,
        factor: f64,
        at_ms: f64,
        until_ms: f64,
    },
    /// Board `board`'s clock runs at `factor` × nominal from `at_ms`
    /// onward (thermal derating). A later event with `factor: 1.0`
    /// restores full speed.
    ClockDerate {
        board: usize,
        factor: f64,
        at_ms: f64,
    },
    /// Board `board` loses compute columns (ECC-disabled DSP banks, a
    /// partially failed SLR): from `at_ms` it serves with only
    /// `capacity_fraction` × its nominal compute throughput. Unlike
    /// [`FaultEvent::ClockDerate`] this scales the *cost model's* service
    /// cycles (the board computes less per cycle, it does not tick
    /// slower), and the placement planner sees the brownout board as
    /// fractionally smaller rather than healthy or dead. `recover_ms`
    /// (`None` = permanent) restores full capacity.
    ComputeDegrade {
        board: usize,
        capacity_fraction: f64,
        at_ms: f64,
        recover_ms: Option<f64>,
    },
    /// Rack-scoped correlated failure: every board of rack `rack` (as
    /// mapped by [`FabricSpec`] — requires `fabric` to be configured) dies
    /// at `at_ms` and recovers together at `recover_ms` (`None` =
    /// permanent). Semantically identical to one [`FaultEvent::BoardDown`]
    /// per member board — a shared-PDU or top-of-rack-switch outage — and
    /// the reason replica placement spreads across racks as failure
    /// domains.
    RackDown {
        rack: usize,
        at_ms: f64,
        recover_ms: Option<f64>,
    },
}

impl FaultEvent {
    /// The instant the fault begins (scripts are ordered by this).
    pub fn at_ms(&self) -> f64 {
        match self {
            FaultEvent::BoardDown { at_ms, .. }
            | FaultEvent::LinkDegrade { at_ms, .. }
            | FaultEvent::ClockDerate { at_ms, .. }
            | FaultEvent::ComputeDegrade { at_ms, .. }
            | FaultEvent::RackDown { at_ms, .. } => *at_ms,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            FaultEvent::BoardDown {
                board,
                at_ms,
                recover_ms,
            } => {
                let mut j = Json::obj()
                    .set("kind", "board_down")
                    .set("board", *board)
                    .set("at_ms", *at_ms);
                if let Some(r) = recover_ms {
                    j = j.set("recover_ms", *r);
                }
                j
            }
            FaultEvent::LinkDegrade {
                link,
                factor,
                at_ms,
                until_ms,
            } => Json::obj()
                .set("kind", "link_degrade")
                .set("link", *link)
                .set("factor", *factor)
                .set("at_ms", *at_ms)
                .set("until_ms", *until_ms),
            FaultEvent::ClockDerate {
                board,
                factor,
                at_ms,
            } => Json::obj()
                .set("kind", "clock_derate")
                .set("board", *board)
                .set("factor", *factor)
                .set("at_ms", *at_ms),
            FaultEvent::ComputeDegrade {
                board,
                capacity_fraction,
                at_ms,
                recover_ms,
            } => {
                let mut j = Json::obj()
                    .set("kind", "compute_degrade")
                    .set("board", *board)
                    .set("capacity_fraction", *capacity_fraction)
                    .set("at_ms", *at_ms);
                if let Some(r) = recover_ms {
                    j = j.set("recover_ms", *r);
                }
                j
            }
            FaultEvent::RackDown {
                rack,
                at_ms,
                recover_ms,
            } => {
                let mut j = Json::obj()
                    .set("kind", "rack_down")
                    .set("rack", *rack)
                    .set("at_ms", *at_ms);
                if let Some(r) = recover_ms {
                    j = j.set("recover_ms", *r);
                }
                j
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<FaultEvent, String> {
        let at_ms = j
            .get("at_ms")
            .as_f64()
            .ok_or("fault: missing/invalid 'at_ms'")?;
        match j.get("kind").as_str().ok_or("fault: missing 'kind'")? {
            "board_down" => Ok(FaultEvent::BoardDown {
                board: j
                    .get("board")
                    .as_usize()
                    .ok_or("fault board_down: missing/invalid 'board'")?,
                at_ms,
                recover_ms: match j.get("recover_ms") {
                    Json::Null => None,
                    v => Some(
                        v.as_f64()
                            .ok_or("fault board_down: invalid 'recover_ms'")?,
                    ),
                },
            }),
            "link_degrade" => Ok(FaultEvent::LinkDegrade {
                link: j
                    .get("link")
                    .as_usize()
                    .ok_or("fault link_degrade: missing/invalid 'link'")?,
                factor: j
                    .get("factor")
                    .as_f64()
                    .ok_or("fault link_degrade: missing/invalid 'factor'")?,
                at_ms,
                until_ms: j
                    .get("until_ms")
                    .as_f64()
                    .ok_or("fault link_degrade: missing/invalid 'until_ms'")?,
            }),
            "clock_derate" => Ok(FaultEvent::ClockDerate {
                board: j
                    .get("board")
                    .as_usize()
                    .ok_or("fault clock_derate: missing/invalid 'board'")?,
                factor: j
                    .get("factor")
                    .as_f64()
                    .ok_or("fault clock_derate: missing/invalid 'factor'")?,
                at_ms,
            }),
            "compute_degrade" => Ok(FaultEvent::ComputeDegrade {
                board: j
                    .get("board")
                    .as_usize()
                    .ok_or("fault compute_degrade: missing/invalid 'board'")?,
                capacity_fraction: j
                    .get("capacity_fraction")
                    .as_f64()
                    .ok_or("fault compute_degrade: missing/invalid 'capacity_fraction'")?,
                at_ms,
                recover_ms: match j.get("recover_ms") {
                    Json::Null => None,
                    v => Some(
                        v.as_f64()
                            .ok_or("fault compute_degrade: invalid 'recover_ms'")?,
                    ),
                },
            }),
            "rack_down" => Ok(FaultEvent::RackDown {
                rack: j
                    .get("rack")
                    .as_usize()
                    .ok_or("fault rack_down: missing/invalid 'rack'")?,
                at_ms,
                recover_ms: match j.get("recover_ms") {
                    Json::Null => None,
                    v => Some(v.as_f64().ok_or("fault rack_down: invalid 'recover_ms'")?),
                },
            }),
            other => Err(format!(
                "fault: unknown kind '{other}' (expected 'board_down', \
                 'link_degrade', 'clock_derate', 'compute_degrade' or 'rack_down')"
            )),
        }
    }
}

/// A deterministic, time-ordered fault schedule injected into the
/// multi-tenant fleet simulator through the same event heap as arrivals
/// and completions — fault timing composes exactly with batching windows
/// and controller instants. Strictly opt-in: with no script configured
/// every simulator runs pre-existing code byte-for-byte.
///
/// # Examples
///
/// The CLI `--faults` file format round-trips through JSON:
///
/// ```
/// use decoilfnet::config::{FaultEvent, FaultScript};
///
/// let script = FaultScript::from_json_str(
///     r#"[
///         {"kind": "board_down", "board": 1, "at_ms": 0.5, "recover_ms": 2.0},
///         {"kind": "link_degrade", "link": 0, "factor": 0.25, "at_ms": 1.0, "until_ms": 3.0},
///         {"kind": "clock_derate", "board": 0, "factor": 0.8, "at_ms": 1.5}
///     ]"#,
/// )
/// .unwrap();
/// assert_eq!(script.events.len(), 3);
/// assert!(matches!(script.events[0], FaultEvent::BoardDown { board: 1, .. }));
/// let back = FaultScript::from_json_str(&script.to_json().to_string_pretty()).unwrap();
/// assert_eq!(back, script);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct FaultScript {
    pub events: Vec<FaultEvent>,
}

impl FaultScript {
    /// Script-local validation (board/link indices are checked against the
    /// fleet size in [`ClusterConfig::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        if self.events.is_empty() {
            return Err("faults: events must be non-empty when a script is set".into());
        }
        let mut last_at = f64::NEG_INFINITY;
        for (i, ev) in self.events.iter().enumerate() {
            let at = ev.at_ms();
            if !(at >= 0.0) || !at.is_finite() {
                return Err(format!("faults: events[{i}].at_ms must be finite and >= 0"));
            }
            if at < last_at {
                return Err("faults: events must be ordered by at_ms".into());
            }
            last_at = at;
            match ev {
                FaultEvent::BoardDown { recover_ms, .. } => {
                    if let Some(r) = recover_ms {
                        if !(r > &at) || !r.is_finite() {
                            return Err(format!(
                                "faults: events[{i}].recover_ms must be finite and > at_ms"
                            ));
                        }
                    }
                }
                FaultEvent::LinkDegrade {
                    factor, until_ms, ..
                } => {
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err(format!(
                            "faults: events[{i}].factor must be in (0, 1]"
                        ));
                    }
                    if !(until_ms > &at) || !until_ms.is_finite() {
                        return Err(format!(
                            "faults: events[{i}].until_ms must be finite and > at_ms"
                        ));
                    }
                }
                FaultEvent::ClockDerate { factor, .. } => {
                    if !(*factor > 0.0 && *factor <= 1.0) {
                        return Err(format!(
                            "faults: events[{i}].factor must be in (0, 1]"
                        ));
                    }
                }
                FaultEvent::ComputeDegrade {
                    capacity_fraction,
                    recover_ms,
                    ..
                } => {
                    if !(*capacity_fraction > 0.0 && *capacity_fraction <= 1.0) {
                        return Err(format!(
                            "faults: events[{i}].capacity_fraction must be in (0, 1]"
                        ));
                    }
                    if let Some(r) = recover_ms {
                        if !(r > &at) || !r.is_finite() {
                            return Err(format!(
                                "faults: events[{i}].recover_ms must be finite and > at_ms"
                            ));
                        }
                    }
                }
                FaultEvent::RackDown { recover_ms, .. } => {
                    if let Some(r) = recover_ms {
                        if !(r > &at) || !r.is_finite() {
                            return Err(format!(
                                "faults: events[{i}].recover_ms must be finite and > at_ms"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut arr = Json::Arr(vec![]);
        for e in &self.events {
            arr = arr.push(e.to_json());
        }
        Json::obj().set("events", arr)
    }

    /// Accepts either `{"events": [...]}` or a bare JSON array of events
    /// (the CLI `--faults` file format).
    pub fn from_json(j: &Json) -> Result<FaultScript, String> {
        let list = match j {
            Json::Arr(_) => j,
            _ => match j.get("events") {
                Json::Null => return Err("faults: missing 'events' array".into()),
                v => v,
            },
        };
        let events = list
            .as_arr()
            .ok_or("faults: 'events' must be an array")?
            .iter()
            .map(FaultEvent::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let script = FaultScript { events };
        script.validate()?;
        Ok(script)
    }

    pub fn from_json_str(s: &str) -> Result<FaultScript, String> {
        let j = parse(s).map_err(|e| format!("faults json: {e}"))?;
        FaultScript::from_json(&j)
    }
}

/// How a preempted batch is re-served.
///
/// `Restart` is the original protocol: the victim's items are all re-queued
/// and their next service pays the full batch cost again plus
/// [`ClusterConfig::preempt_restart_cycles`] — the board's partial work is
/// thrown away. `Resume` is work-preserving: items whose service the victim
/// had already completed at the preemption instant finish there and then,
/// only the unfinished remainder re-queues, and the next service pays only
/// [`ClusterConfig::preempt_refill_cycles`] (the pipeline refill /
/// context-restore) on top of the remainder's own cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptMode {
    Restart,
    Resume,
}

impl PreemptMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            PreemptMode::Restart => "restart",
            PreemptMode::Resume => "resume",
        }
    }

    pub fn from_name(s: &str) -> Result<PreemptMode, String> {
        match s {
            "restart" => Ok(PreemptMode::Restart),
            "resume" => Ok(PreemptMode::Resume),
            other => Err(format!(
                "unknown preempt mode '{other}' (expected 'restart' or 'resume')"
            )),
        }
    }
}

/// Client retry behavior for shed requests: a shed request re-arrives
/// after an exponentially growing, deterministically jittered backoff
/// until its attempts are exhausted, at which point it is **abandoned**
/// (counted, never served — conservation holds as
/// `offered == completed + abandoned`).
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Retry attempts after the initial shed (0 = shed once then abandon).
    pub max_attempts: u32,
    /// Backoff before retry *k* (1-based) is `backoff_base_ms × 2^(k−1)`,
    /// stretched by the jitter draw.
    pub backoff_base_ms: f64,
    /// Jitter fraction in [0, 1]: each backoff is multiplied by
    /// `1 + jitter × u` with `u ∈ [0, 1)` drawn from a deterministic
    /// per-(tenant, request, attempt) stream — retries de-synchronize
    /// without perturbing reproducibility.
    pub jitter: f64,
}

impl RetryPolicy {
    /// Defaults: 3 attempts, 1 ms base backoff, no jitter.
    pub fn default_policy() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            backoff_base_ms: 1.0,
            jitter: 0.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.backoff_base_ms > 0.0) || !self.backoff_base_ms.is_finite() {
            return Err("retry: backoff_base_ms must be finite and > 0".into());
        }
        if !(self.jitter >= 0.0 && self.jitter <= 1.0) {
            return Err("retry: jitter must be in [0, 1]".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("max_attempts", self.max_attempts as usize)
            .set("backoff_base_ms", self.backoff_base_ms)
            .set("jitter", self.jitter)
    }

    pub fn from_json(j: &Json) -> Result<RetryPolicy, String> {
        let base = RetryPolicy::default_policy();
        Ok(RetryPolicy {
            max_attempts: j
                .get("max_attempts")
                .as_usize()
                .map(|v| v as u32)
                .unwrap_or(base.max_attempts),
            backoff_base_ms: j
                .get("backoff_base_ms")
                .as_f64()
                .unwrap_or(base.backoff_base_ms),
            jitter: j.get("jitter").as_f64().unwrap_or(base.jitter),
        })
    }
}

/// Overload shedding policy of one tenant. When set, admission stops being
/// unconditional: each arrival's wait is predicted from the tenant's queue
/// depth and its hosting boards' occupancy, and a request that cannot meet
/// `deadline_ms` (or that lands on a queue already `max_queue` deep) is
/// **shed** — bounced back to the client, who retries per `retry`. Strictly
/// opt-in: with no policy every request is admitted and the engine runs the
/// pre-overload code byte-for-byte.
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadPolicy {
    /// Admission deadline in milliseconds: shed when the predicted
    /// queue + service wait exceeds this.
    pub deadline_ms: f64,
    /// Hard cap on the tenant's pending-request queue depth; arrivals
    /// beyond it are shed regardless of the deadline prediction.
    pub max_queue: usize,
    pub retry: RetryPolicy,
}

impl OverloadPolicy {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.deadline_ms > 0.0) || !self.deadline_ms.is_finite() {
            return Err("overload: deadline_ms must be finite and > 0".into());
        }
        if self.max_queue == 0 {
            return Err("overload: max_queue must be >= 1".into());
        }
        self.retry.validate()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("deadline_ms", self.deadline_ms)
            .set("max_queue", self.max_queue)
            .set("retry", self.retry.to_json())
    }

    pub fn from_json(j: &Json) -> Result<OverloadPolicy, String> {
        Ok(OverloadPolicy {
            deadline_ms: j
                .get("deadline_ms")
                .as_f64()
                .ok_or("overload: missing/invalid 'deadline_ms'")?,
            max_queue: j
                .get("max_queue")
                .as_usize()
                .ok_or("overload: missing/invalid 'max_queue'")?,
            retry: match j.get("retry") {
                Json::Null => RetryPolicy::default_policy(),
                v => RetryPolicy::from_json(v)?,
            },
        })
    }
}

/// Service-level objective of one tenant: a latency target plus a priority
/// class and a fair-share weight. Priorities are strict: under contention a
/// higher-priority tenant's batch may preempt a lower-priority tenant's
/// batch mid-service (the preempted work is re-queued and billed a
/// mode-dependent penalty). *Within* one priority class, admission is
/// deficit-weighted round-robin on `weight`: each tenant carries a deficit
/// counter of normalized service (billed cycles / weight) and the
/// lowest-deficit pending tenant is admitted first, so equal-class peers
/// share boards in proportion to their weights instead of starving on
/// tenant order.
#[derive(Debug, Clone, PartialEq)]
pub struct SloPolicy {
    /// Target p99 latency in milliseconds; the per-tenant report compares
    /// the simulated p99 against this and sets `slo_met`. On the unified
    /// control plane (re-shard policy armed) this is also the tenant's
    /// re-shard trigger: a window p99 above it marks the tenant for
    /// scale-out at the next placement.
    pub p99_ms: f64,
    /// Priority class: larger values preempt smaller ones. Equal priorities
    /// never preempt each other.
    pub priority: u8,
    /// Fair-share weight within the priority class (> 0; 1.0 = equal
    /// share). A weight-2 tenant gets twice the service share of a weight-1
    /// peer of the same class while both have pending work.
    pub weight: f64,
    /// Overload shedding + client retry/backoff. `None` (the default, and
    /// the JSON key absent) admits every request unconditionally — the
    /// pre-overload engine byte-for-byte.
    pub overload: Option<OverloadPolicy>,
}

impl SloPolicy {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.p99_ms > 0.0) || !self.p99_ms.is_finite() {
            return Err("slo: p99_ms must be finite and > 0".into());
        }
        if !(self.weight > 0.0) || !self.weight.is_finite() {
            return Err("slo: weight must be finite and > 0".into());
        }
        if let Some(o) = &self.overload {
            o.validate()?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("p99_ms", self.p99_ms)
            .set("priority", self.priority as usize)
            .set("weight", self.weight);
        if let Some(o) = &self.overload {
            j = j.set("overload", o.to_json());
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<SloPolicy, String> {
        Ok(SloPolicy {
            p99_ms: j
                .get("p99_ms")
                .as_f64()
                .ok_or("slo: missing/invalid 'p99_ms'")?,
            // Absent means the lowest class; present-but-malformed is an
            // error, not a silent demotion to priority 0.
            priority: match j.get("priority") {
                Json::Null => 0,
                v => v
                    .as_usize()
                    .filter(|&p| p <= u8::MAX as usize)
                    .ok_or("slo: 'priority' must be an integer in 0..=255")?
                    as u8,
            },
            // Absent means an equal share.
            weight: match j.get("weight") {
                Json::Null => 1.0,
                v => v.as_f64().ok_or("slo: 'weight' must be a number")?,
            },
            // Absent means unconditional admission (the pre-overload
            // engine, and what every committed fixture scenario uses).
            overload: match j.get("overload") {
                Json::Null => None,
                v => Some(OverloadPolicy::from_json(v)?),
            },
        })
    }
}

/// One tenant of a shared fleet: its own network, weights, open-loop
/// workload and SLO. Multi-tenant simulation ignores the fleet-level
/// `arrival_rps`/`requests`/`load_steps` fields and drives each tenant's
/// stream instead; per-tenant streams are seeded from the cluster seed and
/// the tenant index, so every tenant samples an independent arrival path.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Unique tenant name (reports and CLI tables key on it).
    pub name: String,
    /// The tenant's own network.
    pub network: Network,
    /// Seed for this tenant's synthetic weights.
    pub weights_seed: u64,
    /// Open-loop arrival rate in requests/second (JSON: absent/null means a
    /// saturating burst, as at fleet level).
    pub arrival_rps: f64,
    /// Requests this tenant fires.
    pub requests: usize,
    /// Traffic shifts on top of `arrival_rps` (per-tenant load spikes).
    pub load_steps: Vec<LoadStep>,
    /// How this tenant's network is sharded across the fleet.
    pub mode: ShardMode,
    /// Replicated mode: cap on the number of replicas the placement planner
    /// may take (`None` = every board with room). Capping a high-priority
    /// tenant leaves fabric — including the board prefix a pipelined tenant
    /// needs — free for lower classes.
    pub replicas: Option<usize>,
    pub slo: SloPolicy,
}

impl TenantSpec {
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("tenant: name must be non-empty".into());
        }
        self.network
            .validate()
            .map_err(|e| format!("tenant '{}': {e}", self.name))?;
        if self.requests == 0 {
            return Err(format!("tenant '{}': requests must be >= 1", self.name));
        }
        if !(self.arrival_rps > 0.0) {
            return Err(format!(
                "tenant '{}': arrival_rps must be > 0 (or omitted for a burst)",
                self.name
            ));
        }
        if self.replicas == Some(0) {
            return Err(format!(
                "tenant '{}': replicas must be >= 1 when set",
                self.name
            ));
        }
        let mut last_at = None;
        for (i, st) in self.load_steps.iter().enumerate() {
            if !(st.rps > 0.0) {
                return Err(format!(
                    "tenant '{}': load_steps[{i}].rps must be > 0",
                    self.name
                ));
            }
            if let Some(prev) = last_at {
                if st.at_request <= prev {
                    return Err(format!(
                        "tenant '{}': load_steps must be ordered by at_request",
                        self.name
                    ));
                }
            }
            last_at = Some(st.at_request);
        }
        self.slo
            .validate()
            .map_err(|e| format!("tenant '{}': {e}", self.name))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("network", self.network.to_json())
            .set("weights_seed", self.weights_seed)
            .set("requests", self.requests)
            .set("mode", self.mode.as_str())
            .set("slo", self.slo.to_json());
        // As at fleet level, a saturating burst is encoded by omission.
        if self.arrival_rps.is_finite() {
            j = j.set("arrival_rps", self.arrival_rps);
        }
        if let Some(r) = self.replicas {
            j = j.set("replicas", r);
        }
        if !self.load_steps.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for s in &self.load_steps {
                let mut o = Json::obj().set("at_request", s.at_request);
                if s.rps.is_finite() {
                    o = o.set("rps", s.rps);
                }
                arr = arr.push(o);
            }
            j = j.set("load_steps", arr);
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<TenantSpec, String> {
        let load_steps = parse_load_steps(j.get("load_steps"), "tenant")?;
        let spec = TenantSpec {
            name: j
                .get("name")
                .as_str()
                .ok_or("tenant: missing/invalid 'name'")?
                .to_string(),
            network: Network::from_json(j.get("network"))
                .map_err(|e| format!("tenant network: {e}"))?,
            weights_seed: j.get("weights_seed").as_u64().unwrap_or(1),
            arrival_rps: match j.get("arrival_rps") {
                Json::Null => f64::INFINITY,
                v => v.as_f64().ok_or("tenant: invalid 'arrival_rps'")?,
            },
            requests: j
                .get("requests")
                .as_usize()
                .ok_or("tenant: missing/invalid 'requests'")?,
            load_steps,
            mode: match j.get("mode") {
                Json::Null => ShardMode::Replicated,
                v => ShardMode::from_name(v.as_str().ok_or("tenant: invalid 'mode'")?)?,
            },
            replicas: match j.get("replicas") {
                Json::Null => None,
                v => Some(v.as_usize().ok_or("tenant: invalid 'replicas'")?),
            },
            slo: SloPolicy::from_json(j.get("slo"))?,
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// Parse a `load_steps` JSON array (shared by the fleet-level and per-tenant
/// forms; `ctx` names the owner in error messages).
fn parse_load_steps(j: &Json, ctx: &str) -> Result<Vec<LoadStep>, String> {
    match j {
        Json::Null => Ok(Vec::new()),
        v => v
            .as_arr()
            .ok_or_else(|| format!("{ctx}: 'load_steps' must be an array"))?
            .iter()
            .map(|s| -> Result<LoadStep, String> {
                Ok(LoadStep {
                    at_request: s
                        .get("at_request")
                        .as_usize()
                        .ok_or_else(|| format!("{ctx}: load_step missing 'at_request'"))?,
                    rps: match s.get("rps") {
                        Json::Null => f64::INFINITY,
                        v => v
                            .as_f64()
                            .ok_or_else(|| format!("{ctx}: invalid load_step 'rps'"))?,
                    },
                })
            })
            .collect(),
    }
}

/// How the racks of a [`FabricSpec`] are wired together.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricTopology {
    /// Racks on a ring: cross-rack traffic hops rack-to-rack along the
    /// shorter arc (ties resolved clockwise), crossing one inter-rack
    /// segment per hop. Cheap to build, hop count grows with distance.
    RackRing,
    /// Two-tier leaf-spine: every rack's uplink reaches a non-blocking
    /// spine, so any cross-rack route is exactly source-uplink →
    /// destination-uplink regardless of rack distance — but all of a
    /// rack's cross-rack traffic (in either direction) serializes on its
    /// one uplink.
    LeafSpine,
}

impl FabricTopology {
    pub fn as_str(&self) -> &'static str {
        match self {
            FabricTopology::RackRing => "rack_ring",
            FabricTopology::LeafSpine => "leaf_spine",
        }
    }

    pub fn from_name(s: &str) -> Result<FabricTopology, String> {
        match s {
            "rack_ring" => Ok(FabricTopology::RackRing),
            "leaf_spine" => Ok(FabricTopology::LeafSpine),
            other => Err(format!(
                "unknown fabric topology '{other}' (expected 'rack_ring' or 'leaf_spine')"
            )),
        }
    }
}

/// Rack-scale interconnect description: boards map to racks in contiguous
/// chunks of `boards_per_rack` (board `b` lives in rack
/// `b / boards_per_rack`, mirroring the rack order `board_specs` already
/// uses), intra-rack traffic crosses that rack's backplane segment, and
/// cross-rack traffic additionally crosses inter-rack uplink segments per
/// the [`FabricTopology`]. Every segment is a *shared serializing
/// timeline* (the [`crate::cluster::LinkChannel`] occupancy model), so
/// co-tenant transfers, migration bills and fault drains genuinely
/// contend. `None` on [`ClusterConfig::fabric`] (the default, JSON key
/// absent) keeps the original private point-to-point link arithmetic
/// byte-for-byte.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricSpec {
    pub topology: FabricTopology,
    /// Boards per rack (the last rack may be partially filled).
    pub boards_per_rack: usize,
    /// Intra-rack backplane segment bandwidth, bytes per reference cycle.
    pub intra_bytes_per_cycle: f64,
    /// Per-transfer intra-rack hop latency (serialization + switch).
    pub intra_latency_cycles: u64,
    /// Inter-rack uplink segment bandwidth, bytes per reference cycle.
    /// Typically thinner than the backplane — the whole point: a saturated
    /// uplink is the fleet-scale shared channel.
    pub uplink_bytes_per_cycle: f64,
    /// Per-transfer uplink hop latency.
    pub uplink_latency_cycles: u64,
}

impl FabricSpec {
    /// Default leaf-spine fabric: backplane as fat as the classic
    /// point-to-point link (16 B/cycle, 64-cycle hop), uplinks a quarter
    /// as wide with a switch-traversal latency — cross-rack costs are
    /// real but not pathological.
    pub fn leaf_spine(boards_per_rack: usize) -> FabricSpec {
        FabricSpec {
            topology: FabricTopology::LeafSpine,
            boards_per_rack,
            intra_bytes_per_cycle: 16.0,
            intra_latency_cycles: 64,
            uplink_bytes_per_cycle: 4.0,
            uplink_latency_cycles: 256,
        }
    }

    /// Same segment parameters on a rack ring.
    pub fn rack_ring(boards_per_rack: usize) -> FabricSpec {
        FabricSpec {
            topology: FabricTopology::RackRing,
            ..FabricSpec::leaf_spine(boards_per_rack)
        }
    }

    /// Rack housing board `b`.
    pub fn rack_of(&self, board: usize) -> usize {
        board / self.boards_per_rack
    }

    /// Number of racks a `boards`-board fleet occupies.
    pub fn n_racks(&self, boards: usize) -> usize {
        boards.div_ceil(self.boards_per_rack)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.boards_per_rack == 0 {
            return Err("fabric: boards_per_rack must be >= 1".into());
        }
        if !(self.intra_bytes_per_cycle > 0.0) || !self.intra_bytes_per_cycle.is_finite() {
            return Err("fabric: intra_bytes_per_cycle must be finite and > 0".into());
        }
        if !(self.uplink_bytes_per_cycle > 0.0) || !self.uplink_bytes_per_cycle.is_finite() {
            return Err("fabric: uplink_bytes_per_cycle must be finite and > 0".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("topology", self.topology.as_str())
            .set("boards_per_rack", self.boards_per_rack)
            .set("intra_bytes_per_cycle", self.intra_bytes_per_cycle)
            .set("intra_latency_cycles", self.intra_latency_cycles)
            .set("uplink_bytes_per_cycle", self.uplink_bytes_per_cycle)
            .set("uplink_latency_cycles", self.uplink_latency_cycles)
    }

    pub fn from_json(j: &Json) -> Result<FabricSpec, String> {
        let base = FabricSpec::leaf_spine(
            j.get("boards_per_rack")
                .as_usize()
                .ok_or("fabric: missing/invalid 'boards_per_rack'")?,
        );
        let spec = FabricSpec {
            topology: FabricTopology::from_name(
                j.get("topology")
                    .as_str()
                    .ok_or("fabric: missing 'topology'")?,
            )?,
            boards_per_rack: base.boards_per_rack,
            intra_bytes_per_cycle: j
                .get("intra_bytes_per_cycle")
                .as_f64()
                .unwrap_or(base.intra_bytes_per_cycle),
            intra_latency_cycles: j
                .get("intra_latency_cycles")
                .as_u64()
                .unwrap_or(base.intra_latency_cycles),
            uplink_bytes_per_cycle: j
                .get("uplink_bytes_per_cycle")
                .as_f64()
                .unwrap_or(base.uplink_bytes_per_cycle),
            uplink_latency_cycles: j
                .get("uplink_latency_cycles")
                .as_u64()
                .unwrap_or(base.uplink_latency_cycles),
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json_str(s: &str) -> Result<FabricSpec, String> {
        let j = parse(s).map_err(|e| format!("fabric json: {e}"))?;
        FabricSpec::from_json(&j)
    }
}

/// Configuration of a simulated multi-accelerator serving fleet.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of boards provisioned. Pipelined mode may leave boards idle
    /// when the network has fewer fusion groups than boards.
    pub boards: usize,
    pub mode: ShardMode,
    /// Fleet composition for heterogeneous fleets. Empty means `boards`
    /// identical boards on the base config's platform; otherwise the counts
    /// must sum to `boards` and fleet order follows spec order.
    pub board_specs: Vec<BoardSpec>,
    /// Inter-board link bandwidth (bytes per reference-clock cycle). Links
    /// have finite capacity: concurrent boundary transfers serialize, so the
    /// link itself can become the bottleneck stage of a pipelined fleet.
    pub link_bytes_per_cycle: f64,
    /// Fixed per-transfer link latency (serialization + switch hop).
    pub link_latency_cycles: u64,
    /// Aggregate off-chip bandwidth shared by all co-located boards, in
    /// bytes/cycle at the reference clock. `None` disables the contention
    /// model (each board keeps its full private provisioned rate).
    pub aggregate_ddr_bytes_per_cycle: Option<f64>,
    /// Open-loop arrival rate in requests/second. `f64::INFINITY` (JSON:
    /// field absent or `null`) means a saturating burst: every request
    /// arrives at t = 0, which measures fleet capacity.
    pub arrival_rps: f64,
    /// Traffic shifts applied on top of `arrival_rps` (empty = constant
    /// rate). Steps must be ordered by `at_request`.
    pub load_steps: Vec<LoadStep>,
    /// Number of requests the workload generator fires.
    pub requests: usize,
    /// PRNG seed for arrival sampling.
    pub seed: u64,
    /// Per-board dynamic batching bounds (mirrors `BatchPolicy`).
    pub max_batch: usize,
    pub max_wait_us: f64,
    /// Load-driven re-shard controller; `None` keeps the initial shard for
    /// the whole run.
    pub reshard: Option<ReshardPolicy>,
    /// Tenants sharing this fleet. Empty means the classic single-network
    /// simulation; non-empty switches `run_fleet` to the multi-tenant
    /// placement planner + priority-aware simulator, and the fleet-level
    /// `arrival_rps`/`requests`/`load_steps` fields are ignored in favor of
    /// each tenant's own stream.
    pub tenants: Vec<TenantSpec>,
    /// Restart penalty in reference-clock cycles billed when a preempted
    /// batch is re-served under [`PreemptMode::Restart`] (full context
    /// restore; the victim's partial work is also re-done).
    pub preempt_restart_cycles: u64,
    /// How preempted batches are re-served. `Restart` reproduces the
    /// original fixture behavior; `Resume` is work-preserving.
    pub preempt_mode: PreemptMode,
    /// Pipeline-refill penalty in reference-clock cycles billed when a
    /// preempted batch resumes under [`PreemptMode::Resume`] (only the
    /// refill — completed items are kept).
    pub preempt_refill_cycles: u64,
    /// Deterministic fault schedule (board death/recovery, link
    /// degradation, clock derating, partial-capacity brownouts) injected
    /// into the simulator's event stream. `None` (the default, and the
    /// JSON key absent) runs a perfectly healthy fleet byte-for-byte
    /// identically to the pre-fault engine. The single-network simulators
    /// accept `board_down` and `clock_derate` only; `link_degrade` and
    /// `compute_degrade` require a non-empty `tenants` array.
    pub faults: Option<FaultScript>,
    /// Rack-scale interconnect topology. `None` (the default, JSON key
    /// absent) keeps every transfer on the original private
    /// point-to-point links byte-for-byte; `Some` routes all traffic —
    /// pipeline boundaries, migrations, fault drains — over shared
    /// serializing fabric segments and makes placement topology-aware.
    pub fabric: Option<FabricSpec>,
}

impl ClusterConfig {
    /// A small default fleet: 4 replicated boards, PCIe-class links, shared
    /// DDR pool worth two boards, moderate open-loop load.
    pub fn fleet_default() -> ClusterConfig {
        ClusterConfig {
            boards: 4,
            mode: ShardMode::Replicated,
            board_specs: Vec::new(),
            link_bytes_per_cycle: 16.0,
            link_latency_cycles: 64,
            aggregate_ddr_bytes_per_cycle: Some(128.0),
            arrival_rps: f64::INFINITY,
            load_steps: Vec::new(),
            requests: 256,
            seed: 1,
            max_batch: 8,
            max_wait_us: 200.0,
            reshard: None,
            tenants: Vec::new(),
            preempt_restart_cycles: 500,
            preempt_mode: PreemptMode::Restart,
            preempt_refill_cycles: 100,
            faults: None,
            fabric: None,
        }
    }

    /// A copy of this config provisioned with `boards` boards (the sweep
    /// form). A homogeneous fleet just changes the count; a heterogeneous
    /// fleet keeps rack order and truncates the generation counts to fit —
    /// or extends the last generation when growing — so the copy always
    /// validates.
    pub fn with_boards(&self, boards: usize) -> ClusterConfig {
        let mut c = self.clone();
        c.boards = boards;
        if !c.board_specs.is_empty() {
            let mut specs: Vec<BoardSpec> = Vec::new();
            let mut left = boards;
            for s in &self.board_specs {
                if left == 0 {
                    break;
                }
                let take = s.count.min(left);
                specs.push(BoardSpec {
                    count: take,
                    platform: s.platform.clone(),
                });
                left -= take;
            }
            if left > 0 {
                if let Some(last) = specs.last_mut() {
                    last.count += left;
                }
            }
            c.board_specs = specs;
        }
        c
    }

    /// Expand the fleet into one `AccelConfig` per physical board, in rack
    /// order: each board inherits the base config's design knobs and swaps
    /// in its generation's platform (resource envelope, clock, DDR share).
    pub fn board_configs(&self, base: &AccelConfig) -> Vec<AccelConfig> {
        if self.board_specs.is_empty() {
            return vec![base.clone(); self.boards];
        }
        let mut fleet = Vec::with_capacity(self.boards);
        for spec in &self.board_specs {
            for _ in 0..spec.count {
                fleet.push(AccelConfig {
                    platform: spec.platform.clone(),
                    ..base.clone()
                });
            }
        }
        fleet
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.boards == 0 {
            return Err("cluster: boards must be >= 1".into());
        }
        if self.requests == 0 {
            return Err("cluster: requests must be >= 1".into());
        }
        if self.max_batch == 0 {
            return Err("cluster: max_batch must be >= 1".into());
        }
        if !(self.link_bytes_per_cycle > 0.0) {
            return Err("cluster: link_bytes_per_cycle must be > 0".into());
        }
        if let Some(a) = self.aggregate_ddr_bytes_per_cycle {
            if !(a > 0.0) {
                return Err("cluster: aggregate_ddr_bytes_per_cycle must be > 0".into());
            }
        }
        if !(self.arrival_rps > 0.0) {
            return Err("cluster: arrival_rps must be > 0 (or omitted for a burst)".into());
        }
        // The batcher converts this straight to a nanosecond deadline through
        // a checked u64 cast; an infinite wait must fail validation, not
        // panic when the first queue turns non-empty.
        if !(self.max_wait_us >= 0.0) || !self.max_wait_us.is_finite() {
            return Err("cluster: max_wait_us must be finite and >= 0".into());
        }
        if !self.board_specs.is_empty() {
            let total: usize = self.board_specs.iter().map(|s| s.count).sum();
            if total != self.boards {
                return Err(format!(
                    "cluster: board_specs counts sum to {total}, expected boards = {}",
                    self.boards
                ));
            }
            let wb = self.board_specs[0].platform.word_bytes;
            for (i, s) in self.board_specs.iter().enumerate() {
                if s.count == 0 {
                    return Err(format!("cluster: board_specs[{i}].count must be >= 1"));
                }
                let p = &s.platform;
                if !(p.freq_mhz > 0.0) || !(p.ddr_bytes_per_cycle > 0.0) || p.word_bytes == 0 {
                    return Err(format!(
                        "cluster: board_specs[{i}].platform needs freq_mhz > 0, \
                         ddr_bytes_per_cycle > 0, word_bytes >= 1"
                    ));
                }
                if p.word_bytes != wb {
                    return Err(
                        "cluster: all board generations must share one word size \
                         (mixed word_bytes would change boundary volumes mid-pipeline)"
                            .into(),
                    );
                }
            }
        }
        let mut last_at = None;
        for (i, st) in self.load_steps.iter().enumerate() {
            if !(st.rps > 0.0) {
                return Err(format!("cluster: load_steps[{i}].rps must be > 0"));
            }
            if let Some(prev) = last_at {
                if st.at_request <= prev {
                    return Err("cluster: load_steps must be ordered by at_request".into());
                }
            }
            last_at = Some(st.at_request);
        }
        if let Some(r) = &self.reshard {
            r.validate()?;
        }
        for (i, t) in self.tenants.iter().enumerate() {
            t.validate()?;
            if self.tenants[..i].iter().any(|o| o.name == t.name) {
                return Err(format!("cluster: duplicate tenant name '{}'", t.name));
            }
        }
        if let Some(fb) = &self.fabric {
            fb.validate()?;
        }
        if let Some(f) = &self.faults {
            f.validate()?;
            for (i, ev) in f.events.iter().enumerate() {
                // The single-network simulators understand board death and
                // clock derating; link degradation, partial-capacity
                // brownouts and rack outages are multi-tenant-only
                // semantics.
                if self.tenants.is_empty()
                    && matches!(
                        ev,
                        FaultEvent::LinkDegrade { .. }
                            | FaultEvent::ComputeDegrade { .. }
                            | FaultEvent::RackDown { .. }
                    )
                {
                    return Err(format!(
                        "cluster: faults events[{i}] requires a non-empty 'tenants' \
                         array (the single-network simulators only inject \
                         'board_down' and 'clock_derate')"
                    ));
                }
                if let FaultEvent::RackDown { rack, .. } = ev {
                    let fb = self.fabric.as_ref().ok_or_else(|| {
                        format!(
                            "cluster: faults events[{i}] is 'rack_down' but no 'fabric' \
                             is configured — racks only exist on a fabric"
                        )
                    })?;
                    let n_racks = fb.n_racks(self.boards);
                    if *rack >= n_racks {
                        return Err(format!(
                            "cluster: faults events[{i}].rack = {rack} out of range \
                             (fabric has {n_racks} rack(s))"
                        ));
                    }
                    continue;
                }
                let (label, b) = match ev {
                    FaultEvent::BoardDown { board, .. } => ("board", *board),
                    FaultEvent::LinkDegrade { link, .. } => ("link", *link),
                    FaultEvent::ClockDerate { board, .. } => ("board", *board),
                    FaultEvent::ComputeDegrade { board, .. } => ("board", *board),
                    FaultEvent::RackDown { .. } => unreachable!("handled above"),
                };
                if b >= self.boards {
                    return Err(format!(
                        "cluster: faults events[{i}].{label} = {b} out of range \
                         (boards = {})",
                        self.boards
                    ));
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("boards", self.boards)
            .set("mode", self.mode.as_str())
            .set("link_bytes_per_cycle", self.link_bytes_per_cycle)
            .set("link_latency_cycles", self.link_latency_cycles)
            .set("requests", self.requests)
            .set("seed", self.seed)
            .set("max_batch", self.max_batch)
            .set("max_wait_us", self.max_wait_us)
            .set("preempt_restart_cycles", self.preempt_restart_cycles)
            .set("preempt_mode", self.preempt_mode.as_str())
            .set("preempt_refill_cycles", self.preempt_refill_cycles);
        if let Some(a) = self.aggregate_ddr_bytes_per_cycle {
            j = j.set("aggregate_ddr_bytes_per_cycle", a);
        }
        // JSON has no Infinity: a saturating burst is encoded by omission.
        if self.arrival_rps.is_finite() {
            j = j.set("arrival_rps", self.arrival_rps);
        }
        if !self.board_specs.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for s in &self.board_specs {
                arr = arr.push(s.to_json());
            }
            j = j.set("board_specs", arr);
        }
        if !self.load_steps.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for s in &self.load_steps {
                let mut o = Json::obj().set("at_request", s.at_request);
                if s.rps.is_finite() {
                    o = o.set("rps", s.rps);
                }
                arr = arr.push(o);
            }
            j = j.set("load_steps", arr);
        }
        if let Some(r) = &self.reshard {
            j = j.set("reshard", r.to_json());
        }
        if !self.tenants.is_empty() {
            let mut arr = Json::Arr(vec![]);
            for t in &self.tenants {
                arr = arr.push(t.to_json());
            }
            j = j.set("tenants", arr);
        }
        if let Some(f) = &self.faults {
            j = j.set("faults", f.to_json());
        }
        if let Some(fb) = &self.fabric {
            j = j.set("fabric", fb.to_json());
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<ClusterConfig, String> {
        let base = ClusterConfig::fleet_default();
        let board_specs = match j.get("board_specs") {
            Json::Null => Vec::new(),
            v => v
                .as_arr()
                .ok_or("cluster: 'board_specs' must be an array")?
                .iter()
                .map(BoardSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?,
        };
        let load_steps = parse_load_steps(j.get("load_steps"), "cluster")?;
        let reshard = match j.get("reshard") {
            Json::Null => None,
            v => Some(ReshardPolicy::from_json(v)?),
        };
        let tenants = match j.get("tenants") {
            Json::Null => Vec::new(),
            v => v
                .as_arr()
                .ok_or("cluster: 'tenants' must be an array")?
                .iter()
                .map(TenantSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?,
        };
        let faults = match j.get("faults") {
            Json::Null => None,
            v => Some(FaultScript::from_json(v)?),
        };
        let fabric = match j.get("fabric") {
            Json::Null => None,
            v => Some(FabricSpec::from_json(v)?),
        };
        let cfg = ClusterConfig {
            boards: j
                .get("boards")
                .as_usize()
                .ok_or("cluster: missing/invalid 'boards'")?,
            mode: ShardMode::from_name(
                j.get("mode").as_str().ok_or("cluster: missing 'mode'")?,
            )?,
            board_specs,
            link_bytes_per_cycle: j
                .get("link_bytes_per_cycle")
                .as_f64()
                .unwrap_or(base.link_bytes_per_cycle),
            link_latency_cycles: j
                .get("link_latency_cycles")
                .as_u64()
                .unwrap_or(base.link_latency_cycles),
            aggregate_ddr_bytes_per_cycle: match j.get("aggregate_ddr_bytes_per_cycle") {
                Json::Null => None,
                v => Some(
                    v.as_f64()
                        .ok_or("cluster: invalid 'aggregate_ddr_bytes_per_cycle'")?,
                ),
            },
            arrival_rps: match j.get("arrival_rps") {
                Json::Null => f64::INFINITY,
                v => v.as_f64().ok_or("cluster: invalid 'arrival_rps'")?,
            },
            load_steps,
            requests: j.get("requests").as_usize().unwrap_or(base.requests),
            seed: j.get("seed").as_u64().unwrap_or(base.seed),
            max_batch: j.get("max_batch").as_usize().unwrap_or(base.max_batch),
            max_wait_us: j.get("max_wait_us").as_f64().unwrap_or(base.max_wait_us),
            reshard,
            tenants,
            preempt_restart_cycles: j
                .get("preempt_restart_cycles")
                .as_u64()
                .unwrap_or(base.preempt_restart_cycles),
            preempt_mode: match j.get("preempt_mode") {
                Json::Null => base.preempt_mode,
                v => PreemptMode::from_name(
                    v.as_str().ok_or("cluster: invalid 'preempt_mode'")?,
                )?,
            },
            preempt_refill_cycles: j
                .get("preempt_refill_cycles")
                .as_u64()
                .unwrap_or(base.preempt_refill_cycles),
            faults,
            fabric,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<ClusterConfig, String> {
        let j = parse(s).map_err(|e| format!("cluster json: {e}"))?;
        ClusterConfig::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_finite_rate() {
        let mut c = ClusterConfig::fleet_default();
        c.arrival_rps = 1500.0;
        c.mode = ShardMode::Pipelined;
        c.boards = 7;
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_roundtrip_burst_and_no_contention() {
        let mut c = ClusterConfig::fleet_default();
        c.aggregate_ddr_bytes_per_cycle = None; // contention disabled
        assert!(c.arrival_rps.is_infinite());
        let s = c.to_json().to_string_compact();
        assert!(!s.contains("arrival_rps"), "burst is encoded by omission");
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn json_roundtrip_hetero_steps_reshard() {
        let mut c = ClusterConfig::fleet_default();
        c.boards = 3;
        c.board_specs = vec![
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        c.arrival_rps = 400.0;
        c.load_steps = vec![
            LoadStep {
                at_request: 64,
                rps: 900.0,
            },
            LoadStep {
                at_request: 128,
                rps: f64::INFINITY,
            },
        ];
        c.reshard = Some(ReshardPolicy::default_policy());
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn board_configs_expand_in_rack_order() {
        let base = AccelConfig::paper_default();
        let mut c = ClusterConfig::fleet_default();
        c.boards = 3;
        c.board_specs = vec![
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        let fleet = c.board_configs(&base);
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet[0].platform.freq_mhz, 120.0);
        assert_eq!(fleet[1].platform.freq_mhz, 100.0);
        assert_eq!(fleet[2].platform.freq_mhz, 100.0);
        // Design knobs come from the base config.
        assert_eq!(fleet[2].max_depth_parallel, base.max_depth_parallel);

        // Homogeneous fallback.
        let c2 = ClusterConfig::fleet_default();
        let fleet2 = c2.board_configs(&base);
        assert_eq!(fleet2.len(), 4);
        assert!(fleet2.iter().all(|f| *f == base));
    }

    #[test]
    fn with_boards_resizes_heterogeneous_fleets_validly() {
        let mut c = ClusterConfig::fleet_default();
        c.boards = 4;
        c.board_specs = vec![
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        c.validate().unwrap();
        for boards in 1..=8 {
            let s = c.with_boards(boards);
            assert_eq!(s.boards, boards);
            s.validate()
                .unwrap_or_else(|e| panic!("with_boards({boards}): {e}"));
            let total: usize = s.board_specs.iter().map(|b| b.count).sum();
            assert_eq!(total, boards);
        }
        // Truncation keeps rack order: 1 board → the first (fast) spec.
        assert_eq!(c.with_boards(1).board_specs[0].platform.freq_mhz, 120.0);
        // Growth extends the last generation.
        let grown = c.with_boards(6);
        assert_eq!(grown.board_specs.last().unwrap().count, 4);
        // Homogeneous configs just change the count.
        let homo = ClusterConfig::fleet_default().with_boards(9);
        assert_eq!(homo.boards, 9);
        assert!(homo.board_specs.is_empty());
    }

    #[test]
    fn rejects_invalid() {
        for (field, bad) in [
            ("boards", r#"{"boards":0,"mode":"replicated"}"#),
            ("mode", r#"{"boards":2,"mode":"sideways"}"#),
            ("requests", r#"{"boards":2,"mode":"replicated","requests":0}"#),
            ("batch", r#"{"boards":2,"mode":"replicated","max_batch":0}"#),
            (
                "aggregate",
                r#"{"boards":2,"mode":"replicated","aggregate_ddr_bytes_per_cycle":0}"#,
            ),
            ("rate", r#"{"boards":2,"mode":"replicated","arrival_rps":-5}"#),
            (
                "spec count sum",
                r#"{"boards":3,"mode":"replicated","board_specs":[
                    {"count":1,"platform":{"name":"a","dsp":10,"bram36":10,"lut":10,
                     "ff":10,"freq_mhz":100.0,"ddr_bytes_per_cycle":8.0,"word_bytes":4}}]}"#,
            ),
            (
                "step order",
                r#"{"boards":2,"mode":"replicated","arrival_rps":100,
                    "load_steps":[{"at_request":50,"rps":200},{"at_request":20,"rps":300}]}"#,
            ),
            (
                "reshard window",
                r#"{"boards":2,"mode":"replicated","reshard":{"window":0}}"#,
            ),
        ] {
            assert!(
                ClusterConfig::from_json_str(bad).is_err(),
                "{field} should be rejected"
            );
        }
    }

    #[test]
    fn rejects_mixed_word_sizes() {
        let mut small = Platform::virtex7_at_100mhz();
        small.word_bytes = 2;
        let mut c = ClusterConfig::fleet_default();
        c.boards = 2;
        c.board_specs = vec![
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 1,
                platform: small,
            },
        ];
        assert!(c.validate().is_err());
    }

    fn two_tenants() -> Vec<TenantSpec> {
        use crate::config::network::{tiny_vgg, vgg16_prefix};
        vec![
            TenantSpec {
                name: "interactive".to_string(),
                network: vgg16_prefix(),
                weights_seed: 1,
                arrival_rps: 40.0,
                requests: 64,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 80.0,
                    priority: 2,
                    weight: 1.0,
                    overload: None,
                },
            },
            TenantSpec {
                name: "batch".to_string(),
                network: tiny_vgg(),
                weights_seed: 2,
                arrival_rps: f64::INFINITY,
                requests: 128,
                load_steps: vec![LoadStep {
                    at_request: 32,
                    rps: 500.0,
                }],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 5000.0,
                    priority: 0,
                    weight: 1.0,
                    overload: None,
                },
            },
        ]
    }

    #[test]
    fn json_roundtrip_preempt_mode_and_weight() {
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.preempt_mode = PreemptMode::Resume;
        c.preempt_refill_cycles = 75;
        c.tenants[0].slo.weight = 2.5;
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
        assert_eq!(back.preempt_mode, PreemptMode::Resume);
        assert_eq!(back.preempt_refill_cycles, 75);
        assert_eq!(back.tenants[0].slo.weight, 2.5);
        // Unknown mode names are rejected.
        assert!(PreemptMode::from_name("rewind").is_err());
        assert_eq!(PreemptMode::from_name("resume"), Ok(PreemptMode::Resume));
        assert_eq!(PreemptMode::Restart.as_str(), "restart");
    }

    #[test]
    fn slo_weight_defaults_to_one_and_rejects_nonpositive() {
        use crate::util::json::parse;
        // Absent → equal share; this is what keeps pre-weight tenant JSON
        // parsing (and the committed fixtures' scenarios) unchanged.
        let s = SloPolicy::from_json(&parse(r#"{"p99_ms": 5.0}"#).unwrap()).unwrap();
        assert_eq!(s.weight, 1.0);
        s.validate().unwrap();
        for w in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let bad = SloPolicy {
                p99_ms: 5.0,
                priority: 1,
                weight: w,
                overload: None,
            };
            assert!(bad.validate().is_err(), "weight {w} must be rejected");
        }
    }

    #[test]
    fn validators_reject_nonfinite_thresholds() {
        // Regression: every f64 that feeds a `* factor → u64 cycle cast` or
        // a latency comparison must be finite. Pre-hardening an INFINITY
        // migration_factor validated fine and then saturated the migration
        // bill mid-run; NaN thresholds disarmed triggers silently.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut r = ReshardPolicy::default_policy();
            r.migration_factor = bad;
            assert!(r.validate().is_err(), "migration_factor {bad}");
            let mut r = ReshardPolicy::default_policy();
            r.p99_ms = bad;
            assert!(r.validate().is_err(), "reshard p99_ms {bad}");
            let mut r = ReshardPolicy::default_policy();
            r.util_skew = bad;
            assert!(r.validate().is_err(), "util_skew {bad}");

            let slo = SloPolicy {
                p99_ms: bad,
                priority: 1,
                weight: 1.0,
                overload: None,
            };
            assert!(slo.validate().is_err(), "slo p99_ms {bad}");

            let o = OverloadPolicy {
                deadline_ms: bad,
                max_queue: 8,
                retry: RetryPolicy::default_policy(),
            };
            assert!(o.validate().is_err(), "deadline_ms {bad}");

            let mut c = ClusterConfig::fleet_default();
            c.max_wait_us = bad;
            assert!(c.validate().is_err(), "max_wait_us {bad}");
        }
        // The finite defaults all still pass.
        ReshardPolicy::default_policy().validate().unwrap();
        ClusterConfig::fleet_default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_tenants() {
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[0].replicas = Some(2);
        c.preempt_restart_cycles = 1234;
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
        // Burst is encoded by omission on the tenant too, and so is an
        // uncapped replica count.
        assert!(back.tenants[1].arrival_rps.is_infinite());
        assert_eq!(back.tenants[0].replicas, Some(2));
        assert_eq!(back.tenants[1].replicas, None);
        assert_eq!(back.tenants[0].slo.priority, 2);

        // replicas: 0 is rejected.
        let mut bad = ClusterConfig::fleet_default();
        bad.tenants = two_tenants();
        bad.tenants[0].replicas = Some(0);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn tenant_validation_rejects_bad_specs() {
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[1].name = "interactive".to_string(); // duplicate
        assert!(c.validate().unwrap_err().contains("duplicate tenant"));

        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[0].requests = 0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[0].slo.p99_ms = 0.0;
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[0].name = String::new();
        assert!(c.validate().is_err());

        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[1].load_steps = vec![
            LoadStep {
                at_request: 40,
                rps: 10.0,
            },
            LoadStep {
                at_request: 20,
                rps: 20.0,
            },
        ];
        assert!(c.validate().is_err());
    }

    #[test]
    fn slo_priority_malformed_is_an_error_not_a_demotion() {
        use crate::util::json::parse;
        // Absent → lowest class.
        let s = SloPolicy::from_json(&parse(r#"{"p99_ms": 5.0}"#).unwrap()).unwrap();
        assert_eq!(s.priority, 0);
        // Present but malformed → error (a silent priority-0 demotion would
        // invert the preemption story without a diagnostic).
        for bad in [
            r#"{"p99_ms": 5.0, "priority": "2"}"#,
            r#"{"p99_ms": 5.0, "priority": 2.5}"#,
            r#"{"p99_ms": 5.0, "priority": 300}"#,
            r#"{"p99_ms": 5.0, "priority": -1}"#,
        ] {
            assert!(
                SloPolicy::from_json(&parse(bad).unwrap()).is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn tenant_spec_parses_minimal_json() {
        // Only name/network/requests/slo are required; everything else
        // defaults (burst arrivals, replicated, seed 1).
        let s = r#"{
            "name": "t0",
            "requests": 16,
            "slo": {"p99_ms": 100.0, "priority": 1},
            "network": {
                "name": "n", "input": {"h": 8, "w": 8, "d": 3},
                "layers": [{"type": "conv", "name": "c1", "kernel": 3,
                            "filters": 4, "stride": 1, "padding": 1}]
            }
        }"#;
        let t = TenantSpec::from_json(&crate::util::json::parse(s).unwrap()).unwrap();
        assert_eq!(t.name, "t0");
        assert!(t.arrival_rps.is_infinite());
        assert_eq!(t.mode, ShardMode::Replicated);
        assert_eq!(t.weights_seed, 1);
        assert_eq!(t.slo.priority, 1);
    }

    #[test]
    fn defaults_fill_optional_fields() {
        let c = ClusterConfig::from_json_str(r#"{"boards":3,"mode":"pipelined"}"#).unwrap();
        assert_eq!(c.boards, 3);
        assert_eq!(c.mode, ShardMode::Pipelined);
        assert!(c.arrival_rps.is_infinite());
        assert_eq!(c.max_batch, ClusterConfig::fleet_default().max_batch);
        assert!(c.board_specs.is_empty());
        assert!(c.load_steps.is_empty());
        assert!(c.reshard.is_none());
        assert!(c.tenants.is_empty());
        assert_eq!(
            c.preempt_restart_cycles,
            ClusterConfig::fleet_default().preempt_restart_cycles
        );
        // The new knobs default to the fixture-continuity values: restart
        // semantics, modest refill.
        assert_eq!(c.preempt_mode, PreemptMode::Restart);
        assert_eq!(
            c.preempt_refill_cycles,
            ClusterConfig::fleet_default().preempt_refill_cycles
        );
        // Faults are strictly opt-in: absent key parses to None and the
        // serialized form has no "faults" key.
        assert!(c.faults.is_none());
        assert!(!c.to_json().to_string_compact().contains("faults"));
    }

    fn demo_script() -> FaultScript {
        FaultScript {
            events: vec![
                FaultEvent::LinkDegrade {
                    link: 0,
                    factor: 0.25,
                    at_ms: 0.5,
                    until_ms: 0.8,
                },
                FaultEvent::ClockDerate {
                    board: 1,
                    factor: 0.5,
                    at_ms: 1.0,
                },
                FaultEvent::BoardDown {
                    board: 2,
                    at_ms: 2.0,
                    recover_ms: Some(5.0),
                },
                FaultEvent::ClockDerate {
                    board: 1,
                    factor: 1.0,
                    at_ms: 3.0,
                },
                FaultEvent::BoardDown {
                    board: 0,
                    at_ms: 9.0,
                    recover_ms: None,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_fault_script() {
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.faults = Some(demo_script());
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
        // A bare array is the CLI --faults form.
        let arr = match demo_script().to_json().get("events") {
            Json::Null => panic!("script serializes an 'events' array"),
            v => v.to_string_pretty(),
        };
        assert_eq!(FaultScript::from_json_str(&arr).unwrap(), demo_script());
    }

    #[test]
    fn fault_script_validation() {
        // Valid against a big-enough fleet.
        demo_script().validate().unwrap();

        // Out-of-order events.
        let bad = FaultScript {
            events: vec![
                FaultEvent::ClockDerate {
                    board: 0,
                    factor: 0.5,
                    at_ms: 2.0,
                },
                FaultEvent::ClockDerate {
                    board: 0,
                    factor: 1.0,
                    at_ms: 1.0,
                },
            ],
        };
        assert!(bad.validate().unwrap_err().contains("ordered"));

        // Bad factors / windows / recovery instants.
        for (name, ev) in [
            (
                "zero factor",
                FaultEvent::ClockDerate {
                    board: 0,
                    factor: 0.0,
                    at_ms: 1.0,
                },
            ),
            (
                "factor above 1",
                FaultEvent::LinkDegrade {
                    link: 0,
                    factor: 1.5,
                    at_ms: 1.0,
                    until_ms: 2.0,
                },
            ),
            (
                "empty degrade window",
                FaultEvent::LinkDegrade {
                    link: 0,
                    factor: 0.5,
                    at_ms: 2.0,
                    until_ms: 2.0,
                },
            ),
            (
                "recover before failure",
                FaultEvent::BoardDown {
                    board: 0,
                    at_ms: 2.0,
                    recover_ms: Some(1.0),
                },
            ),
            (
                "negative at_ms",
                FaultEvent::ClockDerate {
                    board: 0,
                    factor: 0.5,
                    at_ms: -1.0,
                },
            ),
        ] {
            let s = FaultScript { events: vec![ev] };
            assert!(s.validate().is_err(), "{name} must be rejected");
        }
        assert!(FaultScript { events: vec![] }.validate().is_err());

        // Fleet-level checks: indices in range, tenants required.
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.faults = Some(FaultScript {
            events: vec![FaultEvent::BoardDown {
                board: 4,
                at_ms: 1.0,
                recover_ms: None,
            }],
        });
        assert!(c.validate().unwrap_err().contains("out of range"));

        let mut c = ClusterConfig::fleet_default();
        c.faults = Some(demo_script());
        assert!(c.validate().unwrap_err().contains("tenants"));

        // Unknown kind rejected at parse time.
        assert!(FaultScript::from_json_str(
            r#"{"events":[{"kind":"gamma_ray","at_ms":1.0}]}"#
        )
        .is_err());
    }

    #[test]
    fn json_roundtrip_overload_policy() {
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[1].slo.overload = Some(OverloadPolicy {
            deadline_ms: 2.0,
            max_queue: 32,
            retry: RetryPolicy {
                max_attempts: 4,
                backoff_base_ms: 0.5,
                jitter: 0.25,
            },
        });
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
        // Absence is encoded by key omission: the no-overload tenant's
        // serialized slo has no "overload" key (fixture byte-identity
        // leans on this).
        let t0 = c.tenants[0].to_json().to_string_compact();
        assert!(!t0.contains("overload"));
        // Retry block omitted → defaults.
        let o = OverloadPolicy::from_json(
            &parse(r#"{"deadline_ms": 1.0, "max_queue": 8}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(o.retry, RetryPolicy::default_policy());
        o.validate().unwrap();
    }

    #[test]
    fn overload_policy_validation() {
        let good = OverloadPolicy {
            deadline_ms: 1.0,
            max_queue: 8,
            retry: RetryPolicy::default_policy(),
        };
        good.validate().unwrap();
        // max_attempts: 0 is legal — shed once, abandon immediately.
        let mut once = good.clone();
        once.retry.max_attempts = 0;
        once.validate().unwrap();

        let mut bad = good.clone();
        bad.deadline_ms = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.max_queue = 0;
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.retry.backoff_base_ms = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.retry.jitter = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = good;
        bad.retry.jitter = -0.1;
        assert!(bad.validate().is_err());

        // An invalid nested policy fails the whole tenant validation.
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.tenants[0].slo.overload = Some(OverloadPolicy {
            deadline_ms: -1.0,
            max_queue: 8,
            retry: RetryPolicy::default_policy(),
        });
        assert!(c.validate().unwrap_err().contains("deadline_ms"));
    }

    #[test]
    fn json_roundtrip_compute_degrade() {
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.faults = Some(FaultScript {
            events: vec![
                FaultEvent::ComputeDegrade {
                    board: 1,
                    capacity_fraction: 0.4,
                    at_ms: 1.0,
                    recover_ms: Some(4.0),
                },
                FaultEvent::ComputeDegrade {
                    board: 2,
                    capacity_fraction: 0.75,
                    at_ms: 2.0,
                    recover_ms: None,
                },
            ],
        });
        c.validate().unwrap();
        let s = c.to_json().to_string_pretty();
        let back = ClusterConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
        // Permanent brownout serializes with no recover_ms key.
        let ev = c.faults.as_ref().unwrap().events[1].to_json();
        assert!(!ev.to_string_compact().contains("recover_ms"));
    }

    #[test]
    fn compute_degrade_validation() {
        for (name, frac, recover) in [
            ("zero fraction", 0.0, None),
            ("fraction above 1", 1.5, None),
            ("recover before onset", 0.5, Some(0.5)),
        ] {
            let s = FaultScript {
                events: vec![FaultEvent::ComputeDegrade {
                    board: 0,
                    capacity_fraction: frac,
                    at_ms: 1.0,
                    recover_ms: recover,
                }],
            };
            assert!(s.validate().is_err(), "{name} must be rejected");
        }
        // Index check covers the new kind too.
        let mut c = ClusterConfig::fleet_default();
        c.tenants = two_tenants();
        c.faults = Some(FaultScript {
            events: vec![FaultEvent::ComputeDegrade {
                board: 9,
                capacity_fraction: 0.5,
                at_ms: 1.0,
                recover_ms: None,
            }],
        });
        assert!(c.validate().unwrap_err().contains("out of range"));
    }

    #[test]
    fn single_network_faults_allow_board_down_and_derate_only() {
        // The ROADMAP follow-up: board_down + clock_derate scripts are now
        // legal without tenants (the single-network simulators inject
        // them)…
        let mut c = ClusterConfig::fleet_default();
        c.faults = Some(FaultScript {
            events: vec![
                FaultEvent::ClockDerate {
                    board: 0,
                    factor: 0.5,
                    at_ms: 0.5,
                },
                FaultEvent::BoardDown {
                    board: 1,
                    at_ms: 1.0,
                    recover_ms: Some(3.0),
                },
            ],
        });
        c.validate().unwrap();
        // …while link_degrade and compute_degrade still require tenants.
        for ev in [
            FaultEvent::LinkDegrade {
                link: 0,
                factor: 0.5,
                at_ms: 1.0,
                until_ms: 2.0,
            },
            FaultEvent::ComputeDegrade {
                board: 0,
                capacity_fraction: 0.5,
                at_ms: 1.0,
                recover_ms: None,
            },
        ] {
            let mut c = ClusterConfig::fleet_default();
            c.faults = Some(FaultScript { events: vec![ev] });
            assert!(c.validate().unwrap_err().contains("tenants"));
        }
    }
}
