//! Accelerator platform configuration: the Virtex-7 XC7V690T budget the paper
//! targets, clocking, DDR bandwidth, and the knobs of the DeCoILFNet design
//! (depth-group parallelism, fusion plan constraints).

use crate::util::json::{parse, Json};

/// FPGA platform resource budget + clocking.
///
/// Defaults are the paper's board: Virtex-7 XC7V690T — 3600 DSP48 slices,
/// 1470 BRAM36 (the paper's Table I counts 1470 available; §IV quotes the
/// 6.46 MB on-chip total), 433,200 LUTs, 866,400 flip-flops, at 120 MHz.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub name: String,
    pub dsp: usize,
    /// BRAM36 blocks (each 36 Kb; a BRAM18 is half).
    pub bram36: usize,
    pub lut: usize,
    pub ff: usize,
    pub freq_mhz: f64,
    /// Effective off-chip DDR bandwidth in bytes/cycle. Virtex-7 boards
    /// carry a 64-bit DDR3-1600 channel (12.8 GB/s peak); at ~60% controller
    /// efficiency that is ≈ 64 B/cycle at 120 MHz. The paper's "bandwidth
    /// constrained setup" refers to traffic *volume* (its Table IV metric),
    /// not to starving the pipeline — with this bandwidth the fused pipeline
    /// is compute-bound, as the paper requires.
    pub ddr_bytes_per_cycle: f64,
    /// Datapath word size in bytes (32-bit fixed → 4).
    pub word_bytes: usize,
}

impl Platform {
    pub fn virtex7_xc7v690t() -> Platform {
        Platform {
            name: "Virtex-7 XC7V690T".to_string(),
            dsp: 3600,
            bram36: 1470,
            lut: 433_200,
            ff: 866_400,
            freq_mhz: 120.0,
            ddr_bytes_per_cycle: 64.0,
            word_bytes: 4,
        }
    }

    /// The baselines [2][3] ran the same board at 100 MHz.
    pub fn virtex7_at_100mhz() -> Platform {
        Platform {
            freq_mhz: 100.0,
            ..Platform::virtex7_xc7v690t()
        }
    }

    /// An older board generation for heterogeneous-fleet studies: same
    /// fabric resources, half the clock, half the off-chip draw (earlier
    /// DDR controller). The canonical "slow gen" used by the cluster
    /// benches, tests and demos — keep them on one definition so the
    /// scenario numbers can't drift apart.
    pub fn virtex7_older_gen() -> Platform {
        Platform {
            name: "Virtex-7 (older gen)".to_string(),
            freq_mhz: 60.0,
            ddr_bytes_per_cycle: 32.0,
            ..Platform::virtex7_xc7v690t()
        }
    }

    /// Cycles → milliseconds at this platform's clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e3)
    }

    /// On-chip BRAM capacity in bytes.
    pub fn bram_bytes(&self) -> usize {
        self.bram36 * 36 * 1024 / 8
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("dsp", self.dsp)
            .set("bram36", self.bram36)
            .set("lut", self.lut)
            .set("ff", self.ff)
            .set("freq_mhz", self.freq_mhz)
            .set("ddr_bytes_per_cycle", self.ddr_bytes_per_cycle)
            .set("word_bytes", self.word_bytes)
    }

    pub fn from_json(j: &Json) -> Option<Platform> {
        Some(Platform {
            name: j.get("name").as_str()?.to_string(),
            dsp: j.get("dsp").as_usize()?,
            bram36: j.get("bram36").as_usize()?,
            lut: j.get("lut").as_usize()?,
            ff: j.get("ff").as_usize()?,
            freq_mhz: j.get("freq_mhz").as_f64()?,
            ddr_bytes_per_cycle: j.get("ddr_bytes_per_cycle").as_f64()?,
            word_bytes: j.get("word_bytes").as_usize()?,
        })
    }
}

/// DeCoILFNet design knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    pub platform: Platform,
    /// Maximum depth processed in parallel per layer (d_g). Depths beyond
    /// this use iterative decomposition (serial depth groups, §V).
    pub max_depth_parallel: usize,
    /// Multiplier pipeline depth — the paper's DSP multiplier latency.
    pub mult_latency: usize,
    /// If false, the whole network runs layer-by-layer through DDR (point A
    /// of Fig 7); fusion planning is skipped.
    pub fusion_enabled: bool,
}

impl AccelConfig {
    /// Paper configuration: Virtex-7 at 120 MHz, d_g capped at 64 (the paper
    /// fuses the 7-layer VGG prefix whose depths reach 128 input channels and
    /// iterates in groups for deeper layers), 9-stage multipliers.
    pub fn paper_default() -> AccelConfig {
        AccelConfig {
            platform: Platform::virtex7_xc7v690t(),
            max_depth_parallel: 64,
            mult_latency: 9,
            fusion_enabled: true,
        }
    }

    /// Small config for unit tests (matches the paper's §III test example:
    /// depth 3 fully parallel).
    pub fn test_example() -> AccelConfig {
        AccelConfig {
            platform: Platform::virtex7_xc7v690t(),
            max_depth_parallel: 8,
            mult_latency: 9,
            fusion_enabled: true,
        }
    }

    /// Depth-group parallelism for a layer of input depth `d`: min(d, cap).
    pub fn depth_parallel(&self, d: usize) -> usize {
        self.max_depth_parallel.min(d).max(1)
    }

    /// Number of serial depth groups for input depth `d` (§V iterative
    /// decomposition).
    pub fn depth_groups(&self, d: usize) -> usize {
        d.div_ceil(self.depth_parallel(d))
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("platform", self.platform.to_json())
            .set("max_depth_parallel", self.max_depth_parallel)
            .set("mult_latency", self.mult_latency)
            .set("fusion_enabled", self.fusion_enabled)
    }

    pub fn from_json(j: &Json) -> Option<AccelConfig> {
        Some(AccelConfig {
            platform: Platform::from_json(j.get("platform"))?,
            max_depth_parallel: j.get("max_depth_parallel").as_usize()?,
            mult_latency: j.get("mult_latency").as_usize()?,
            fusion_enabled: j.get("fusion_enabled").as_bool()?,
        })
    }

    pub fn from_json_str(s: &str) -> Option<AccelConfig> {
        AccelConfig::from_json(&parse(s).ok()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtex7_budget_matches_paper_table1() {
        let p = Platform::virtex7_xc7v690t();
        assert_eq!(p.dsp, 3600);
        assert_eq!(p.bram36, 1470);
        assert_eq!(p.lut, 433_200);
        assert_eq!(p.ff, 866_400);
        assert_eq!(p.freq_mhz, 120.0);
    }

    #[test]
    fn bram_capacity_near_paper_quote() {
        // Paper quotes 6.46 MB on-chip BRAM for the XC7V690T.
        let mb = Platform::virtex7_xc7v690t().bram_bytes() as f64 / (1024.0 * 1024.0);
        assert!((mb - 6.46).abs() < 0.2, "got {mb} MB");
    }

    #[test]
    fn cycles_to_ms() {
        let p = Platform::virtex7_xc7v690t();
        // Paper: 5034k cycles at 120 MHz = 41.95 ms (Table IV ↔ Table II).
        let ms = p.cycles_to_ms(5_034_000);
        assert!((ms - 41.95).abs() < 0.01, "got {ms}");
    }

    #[test]
    fn depth_grouping() {
        let c = AccelConfig::paper_default();
        assert_eq!(c.depth_parallel(3), 3);
        assert_eq!(c.depth_groups(3), 1);
        assert_eq!(c.depth_parallel(64), 64);
        assert_eq!(c.depth_groups(64), 1);
        assert_eq!(c.depth_parallel(128), 64);
        assert_eq!(c.depth_groups(128), 2);
        assert_eq!(c.depth_groups(256), 4);
        assert_eq!(c.depth_groups(512), 8);
    }

    #[test]
    fn json_roundtrip() {
        let c = AccelConfig::paper_default();
        let s = c.to_json().to_string_pretty();
        let back = AccelConfig::from_json_str(&s).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn baseline_platform_clock() {
        assert_eq!(Platform::virtex7_at_100mhz().freq_mhz, 100.0);
    }
}
