//! Dense tensors and fixed-point arithmetic.
//!
//! `NdTensor` is a minimal row-major f32 tensor sized for this repo's needs
//! (feature maps, filter banks, reference convolutions). The accelerator
//! simulator uses [`fixed::Fx`] Q16.16 values internally; conversion helpers
//! live here.

pub mod fixed;

use self::fixed::Fx;

/// Row-major dense f32 tensor with runtime shape.
///
/// Layout convention across the repo (matches the paper's streaming order and
/// the JAX side's NHWC): feature maps are `[h, w, c]`, filter banks are
/// `[k, kh, kw, c]`.
#[derive(Debug, Clone, PartialEq)]
pub struct NdTensor {
    shape: Vec<usize>,
    strides: Vec<usize>,
    data: Vec<f32>,
}

impl NdTensor {
    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> NdTensor {
        let n: usize = shape.iter().product();
        NdTensor {
            shape: shape.to_vec(),
            strides: row_major_strides(shape),
            data: vec![0.0; n],
        }
    }

    /// Build from existing data; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> NdTensor {
        let n: usize = shape.iter().product();
        assert_eq!(
            data.len(),
            n,
            "data length {} != shape product {} for {:?}",
            data.len(),
            n,
            shape
        );
        NdTensor {
            shape: shape.to_vec(),
            strides: row_major_strides(shape),
            data,
        }
    }

    /// Deterministic pseudo-random tensor in `[lo, hi)`.
    pub fn random(shape: &[usize], seed: u64, lo: f32, hi: f32) -> NdTensor {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut t = NdTensor::zeros(shape);
        rng.fill_f32(&mut t.data, lo, hi);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    #[inline]
    fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len(), "index rank mismatch");
        let mut off = 0;
        for (i, (&ix, &st)) in idx.iter().zip(&self.strides).enumerate() {
            debug_assert!(
                ix < self.shape[i],
                "index {ix} out of bounds for dim {i} of extent {}",
                self.shape[i]
            );
            off += ix * st;
        }
        off
    }

    #[inline]
    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.offset(idx);
        self.data[off] = v;
    }

    /// 3-D accessor `[h, w, c]` — the hot path for feature maps; avoids the
    /// slice-building overhead of `get`.
    #[inline]
    pub fn at3(&self, y: usize, x: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 3);
        self.data[y * self.strides[0] + x * self.strides[1] + c]
    }

    #[inline]
    pub fn set3(&mut self, y: usize, x: usize, c: usize, v: f32) {
        debug_assert_eq!(self.rank(), 3);
        let off = y * self.strides[0] + x * self.strides[1] + c;
        self.data[off] = v;
    }

    /// 4-D accessor `[k, kh, kw, c]` for filter banks.
    #[inline]
    pub fn at4(&self, k: usize, y: usize, x: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        self.data[k * self.strides[0] + y * self.strides[1] + x * self.strides[2] + c]
    }

    /// Reshape (same element count).
    pub fn reshape(&self, shape: &[usize]) -> NdTensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape element count mismatch");
        NdTensor::from_vec(shape, self.data.clone())
    }

    /// Elementwise maximum absolute difference vs another tensor.
    pub fn max_abs_diff(&self, other: &NdTensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch in max_abs_diff");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Mean absolute value (used for relative-error reporting).
    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|v| v.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// Quantize every element to Q16.16.
    pub fn to_fixed(&self) -> FxTensor {
        FxTensor {
            shape: self.shape.clone(),
            strides: self.strides.clone(),
            data: self.data.iter().map(|&v| Fx::from_f32(v)).collect(),
        }
    }
}

/// Fixed-point tensor — what actually flows through the simulated datapath.
#[derive(Debug, Clone, PartialEq)]
pub struct FxTensor {
    shape: Vec<usize>,
    strides: Vec<usize>,
    data: Vec<Fx>,
}

impl FxTensor {
    pub fn zeros(shape: &[usize]) -> FxTensor {
        let n: usize = shape.iter().product();
        FxTensor {
            shape: shape.to_vec(),
            strides: row_major_strides(shape),
            data: vec![Fx::ZERO; n],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[Fx] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [Fx] {
        &mut self.data
    }

    #[inline]
    pub fn at3(&self, y: usize, x: usize, c: usize) -> Fx {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[y * self.strides[0] + x * self.strides[1] + c]
    }

    #[inline]
    pub fn set3(&mut self, y: usize, x: usize, c: usize, v: Fx) {
        debug_assert_eq!(self.shape.len(), 3);
        let off = y * self.strides[0] + x * self.strides[1] + c;
        self.data[off] = v;
    }

    #[inline]
    pub fn at4(&self, k: usize, y: usize, x: usize, c: usize) -> Fx {
        debug_assert_eq!(self.shape.len(), 4);
        self.data[k * self.strides[0] + y * self.strides[1] + x * self.strides[2] + c]
    }

    /// Row-major slice of channel values at (y, x) — the depth-concatenated
    /// "wide word" of the paper, contiguous by construction.
    #[inline]
    pub fn pixel(&self, y: usize, x: usize) -> &[Fx] {
        debug_assert_eq!(self.shape.len(), 3);
        let c = self.shape[2];
        let off = y * self.strides[0] + x * self.strides[1];
        &self.data[off..off + c]
    }

    pub fn to_f32(&self) -> NdTensor {
        NdTensor {
            shape: self.shape.clone(),
            strides: self.strides.clone(),
            data: self.data.iter().map(|v| v.to_f32()).collect(),
        }
    }
}

fn row_major_strides(shape: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        assert_eq!(row_major_strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(row_major_strides(&[5]), vec![1]);
        assert_eq!(row_major_strides(&[]), Vec::<usize>::new());
    }

    #[test]
    fn get_set_roundtrip() {
        let mut t = NdTensor::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.5);
        assert_eq!(t.get(&[1, 2, 3]), 7.5);
        assert_eq!(t.at3(1, 2, 3), 7.5);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
    }

    #[test]
    fn at3_matches_get_everywhere() {
        let t = NdTensor::random(&[4, 5, 3], 1, -1.0, 1.0);
        for y in 0..4 {
            for x in 0..5 {
                for c in 0..3 {
                    assert_eq!(t.get(&[y, x, c]), t.at3(y, x, c));
                }
            }
        }
    }

    #[test]
    fn at4_matches_layout() {
        let data: Vec<f32> = (0..2 * 3 * 3 * 2).map(|i| i as f32).collect();
        let t = NdTensor::from_vec(&[2, 3, 3, 2], data);
        assert_eq!(t.at4(0, 0, 0, 0), 0.0);
        assert_eq!(t.at4(0, 0, 0, 1), 1.0);
        assert_eq!(t.at4(1, 0, 0, 0), 18.0);
        assert_eq!(t.at4(1, 2, 2, 1), 35.0);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn from_vec_length_mismatch_panics() {
        NdTensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let a = NdTensor::random(&[10, 10, 3], 42, -2.0, 2.0);
        let b = NdTensor::random(&[10, 10, 3], 42, -2.0, 2.0);
        assert_eq!(a, b);
        assert!(a.data().iter().all(|&v| (-2.0..2.0).contains(&v)));
        let c = NdTensor::random(&[10, 10, 3], 43, -2.0, 2.0);
        assert_ne!(a, c);
    }

    #[test]
    fn fixed_roundtrip_error_bounded() {
        let t = NdTensor::random(&[6, 6, 4], 7, -10.0, 10.0);
        let back = t.to_fixed().to_f32();
        assert!(t.max_abs_diff(&back) <= 0.5 * fixed::Fx::epsilon() as f32 + 1e-9);
    }

    #[test]
    fn pixel_is_depth_contiguous() {
        let mut t = FxTensor::zeros(&[2, 2, 3]);
        t.set3(1, 0, 0, Fx::from_f32(1.0));
        t.set3(1, 0, 1, Fx::from_f32(2.0));
        t.set3(1, 0, 2, Fx::from_f32(3.0));
        let px = t.pixel(1, 0);
        assert_eq!(
            px.iter().map(|v| v.to_f32()).collect::<Vec<_>>(),
            vec![1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn reshape_preserves_data() {
        let t = NdTensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let r = t.reshape(&[3, 2]);
        assert_eq!(r.get(&[0, 1]), 2.0);
        assert_eq!(r.get(&[2, 1]), 6.0);
    }

    #[test]
    fn diff_metrics() {
        let a = NdTensor::from_vec(&[3], vec![1.0, -2.0, 3.0]);
        let b = NdTensor::from_vec(&[3], vec![1.5, -2.0, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
        assert!((a.mean_abs() - 2.0).abs() < 1e-6);
    }
}
