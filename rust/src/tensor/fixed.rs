//! Q16.16 fixed-point arithmetic — the accelerator datapath number format.
//!
//! Table IV of the paper lists DeCoILFNet's precision as "32 bits fixed"
//! (vs 32-bit float for the two baseline accelerators). We model that with a
//! signed Q16.16: 1 sign + 15 integer + 16 fraction bits, saturating on
//! overflow the way a hardened DSP datapath would be configured.
//!
//! Multiplication uses the full 64-bit product then a round-to-nearest shift,
//! matching a DSP48E1 multiplier (25×18 cascades produce the full product;
//! the accumulator keeps guard bits; the final output is re-quantized).

/// Number of fraction bits.
pub const FRAC_BITS: u32 = 16;
/// Fixed-point scale factor (2^16).
pub const SCALE: i64 = 1 << FRAC_BITS;

/// A Q16.16 signed fixed-point value stored in 32 bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Fx(pub i32);

impl Fx {
    pub const ZERO: Fx = Fx(0);
    pub const ONE: Fx = Fx(SCALE as i32);
    pub const MAX: Fx = Fx(i32::MAX);
    pub const MIN: Fx = Fx(i32::MIN);

    /// Quantize an f32 (round to nearest, saturate).
    pub fn from_f32(v: f32) -> Fx {
        let scaled = (v as f64) * SCALE as f64;
        let r = scaled.round();
        if r >= i32::MAX as f64 {
            Fx::MAX
        } else if r <= i32::MIN as f64 {
            Fx::MIN
        } else {
            Fx(r as i32)
        }
    }

    pub fn to_f32(self) -> f32 {
        self.0 as f32 / SCALE as f32
    }

    pub fn to_f64(self) -> f64 {
        self.0 as f64 / SCALE as f64
    }

    /// Saturating addition (datapath adders saturate rather than wrap).
    pub fn add(self, rhs: Fx) -> Fx {
        Fx(self.0.saturating_add(rhs.0))
    }

    pub fn sub(self, rhs: Fx) -> Fx {
        Fx(self.0.saturating_sub(rhs.0))
    }

    /// Saturating multiply with round-to-nearest requantization.
    pub fn mul(self, rhs: Fx) -> Fx {
        let full = self.0 as i64 * rhs.0 as i64; // Q32.32 in 64 bits, exact
        let rounded = (full + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
        saturate_i64(rounded)
    }

    /// ReLU — trivially free in the datapath (sign-bit mux), as the paper notes.
    pub fn relu(self) -> Fx {
        if self.0 < 0 {
            Fx::ZERO
        } else {
            self
        }
    }

    pub fn max(self, rhs: Fx) -> Fx {
        if self.0 >= rhs.0 {
            self
        } else {
            rhs
        }
    }

    /// Absolute quantization step of this format.
    pub fn epsilon() -> f64 {
        1.0 / SCALE as f64
    }
}

fn saturate_i64(v: i64) -> Fx {
    if v > i32::MAX as i64 {
        Fx::MAX
    } else if v < i32::MIN as i64 {
        Fx::MIN
    } else {
        Fx(v as i32)
    }
}

/// A widened multiply-accumulate register: DSP accumulators keep the full
/// Q32.32 product plus guard bits, so chained MACs only quantize once at the
/// end. This is exactly how the paper's adder trees behave (LUT adders over
/// full-width partial products) and it is what keeps fixed-point conv error
/// at ~1 ulp instead of O(taps) ulps.
#[derive(Debug, Clone, Copy, Default)]
pub struct MacAcc(pub i64);

impl MacAcc {
    pub fn new() -> MacAcc {
        MacAcc(0)
    }

    /// acc += a*b, full precision (Q32.32 partial sums in i64 guard space).
    pub fn mac(&mut self, a: Fx, b: Fx) {
        self.0 = self.0.saturating_add(a.0 as i64 * b.0 as i64);
    }

    /// Add another accumulator (adder-tree node).
    pub fn add_acc(&mut self, other: MacAcc) {
        self.0 = self.0.saturating_add(other.0);
    }

    /// Add a bias expressed in Q16.16 (align to Q32.32 before summing).
    pub fn add_bias(&mut self, bias: Fx) {
        self.0 = self.0.saturating_add((bias.0 as i64) << FRAC_BITS);
    }

    /// Final requantization to Q16.16 with round-to-nearest.
    pub fn finish(self) -> Fx {
        let rounded = (self.0 + (1 << (FRAC_BITS - 1))) >> FRAC_BITS;
        saturate_i64(rounded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn roundtrip_exact_values() {
        for v in [-2.0f32, -0.5, 0.0, 0.25, 1.0, 100.5] {
            assert_eq!(Fx::from_f32(v).to_f32(), v);
        }
    }

    #[test]
    fn quantization_error_bounded() {
        prop::check_default(
            "fx-quant-error",
            |r: &mut Rng| r.range_f32(-1000.0, 1000.0),
            |&v| {
                let q = Fx::from_f32(v).to_f64();
                let err = (q - v as f64).abs();
                if err <= 0.5 * Fx::epsilon() + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("err {err} for {v}"))
                }
            },
        );
    }

    #[test]
    fn add_mul_match_float_within_ulp() {
        prop::check_default(
            "fx-arith",
            |r: &mut Rng| (r.range_f32(-100.0, 100.0), r.range_f32(-100.0, 100.0)),
            |&(a, b)| {
                let fa = Fx::from_f32(a);
                let fb = Fx::from_f32(b);
                let sum_err = (fa.add(fb).to_f64() - (fa.to_f64() + fb.to_f64())).abs();
                if sum_err > 1e-9 {
                    return Err(format!("add err {sum_err}"));
                }
                let prod_err = (fa.mul(fb).to_f64() - fa.to_f64() * fb.to_f64()).abs();
                if prod_err > Fx::epsilon() {
                    return Err(format!("mul err {prod_err}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn saturation_add() {
        assert_eq!(Fx::MAX.add(Fx::ONE), Fx::MAX);
        assert_eq!(Fx::MIN.add(Fx(-1)), Fx::MIN);
    }

    #[test]
    fn saturation_mul() {
        let big = Fx::from_f32(30000.0);
        assert_eq!(big.mul(big), Fx::MAX);
        let neg = Fx::from_f32(-30000.0);
        assert_eq!(neg.mul(big), Fx::MIN);
    }

    #[test]
    fn relu_and_max() {
        assert_eq!(Fx::from_f32(-3.0).relu(), Fx::ZERO);
        assert_eq!(Fx::from_f32(3.0).relu(), Fx::from_f32(3.0));
        assert_eq!(Fx::from_f32(1.0).max(Fx::from_f32(2.0)), Fx::from_f32(2.0));
    }

    #[test]
    fn mac_chain_single_quantization() {
        // Sum of 1024 products of small values: the widened accumulator's
        // error must stay ~1 quantization step, not grow with chain length.
        let mut rng = Rng::new(77);
        let mut acc = MacAcc::new();
        let mut exact = 0.0f64;
        for _ in 0..1024 {
            let a = Fx::from_f32(rng.range_f32(-1.0, 1.0));
            let b = Fx::from_f32(rng.range_f32(-1.0, 1.0));
            acc.mac(a, b);
            exact += a.to_f64() * b.to_f64();
        }
        let err = (acc.finish().to_f64() - exact).abs();
        assert!(err <= Fx::epsilon(), "err={err}");
    }

    #[test]
    fn mac_bias_alignment() {
        let mut acc = MacAcc::new();
        acc.mac(Fx::from_f32(2.0), Fx::from_f32(3.0));
        acc.add_bias(Fx::from_f32(0.5));
        assert_eq!(acc.finish().to_f32(), 6.5);
    }

    #[test]
    fn adder_tree_combination() {
        let mut a = MacAcc::new();
        a.mac(Fx::ONE, Fx::ONE);
        let mut b = MacAcc::new();
        b.mac(Fx::from_f32(2.0), Fx::ONE);
        a.add_acc(b);
        assert_eq!(a.finish().to_f32(), 3.0);
    }

    #[test]
    fn rounding_is_to_nearest() {
        // 0.5 * (1 + 2^-16): product is 0.5 + 2^-17, rounds up to 0.5 + 2^-16.
        let a = Fx::from_f32(0.5);
        let b = Fx(SCALE as i32 + 1);
        let got = a.mul(b);
        assert_eq!(got.0, (SCALE / 2) as i32 + 1);
    }
}
