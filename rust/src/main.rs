//! DeCoILFNet CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   simulate   cycle-accurate run of a network under a fusion plan
//!   plan       fusion-plan search under the platform budget (Fig 7)
//!   resources  structural resource report (Table I)
//!   verify     simulator <-> PJRT runtime numeric cross-check
//!   serve      threaded inference server demo over the AOT artifacts
//!   cluster    simulated multi-board fleet (sharding, contention, queueing)
//!   report     headline paper-vs-measured summary (E7)

use std::path::PathBuf;

use decoilfnet::accel::{Engine, FusionPlan, Weights};
use decoilfnet::baselines::{fused_layer, optimized};
use decoilfnet::config::{self, AccelConfig, Network};
use decoilfnet::coordinator::{self, BatchPolicy, Objective, Server, ServerConfig};
use decoilfnet::resources;
use decoilfnet::runtime::Runtime;
use decoilfnet::tensor::NdTensor;
use decoilfnet::util::cli::{render_help, Args, OptSpec};
use decoilfnet::util::stats::fmt_count;
use decoilfnet::util::table::{fmt_speedup, Table};
use decoilfnet::verify;

#[rustfmt::skip]
fn opt_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "net", takes_value: true, help: "network: vgg16-prefix7 | custom-4conv64 | tiny-vgg | paper-example | path to JSON", default: Some("vgg16-prefix7") },
        OptSpec { name: "plan", takes_value: true, help: "fusion plan: fused | unfused | comma sizes (e.g. 2,3,2)", default: Some("fused") },
        OptSpec { name: "prefix", takes_value: true, help: "simulate only the first N layers", default: None },
        OptSpec { name: "artifacts", takes_value: true, help: "artifacts directory", default: Some("artifacts") },
        OptSpec { name: "objective", takes_value: true, help: "planner objective: latency | traffic", default: Some("latency") },
        OptSpec { name: "dsp-cap", takes_value: true, help: "planner DSP cap in percent of the board", default: None },
        OptSpec { name: "requests", takes_value: true, help: "serve/cluster: number of requests to fire", default: Some("32") },
        OptSpec { name: "boards", takes_value: true, help: "cluster: number of simulated boards", default: Some("4") },
        OptSpec { name: "mode", takes_value: true, help: "cluster: sharding mode: replicated | pipelined", default: Some("replicated") },
        OptSpec { name: "rate", takes_value: true, help: "cluster: open-loop arrival rate in req/s (omit for a saturating burst)", default: None },
        OptSpec { name: "aggregate-ddr", takes_value: true, help: "cluster: shared off-chip bandwidth pool in bytes/cycle (omit to disable contention)", default: None },
        OptSpec { name: "cluster-config", takes_value: true, help: "cluster: path to a ClusterConfig JSON (overrides the flags above; supports heterogeneous board_specs, load_steps, reshard policy, tenants)", default: None },
        OptSpec { name: "tenants", takes_value: true, help: "cluster: path to a JSON array of TenantSpec objects — multi-tenant serving with per-tenant SLOs, priorities, DRR weights and preemption", default: None },
        OptSpec { name: "faults", takes_value: true, help: "cluster: path to a FaultScript JSON (board_down / link_degrade / clock_derate / compute_degrade events); board_down-with-recovery and clock_derate also work single-network, the rest require --tenants (or a config with tenants)", default: None },
        OptSpec { name: "fabric", takes_value: true, help: "cluster: path to a FabricSpec JSON (rack_ring | leaf_spine topology, boards_per_rack, per-segment bandwidth/latency) — routes all inter-board traffic over shared rack/uplink segments and prints per-segment utilization", default: None },
        OptSpec { name: "shed", takes_value: false, help: "cluster: print the per-tenant overload-shedding summary (offered / shed / retried / abandoned / goodput) — meaningful when a tenant carries an overload policy", default: None },
        OptSpec { name: "sweep", takes_value: false, help: "cluster: sweep 1..=boards instead of a single run", default: None },
        OptSpec { name: "trace", takes_value: true, help: "cluster: arm the telemetry sink and write the full trace (events, window samples, latency sketches) plus the report to this JSON file", default: None },
        OptSpec { name: "dashboard", takes_value: false, help: "cluster: arm the telemetry sink and print the ASCII fleet dashboard — per-board occupancy lanes with reshard/preemption markers", default: None },
        OptSpec { name: "reshard", takes_value: false, help: "cluster: enable the load-driven re-shard controller (default policy); combined with --tenants it arms tenant-aware re-sharding in the unified control plane", default: None },
        OptSpec { name: "clients", takes_value: true, help: "serve: concurrent client threads", default: Some("4") },
        OptSpec { name: "batch", takes_value: true, help: "serve: max batch size", default: Some("8") },
        OptSpec { name: "seed", takes_value: true, help: "weight/input seed", default: Some("1") },
        OptSpec { name: "json", takes_value: false, help: "emit machine-readable JSON instead of tables", default: None },
        OptSpec { name: "help", takes_value: false, help: "show this help", default: None },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, &opt_specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", help());
            std::process::exit(2);
        }
    };
    if args.has_flag("help") || args.subcommand.is_none() {
        println!("{}", help());
        return;
    }
    let result = match args.subcommand.as_deref().unwrap() {
        "simulate" => cmd_simulate(&args),
        "plan" => cmd_plan(&args),
        "resources" => cmd_resources(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "report" => cmd_report(&args),
        "trace" => cmd_trace(&args),
        other => Err(format!("unknown subcommand '{other}'\n\n{}", help())),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn help() -> String {
    render_help(
        "decoilfnet",
        &[
            ("simulate", "cycle-accurate run of a network under a fusion plan"),
            ("plan", "fusion-plan search under the platform budget (Fig 7)"),
            ("resources", "structural resource report (Table I)"),
            ("verify", "simulator vs PJRT runtime numeric cross-check"),
            ("serve", "threaded inference server demo over the artifacts"),
            ("cluster", "simulated multi-board fleet: sharding + contention + queueing"),
            ("report", "headline paper-vs-measured summary"),
            ("trace", "pipeline timeline (Fig 5 staircase) for a plan"),
        ],
        &opt_specs(),
    )
}

fn load_net(args: &Args) -> Result<Network, String> {
    let name = args.opt("net").unwrap();
    let mut net = match name {
        "vgg16-prefix7" => config::vgg16_prefix(),
        "custom-4conv64" => config::custom_4conv(),
        "tiny-vgg" => config::tiny_vgg(),
        "paper-example" => config::paper_test_example(),
        path => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading network spec '{path}': {e}"))?;
            Network::from_json_str(&text).map_err(|e| e.to_string())?
        }
    };
    if let Some(n) = args.opt_usize("prefix")? {
        if n == 0 || n > net.layers.len() {
            return Err(format!("--prefix must be 1..={}", net.layers.len()));
        }
        net.layers.truncate(n);
        net.name = format!("{}[..{n}]", net.name);
    }
    Ok(net)
}

fn parse_plan(args: &Args, n_layers: usize) -> Result<FusionPlan, String> {
    match args.opt("plan").unwrap() {
        "fused" => Ok(FusionPlan::fully_fused(n_layers)),
        "unfused" => Ok(FusionPlan::unfused(n_layers)),
        spec => {
            let sizes: Result<Vec<usize>, _> =
                spec.split(',').map(|s| s.trim().parse::<usize>()).collect();
            let sizes = sizes.map_err(|_| format!("bad plan spec '{spec}'"))?;
            FusionPlan::from_group_sizes(n_layers, &sizes)
        }
    }
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let net = load_net(args)?;
    let cfg = AccelConfig::paper_default();
    let plan = parse_plan(args, net.layers.len())?;
    let seed = args.opt_usize("seed")?.unwrap_or(1) as u64;
    let weights = Weights::random(&net, seed);
    let rep = Engine::new(cfg.clone()).simulate(&net, &weights, &plan);

    if args.has_flag("json") {
        let j = decoilfnet::util::json::Json::obj()
            .set("network", net.name.as_str())
            .set("plan", plan.label())
            .set("total_cycles", rep.total_cycles)
            .set("ms_at_freq", rep.ms_at(cfg.platform.freq_mhz))
            .set("weight_load_cycles", rep.weight_load_cycles)
            .set("ddr_read_bytes", rep.ddr_read_bytes)
            .set("ddr_write_bytes", rep.ddr_write_bytes);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }

    let mut t = Table::new(&["layer", "rate cyc/px", "first out", "last out", "out px"])
        .title(&format!(
            "simulate {} plan {} @ {} MHz",
            net.name,
            plan.label(),
            cfg.platform.freq_mhz
        ))
        .label_col();
    for lt in &rep.per_layer {
        t.row(&[
            lt.name.clone(),
            lt.rate.to_string(),
            fmt_count(lt.first_out),
            fmt_count(lt.last_out),
            fmt_count(lt.out_pixels),
        ]);
    }
    println!("{}", t.to_ascii());
    println!(
        "total: {} cycles = {:.2} ms   (weight preload {} cycles)   DDR {:.2} MB",
        fmt_count(rep.total_cycles),
        rep.ms_at(cfg.platform.freq_mhz),
        fmt_count(rep.weight_load_cycles),
        rep.total_mb(),
    );
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<(), String> {
    let net = load_net(args)?;
    let cfg = AccelConfig::paper_default();
    let seed = args.opt_usize("seed")?.unwrap_or(1) as u64;
    let weights = Weights::random(&net, seed);
    let objective = match (args.opt("objective").unwrap(), args.opt_usize("dsp-cap")?) {
        (_, Some(pct)) => Objective::LatencyUnderDspCap(pct.min(100) as u8),
        ("latency", None) => Objective::Latency,
        ("traffic", None) => Objective::Traffic,
        (o, _) => return Err(format!("unknown objective '{o}'")),
    };

    let mut costs = coordinator::cost_all_plans(&cfg, &net, &weights);
    costs.sort_by_key(|c| (c.cycles, c.traffic_bytes));
    let mut t = Table::new(&["plan", "groups", "est kcycles", "MB moved", "DSP", "BRAM36", "fits"])
        .title(&format!("fusion-plan search over {} ({} plans)", net.name, costs.len()))
        .label_col();
    for c in costs.iter().take(12) {
        t.row(&[
            c.plan.label(),
            c.plan.n_groups().to_string(),
            fmt_count(c.cycles / 1000),
            format!("{:.2}", c.traffic_bytes as f64 / (1024.0 * 1024.0)),
            c.resources.dsp.to_string(),
            c.resources.bram36().to_string(),
            if c.fits { "yes" } else { "NO" }.to_string(),
        ]);
    }
    println!("{}", t.to_ascii());

    match coordinator::best_plan(&cfg, &net, &weights, objective) {
        Some(best) => println!(
            "winner under {:?}: {}  ({} kcycles, {:.2} MB, {} DSP)",
            objective,
            best.plan.label(),
            fmt_count(best.cycles / 1000),
            best.traffic_bytes as f64 / (1024.0 * 1024.0),
            best.resources.dsp
        ),
        None => println!("no feasible plan under {objective:?}"),
    }
    Ok(())
}

fn cmd_resources(args: &Args) -> Result<(), String> {
    let net = load_net(args)?;
    let cfg = AccelConfig::paper_default();
    let plan = parse_plan(args, net.layers.len())?;
    let used = resources::plan_resources(&cfg, &net, &plan);
    let u = resources::utilization(used, &cfg);
    let p = &cfg.platform;
    let mut t = Table::new(&["resource", "used", "available", "utilization"])
        .title(&format!("{} plan {} on {}", net.name, plan.label(), p.name))
        .label_col();
    t.row(&["DSP".into(), used.dsp.to_string(), p.dsp.to_string(), format!("{:.1}%", u.dsp_pct)]);
    t.row(&["BRAM36".into(), used.bram36().to_string(), p.bram36.to_string(), format!("{:.1}%", u.bram_pct)]);
    t.row(&["LUT".into(), used.lut.to_string(), p.lut.to_string(), format!("{:.1}%", u.lut_pct)]);
    t.row(&["FF".into(), used.ff.to_string(), p.ff.to_string(), format!("{:.1}%", u.ff_pct)]);
    println!("{}", t.to_ascii());
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), String> {
    let dir = PathBuf::from(args.opt("artifacts").unwrap());
    let name = args.opt("net").unwrap();
    let name = if name == "vgg16-prefix7" { "tiny-vgg" } else { name }; // artifacts default
    let rt = Runtime::load(&dir, name).map_err(|e| format!("{e:#}"))?;
    println!("PJRT platform: {}", rt.platform_name());
    let reports =
        verify::verify_all(&rt, &AccelConfig::paper_default()).map_err(|e| format!("{e:#}"))?;
    let mut t = Table::new(&["plan", "max |sim - runtime|", "tolerance", "runtime vs golden", "status"])
        .title(&format!("verify {name}: Q16.16 simulator vs PJRT float"))
        .label_col();
    let mut all_ok = true;
    for r in &reports {
        all_ok &= r.passed;
        t.row(&[
            r.plan.clone(),
            format!("{:.2e}", r.max_abs_diff),
            format!("{:.0e}", r.tolerance),
            format!("{:.2e}", r.golden_diff),
            if r.passed { "PASS" } else { "FAIL" }.to_string(),
        ]);
    }
    println!("{}", t.to_ascii());
    if all_ok {
        Ok(())
    } else {
        Err("verification failed".to_string())
    }
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let dir = PathBuf::from(args.opt("artifacts").unwrap());
    let name = args.opt("net").unwrap();
    let name = if name == "vgg16-prefix7" { "tiny-vgg" } else { name };
    let n_requests = args.opt_usize("requests")?.unwrap_or(32);
    let n_clients = args.opt_usize("clients")?.unwrap_or(4).max(1);
    let max_batch = args.opt_usize("batch")?.unwrap_or(8).max(1);

    let srv = Server::start(ServerConfig {
        artifacts_dir: dir.clone(),
        network: name.to_string(),
        default_plan: "fused".to_string(),
        batch: BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_millis(2),
        },
    })
    .map_err(|e| format!("{e:#}"))?;

    let rt = Runtime::load(&dir, name).map_err(|e| format!("{e:#}"))?;
    let (input, _) = rt.golden().map_err(|e| format!("{e:#}"))?;

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let h = srv.handle.clone();
        let input = input.clone();
        let per_client = n_requests / n_clients + usize::from(c < n_requests % n_clients);
        joins.push(std::thread::spawn(move || {
            for _ in 0..per_client {
                let resp = h.submit(input.clone(), None).wait().unwrap();
                assert!(resp.result.is_ok());
            }
        }));
    }
    for j in joins {
        j.join().map_err(|_| "client thread panicked".to_string())?;
    }
    let wall = t0.elapsed();
    println!("{}", srv.handle.metrics_json());
    println!(
        "{} requests / {:.3} s = {:.1} req/s",
        n_requests,
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64()
    );
    srv.shutdown();
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<(), String> {
    let net = load_net(args)?;
    let cfg = AccelConfig::paper_default();

    let mut ccfg = match args.opt("cluster-config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading cluster config '{path}': {e}"))?;
            decoilfnet::config::ClusterConfig::from_json_str(&text)?
        }
        None => {
            let mut c = decoilfnet::config::ClusterConfig::fleet_default();
            c.boards = args.opt_usize("boards")?.unwrap_or(4).max(1);
            c.mode = decoilfnet::config::ShardMode::from_name(args.opt("mode").unwrap())?;
            c.arrival_rps = args.opt_f64("rate")?.unwrap_or(f64::INFINITY);
            c.aggregate_ddr_bytes_per_cycle = args.opt_f64("aggregate-ddr")?;
            c.requests = args.opt_usize("requests")?.unwrap_or(256).max(1);
            c.seed = args.opt_usize("seed")?.unwrap_or(1) as u64;
            c.max_batch = args.opt_usize("batch")?.unwrap_or(8).max(1);
            if args.has_flag("reshard") {
                c.reshard = Some(decoilfnet::config::ReshardPolicy::default_policy());
            }
            c
        }
    };
    if let Some(path) = args.opt("tenants") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading tenants '{path}': {e}"))?;
        let j = decoilfnet::util::json::parse(&text).map_err(|e| format!("tenants json: {e}"))?;
        ccfg.tenants = j
            .as_arr()
            .ok_or("tenants file must contain a JSON array of TenantSpec objects")?
            .iter()
            .map(decoilfnet::config::TenantSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
    }
    if let Some(path) = args.opt("faults") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading fault script '{path}': {e}"))?;
        ccfg.faults = Some(decoilfnet::config::FaultScript::from_json_str(&text)?);
    }
    if let Some(path) = args.opt("fabric") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading fabric spec '{path}': {e}"))?;
        ccfg.fabric = Some(decoilfnet::config::FabricSpec::from_json_str(&text)?);
    }
    ccfg.validate()?;

    let board_counts: Vec<usize> = if args.has_flag("sweep") {
        (1..=ccfg.boards).collect()
    } else {
        vec![ccfg.boards]
    };

    let mut t = Table::new(&[
        "boards", "mode", "req/s", "p50 ms", "p99 ms", "avg util", "ddr slowdown",
    ])
    .title(&format!(
        "fleet simulation: {} — {} requests, {}",
        net.name,
        ccfg.requests,
        if ccfg.arrival_rps.is_finite() {
            format!("{} req/s open loop", ccfg.arrival_rps)
        } else {
            "saturating burst".to_string()
        }
    ));
    // `--trace`/`--dashboard` arm the telemetry sink; a sweep keeps the
    // final run's trace (the full-fleet configuration).
    let tracing = args.opt("trace").is_some() || args.has_flag("dashboard");
    let mut last_sink: Option<decoilfnet::cluster::TraceSink> = None;
    let mut reports = Vec::new();
    for boards in board_counts {
        // `with_boards` resizes heterogeneous fleets validly (truncating or
        // extending board_specs in rack order), so sweeps work there too.
        let c = ccfg.with_boards(boards);
        let r = if tracing {
            let mut sink = decoilfnet::cluster::TraceSink::enabled();
            let r = decoilfnet::coordinator::simulate_cluster_traced(&cfg, &net, &c, &mut sink)?;
            last_sink = Some(sink);
            r
        } else {
            decoilfnet::coordinator::simulate_cluster(&cfg, &net, &c)?
        };
        // The dynamic engine reports idle provisioned boards too; average
        // utilization over boards that actually served work.
        let active = r.per_board.iter().filter(|b| b.busy_cycles > 0).count();
        let avg_util = if active == 0 {
            0.0
        } else {
            r.per_board
                .iter()
                .filter(|b| b.busy_cycles > 0)
                .map(|b| b.utilization)
                .sum::<f64>()
                / active as f64
        };
        t.row(&[
            format!("{} ({} used)", r.boards, r.used_boards),
            r.mode.as_str().to_string(),
            format!("{:.1}", r.throughput_rps),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.0}%", 100.0 * avg_util),
            format!("{:.2}x", r.ddr_slowdown),
        ]);
        reports.push(r);
    }

    if args.has_flag("json") {
        let mut arr = decoilfnet::util::json::Json::Arr(vec![]);
        for r in &reports {
            arr = arr.push(r.to_json());
        }
        println!("{}", arr.to_string_pretty());
    } else {
        println!("{}", t.to_ascii());
        for r in &reports {
            if r.idle_boards > 0 {
                println!(
                    "warning: {} of {} provisioned board(s) idle — the plan has only {} \
                     pipeline stage(s); extra boards add cost but no throughput",
                    r.idle_boards, r.boards, r.used_boards
                );
            }
            for e in &r.reshard_events {
                let who = match &e.tenant {
                    Some(t) => format!(" [tenant {t}]"),
                    None => String::new(),
                };
                println!(
                    "reshard @ cycle {}{}: {} -> {} ({}; moved {:.2} MB, stalled {} cycles)",
                    e.at_cycle,
                    who,
                    e.from,
                    e.to,
                    e.reason,
                    e.migration_bytes as f64 / (1024.0 * 1024.0),
                    e.stall_cycles
                );
            }
            if let Some(f) = &r.faults {
                println!(
                    "faults: {} board failure(s), {} recover(ies), {} link degrade(s), \
                     {} clock derate(s), {} compute degrade(s), {} emergency re-shard(s); \
                     {} item(s) re-queued, {} downtime cycles",
                    f.board_failures,
                    f.board_recoveries,
                    f.link_degrades,
                    f.clock_derates,
                    f.compute_degrades,
                    f.emergency_reshards,
                    f.items_requeued,
                    f.downtime_cycles
                );
                if let (Some(pre), Some(post)) = (f.pre_fault_p99_ms, f.recovery_p99_ms) {
                    println!(
                        "        pre-fault p99 {pre:.3} ms -> post-recovery p99 {post:.3} ms \
                         ({:.2}x)",
                        post / pre
                    );
                }
                if let Some(rto) = f.recovery_time_ms {
                    println!(
                        "        recovery time: {rto:.3} ms from fault onset to the first \
                         controller window back within 1.25x the pre-fault p99"
                    );
                }
            }
            if let Some(fb) = &r.fabric {
                let mut ft = Table::new(&["segment", "kind", "MB moved", "transfers", "busy util"])
                    .title(&format!(
                        "fabric segments ({} topology, {} rack(s) x {} board(s))",
                        fb.topology, fb.racks, fb.boards_per_rack
                    ))
                    .label_col();
                for s in &fb.segments {
                    ft.row(&[
                        s.name.clone(),
                        s.kind.clone(),
                        format!("{:.2}", s.bytes_moved as f64 / (1024.0 * 1024.0)),
                        s.transfers.to_string(),
                        format!("{:.0}%", 100.0 * s.utilization),
                    ]);
                }
                println!("{}", ft.to_ascii());
            }
            if !r.tenants.is_empty() {
                let mut tt = Table::new(&[
                    "tenant", "prio", "req/s", "p50 ms", "p99 ms", "slo p99 ms", "slo",
                    "preempted",
                ])
                .title(&format!("per-tenant SLOs ({} boards)", r.boards))
                .label_col();
                for t in &r.tenants {
                    tt.row(&[
                        t.name.clone(),
                        t.priority.to_string(),
                        format!("{:.1}", t.throughput_rps),
                        format!("{:.2}", t.p50_ms),
                        // Under the unified control plane the post-settle
                        // tail p99 rides along — the number that shows a
                        // re-shard actually recovered the tenant.
                        match t.tail_p99_ms {
                            Some(tail) => format!("{:.2} ({tail:.2} tail)", t.p99_ms),
                            None => format!("{:.2}", t.p99_ms),
                        },
                        format!("{:.2}", t.slo_p99_ms),
                        // With a fault script armed, show how the tenant
                        // held its SLO for requests completing mid-outage.
                        match t.slo_attainment_outage {
                            Some(a) => format!(
                                "{} ({:.0}% in outage)",
                                if t.slo_met { "MET" } else { "MISSED" },
                                100.0 * a
                            ),
                            None => if t.slo_met { "MET" } else { "MISSED" }.to_string(),
                        },
                        t.preemptions.to_string(),
                    ]);
                }
                println!("{}", tt.to_ascii());
                // `--shed`: graceful-degradation ledger. Offered always
                // equals completed + abandoned (the engine asserts it); the
                // table shows where the lost work went.
                if args.has_flag("shed") {
                    if r.tenants.iter().any(|t| t.shed.is_some()) {
                        let mut st = Table::new(&[
                            "tenant", "offered", "completed", "shed", "retried", "abandoned",
                            "goodput req/s",
                        ])
                        .title(&format!("overload shedding ({} boards)", r.boards))
                        .label_col();
                        for ts in &r.tenants {
                            st.row(&[
                                ts.name.clone(),
                                ts.requests.to_string(),
                                ts.completed.to_string(),
                                ts.shed.unwrap_or(0).to_string(),
                                ts.retried.unwrap_or(0).to_string(),
                                ts.abandoned.unwrap_or(0).to_string(),
                                format!("{:.1}", ts.goodput_rps.unwrap_or(0.0)),
                            ]);
                        }
                        println!("{}", st.to_ascii());
                        if let (Some(sh), Some(re), Some(ab), Some(g)) = (
                            r.shed_total,
                            r.retried_total,
                            r.abandoned_total,
                            r.goodput_rps,
                        ) {
                            println!(
                                "fleet: {sh} shed, {re} retried, {ab} abandoned; goodput \
                                 {g:.1} req/s"
                            );
                        }
                    } else {
                        println!(
                            "note: --shed requested but no tenant carries an overload \
                             policy — admission never sheds"
                        );
                    }
                }
            }
        }
    }
    if let Some(sink) = &last_sink {
        let last = reports.last().expect("at least one report");
        if args.has_flag("dashboard") && !args.has_flag("json") {
            println!();
            print!(
                "{}",
                decoilfnet::cluster::fleet_dashboard(sink, last.boards, last.makespan_cycles, 64)
            );
        }
        if let Some(path) = args.opt("trace") {
            let doc = decoilfnet::util::json::Json::obj()
                .set("schema", "decoilfnet-fleet-trace/v1")
                .set("report", last.to_json())
                .set("trace", sink.to_json());
            std::fs::write(path, doc.to_string_pretty())
                .map_err(|e| format!("writing trace '{path}': {e}"))?;
            println!("wrote fleet trace to {path}");
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<(), String> {
    let cfg = AccelConfig::paper_default();
    let net = config::vgg16_prefix();
    let seed = args.opt_usize("seed")?.unwrap_or(1) as u64;
    let weights = Weights::random(&net, seed);
    let engine = Engine::new(cfg.clone());

    // DeCoILFNet fused.
    let ours = engine.simulate(&net, &weights, &FusionPlan::fully_fused(7));
    // Baselines (their published configuration ran at 100 MHz).
    let ocfg = optimized::OptimizedConfig::zhang2015();
    let opt = optimized::run(&ocfg, &cfg, &net);
    let fus = fused_layer::run(&ocfg, &cfg, &net, 28);
    // CPU (measured on this machine; single honest run).
    let cpu_w = decoilfnet::baselines::cpu_ref::CpuWeights::random(&net, seed);
    let input = NdTensor::random(&net.input.as_slice(), 7, -1.0, 1.0);
    let (_, cum) = decoilfnet::baselines::cpu_ref::forward_timed(&net, &cpu_w, &input);
    let cpu_ms = cum.last().unwrap().1;

    let ours_ms = ours.ms_at(cfg.platform.freq_mhz);
    let mut t = Table::new(&["metric", "paper", "measured"])
        .title("E7 - headline claims")
        .label_col();
    t.row(&[
        "speedup vs CPU (7 layers)".into(),
        "39.03X".into(),
        fmt_speedup(cpu_ms / ours_ms),
    ]);
    t.row(&[
        "cycles vs Optimized [2]".into(),
        "10951k/5034k = 2.18X".into(),
        format!(
            "{}k/{}k = {}",
            opt.total_cycles / 1000,
            ours.total_cycles / 1000,
            fmt_speedup(opt.total_cycles as f64 / ours.total_cycles as f64)
        ),
    ]);
    t.row(&[
        "cycles vs Fused-layer [3]".into(),
        "11655k/5034k = 2.32X".into(),
        format!(
            "{}k/{}k = {}",
            fus.total_cycles / 1000,
            ours.total_cycles / 1000,
            fmt_speedup(fus.total_cycles as f64 / ours.total_cycles as f64)
        ),
    ]);
    t.row(&[
        "DDR traffic vs [2]".into(),
        "77.14/6.69 = 11.5X".into(),
        format!(
            "{:.1}/{:.1} = {}",
            opt.total_mb(),
            ours.total_mb(),
            fmt_speedup(opt.total_mb() / ours.total_mb())
        ),
    ]);
    t.row(&[
        "DDR traffic vs [3]".into(),
        "3.64/6.69 = 0.54X".into(),
        format!(
            "{:.1}/{:.1} = {}",
            fus.total_mb(),
            ours.total_mb(),
            fmt_speedup(fus.total_mb() / ours.total_mb())
        ),
    ]);
    println!("{}", t.to_ascii());
    println!("note: CPU wallclock measured on this machine; the paper used a Xeon E7.");
    println!(
        "      DeCoILFNet fused: {} cycles = {:.2} ms at {} MHz",
        fmt_count(ours.total_cycles),
        ours_ms,
        cfg.platform.freq_mhz
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<(), String> {
    let net = load_net(args)?;
    let cfg = AccelConfig::paper_default();
    let plan = parse_plan(args, net.layers.len())?;
    let seed = args.opt_usize("seed")?.unwrap_or(1) as u64;
    let weights = Weights::random(&net, seed);
    let rep = Engine::new(cfg.clone()).simulate(&net, &weights, &plan);
    if args.has_flag("json") {
        println!(
            "{}",
            decoilfnet::accel::trace::to_json(&net, &rep).to_string_pretty()
        );
    } else {
        println!(
            "pipeline timeline — {} plan {} ({} cycles):\n",
            net.name,
            plan.label(),
            fmt_count(rep.total_cycles)
        );
        print!("{}", decoilfnet::accel::trace::ascii_gantt(&net, &rep, 64));
    }
    Ok(())
}
