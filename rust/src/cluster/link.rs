//! Inter-board link model.
//!
//! Pipelined shards move fusion-group boundary volumes between boards over a
//! point-to-point link (PCIe/Aurora-class on multi-FPGA hosts). The model is
//! the same shape as the DDR channel: fixed sustained bandwidth plus a fixed
//! per-transfer latency (serialization + switch hop). Bandwidth is expressed
//! in bytes per *accelerator* cycle so link time composes directly with the
//! cycle estimates.

/// A point-to-point inter-board link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterBoardLink {
    pub bytes_per_cycle: f64,
    pub latency_cycles: u64,
}

impl InterBoardLink {
    pub fn new(bytes_per_cycle: f64, latency_cycles: u64) -> InterBoardLink {
        assert!(bytes_per_cycle > 0.0);
        InterBoardLink {
            bytes_per_cycle,
            latency_cycles,
        }
    }

    /// A link so fast it is free — for idealized-scaling experiments.
    pub fn ideal() -> InterBoardLink {
        InterBoardLink {
            bytes_per_cycle: f64::INFINITY,
            latency_cycles: 0,
        }
    }

    /// Cycles to move `bytes` across the link (latency + serialization).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.latency_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// A link with an occupancy timeline: the wire has finite capacity, so a
/// transfer begins only when both the sender is ready *and* the previous
/// transfer has drained. Under sustained boundary traffic the link itself
/// can therefore become the bottleneck stage of a pipelined fleet — the
/// failure mode a bandwidth-provisioning study has to be able to produce.
#[derive(Debug, Clone)]
pub struct LinkChannel {
    pub link: InterBoardLink,
    busy_until: u64,
    pub bytes_moved: u64,
    /// Absolute-time degrade windows `(start, end, factor)`: while the data
    /// phase of a transfer overlaps `[start, end)` the wire runs at
    /// `factor` × its nominal bandwidth (fault injection — see
    /// [`crate::config::FaultEvent::LinkDegrade`]). Empty on every healthy
    /// channel, which keeps the healthy arithmetic byte-identical to the
    /// pre-fault model.
    degrades: Vec<(u64, u64, f64)>,
}

impl LinkChannel {
    pub fn new(link: InterBoardLink) -> LinkChannel {
        LinkChannel {
            link,
            busy_until: 0,
            bytes_moved: 0,
            degrades: Vec::new(),
        }
    }

    /// Arm degrade windows on this channel (sorted by start; overlapping
    /// windows compound by taking the slowest factor). Passing an empty
    /// vector restores the exact healthy model.
    pub fn set_degrades(&mut self, mut windows: Vec<(u64, u64, f64)>) {
        windows.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        self.degrades = windows;
    }

    /// Restore accumulated wire state onto a freshly built channel: the
    /// byte odometer and any in-flight occupancy. A re-shard replaces the
    /// channel *objects* (the plan's stage boundaries moved) but the
    /// physical wire between two boards neither forgets what it has
    /// carried nor drains an in-flight transfer early — re-plans that
    /// rebuild their channels thread the old state through here so
    /// `FleetReport` link accounting conserves bytes across the switch.
    pub fn restore_state(&mut self, bytes_moved: u64, busy_until: u64) {
        self.bytes_moved = bytes_moved;
        self.busy_until = busy_until;
    }

    /// Move `bytes` starting no earlier than `earliest`; returns the
    /// completion cycle. Transfers serialize behind each other. An empty
    /// transfer is free and does not occupy the wire.
    pub fn transfer(&mut self, bytes: u64, earliest: u64) -> u64 {
        if bytes == 0 {
            return earliest;
        }
        let start = earliest.max(self.busy_until);
        let end = if self.degrades.is_empty() {
            start + self.link.transfer_cycles(bytes)
        } else {
            start + self.degraded_transfer_cycles(bytes, start)
        };
        self.busy_until = end;
        self.bytes_moved += bytes;
        end
    }

    /// Piecewise serialization through the degrade windows: the data phase
    /// (after the fixed latency) drains at the nominal rate outside every
    /// window and at `factor` × nominal inside — only the overlapping span
    /// is billed slow. Reduces to `latency + ceil(bytes / rate)` when no
    /// window overlaps, because the phase start is integral.
    fn degraded_transfer_cycles(&self, bytes: u64, start: u64) -> u64 {
        let bpc = self.link.bytes_per_cycle;
        if !bpc.is_finite() {
            return self.link.latency_cycles;
        }
        let mut t = (start + self.link.latency_cycles) as f64;
        let mut left = bytes as f64;
        loop {
            let factor = self
                .degrades
                .iter()
                .filter(|w| (w.0 as f64) <= t && t < w.1 as f64)
                .map(|w| w.2)
                .fold(1.0f64, f64::min);
            let boundary = self
                .degrades
                .iter()
                .flat_map(|w| [w.0 as f64, w.1 as f64])
                .filter(|&b| b > t)
                .fold(f64::INFINITY, f64::min);
            let rate = bpc * factor;
            let need = left / rate;
            if t + need <= boundary {
                t += need;
                break;
            }
            left -= (boundary - t) * rate;
            t = boundary;
        }
        t.ceil() as u64 - start
    }

    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_adds_latency_and_serialization() {
        let l = InterBoardLink::new(16.0, 100);
        assert_eq!(l.transfer_cycles(1600), 100 + 100);
        assert_eq!(l.transfer_cycles(1), 100 + 1);
        assert_eq!(l.transfer_cycles(0), 0, "empty transfer is free");
    }

    #[test]
    fn ideal_link_is_free() {
        let l = InterBoardLink::ideal();
        assert_eq!(l.transfer_cycles(u64::MAX / 2), 0);
    }

    #[test]
    fn channel_serializes_back_to_back_transfers() {
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        let e1 = ch.transfer(160, 0); // 0 .. 10+10
        assert_eq!(e1, 20);
        let e2 = ch.transfer(160, 5); // queued behind the first
        assert_eq!(e2, 40);
        let e3 = ch.transfer(16, 100); // idle gap, starts fresh
        assert_eq!(e3, 111);
        assert_eq!(ch.bytes_moved, 336);
    }

    #[test]
    fn channel_empty_transfer_does_not_occupy_the_wire() {
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        assert_eq!(ch.transfer(0, 42), 42);
        assert_eq!(ch.busy_until(), 0);
        assert_eq!(ch.bytes_moved, 0);
    }

    #[test]
    fn ideal_channel_adds_no_time() {
        let mut ch = LinkChannel::new(InterBoardLink::ideal());
        assert_eq!(ch.transfer(1 << 40, 7), 7);
        // Instantaneous transfers occupy no wire time beyond their instant.
        assert_eq!(ch.transfer(1 << 40, 9), 9);
    }

    #[test]
    fn degrade_bills_only_the_overlapping_span() {
        // Nominal: latency 10, then 320 B at 16 B/cyc = data phase [10, 30).
        // A 0.5x window over [20, 40) halves the second half of the phase:
        // 160 B drain in [10, 20), the remaining 160 B take 20 cycles at
        // 8 B/cyc — completion at 40 instead of 30. The slow span is
        // exactly the overlap; cycles before the window stay full rate.
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        ch.set_degrades(vec![(20, 40, 0.5)]);
        assert_eq!(ch.transfer(320, 0), 40);

        // A transfer entirely outside the window is billed at the healthy
        // formula (phase [50, 70) vs window [20, 40)).
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        ch.set_degrades(vec![(20, 40, 0.5)]);
        assert_eq!(ch.transfer(320, 40), 40 + 10 + 20);
    }

    #[test]
    fn back_to_back_flap_windows_compose() {
        // Flap: [20, 30) at 0.5x then [30, 40) at 0.25x, recovery after 40.
        // 320 B from t = 0: [10, 20) drains 160 B, [20, 30) drains 80 B,
        // [30, 40) drains 40 B, the last 40 B at full rate need 2.5 cycles
        // → completes at ceil(42.5) = 43.
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        ch.set_degrades(vec![(20, 30, 0.5), (30, 40, 0.25)]);
        assert_eq!(ch.transfer(320, 0), 43);
    }

    #[test]
    fn overlapping_windows_take_the_slowest_factor() {
        // [10, 30) at 0.5x and [15, 20) at 0.25x overlap; the overlap runs
        // at min = 0.25x. 160 B from t = 0 (latency 10): [10, 15) at 8 B/c
        // drains 40 B, [15, 20) at 4 B/c drains 20 B, [20, 30) at 8 B/c
        // drains 80 B, and the last 20 B at full rate need 1.25 cycles →
        // completes at ceil(31.25) = 32.
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        ch.set_degrades(vec![(10, 30, 0.5), (15, 20, 0.25)]);
        assert_eq!(ch.transfer(160, 0), 32);
    }

    #[test]
    fn empty_degrades_keep_the_healthy_model_exact() {
        // set_degrades(vec![]) must leave every number identical to a
        // never-degraded channel — the byte-compat contract the committed
        // fixtures rely on.
        let mut healthy = LinkChannel::new(InterBoardLink::new(16.0, 10));
        let mut cleared = LinkChannel::new(InterBoardLink::new(16.0, 10));
        cleared.set_degrades(vec![]);
        for (bytes, earliest) in [(160, 0), (160, 5), (16, 100), (1, 101)] {
            assert_eq!(
                healthy.transfer(bytes, earliest),
                cleared.transfer(bytes, earliest)
            );
        }
        assert_eq!(healthy.busy_until(), cleared.busy_until());

        // Degraded ideal links still cost nothing (infinite bandwidth).
        let mut ideal = LinkChannel::new(InterBoardLink::ideal());
        ideal.set_degrades(vec![(0, 1 << 30, 0.01)]);
        assert_eq!(ideal.transfer(1 << 40, 7), 7);
    }
}
