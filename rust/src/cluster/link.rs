//! Inter-board link model.
//!
//! Pipelined shards move fusion-group boundary volumes between boards over a
//! point-to-point link (PCIe/Aurora-class on multi-FPGA hosts). The model is
//! the same shape as the DDR channel: fixed sustained bandwidth plus a fixed
//! per-transfer latency (serialization + switch hop). Bandwidth is expressed
//! in bytes per *accelerator* cycle so link time composes directly with the
//! cycle estimates.

/// A point-to-point inter-board link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterBoardLink {
    pub bytes_per_cycle: f64,
    pub latency_cycles: u64,
}

impl InterBoardLink {
    pub fn new(bytes_per_cycle: f64, latency_cycles: u64) -> InterBoardLink {
        assert!(bytes_per_cycle > 0.0);
        InterBoardLink {
            bytes_per_cycle,
            latency_cycles,
        }
    }

    /// A link so fast it is free — for idealized-scaling experiments.
    pub fn ideal() -> InterBoardLink {
        InterBoardLink {
            bytes_per_cycle: f64::INFINITY,
            latency_cycles: 0,
        }
    }

    /// Cycles to move `bytes` across the link (latency + serialization).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.latency_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// A link with an occupancy timeline: the wire has finite capacity, so a
/// transfer begins only when both the sender is ready *and* the previous
/// transfer has drained. Under sustained boundary traffic the link itself
/// can therefore become the bottleneck stage of a pipelined fleet — the
/// failure mode a bandwidth-provisioning study has to be able to produce.
#[derive(Debug, Clone)]
pub struct LinkChannel {
    pub link: InterBoardLink,
    busy_until: u64,
    pub bytes_moved: u64,
}

impl LinkChannel {
    pub fn new(link: InterBoardLink) -> LinkChannel {
        LinkChannel {
            link,
            busy_until: 0,
            bytes_moved: 0,
        }
    }

    /// Move `bytes` starting no earlier than `earliest`; returns the
    /// completion cycle. Transfers serialize behind each other. An empty
    /// transfer is free and does not occupy the wire.
    pub fn transfer(&mut self, bytes: u64, earliest: u64) -> u64 {
        if bytes == 0 {
            return earliest;
        }
        let start = earliest.max(self.busy_until);
        let end = start + self.link.transfer_cycles(bytes);
        self.busy_until = end;
        self.bytes_moved += bytes;
        end
    }

    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_adds_latency_and_serialization() {
        let l = InterBoardLink::new(16.0, 100);
        assert_eq!(l.transfer_cycles(1600), 100 + 100);
        assert_eq!(l.transfer_cycles(1), 100 + 1);
        assert_eq!(l.transfer_cycles(0), 0, "empty transfer is free");
    }

    #[test]
    fn ideal_link_is_free() {
        let l = InterBoardLink::ideal();
        assert_eq!(l.transfer_cycles(u64::MAX / 2), 0);
    }

    #[test]
    fn channel_serializes_back_to_back_transfers() {
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        let e1 = ch.transfer(160, 0); // 0 .. 10+10
        assert_eq!(e1, 20);
        let e2 = ch.transfer(160, 5); // queued behind the first
        assert_eq!(e2, 40);
        let e3 = ch.transfer(16, 100); // idle gap, starts fresh
        assert_eq!(e3, 111);
        assert_eq!(ch.bytes_moved, 336);
    }

    #[test]
    fn channel_empty_transfer_does_not_occupy_the_wire() {
        let mut ch = LinkChannel::new(InterBoardLink::new(16.0, 10));
        assert_eq!(ch.transfer(0, 42), 42);
        assert_eq!(ch.busy_until(), 0);
        assert_eq!(ch.bytes_moved, 0);
    }

    #[test]
    fn ideal_channel_adds_no_time() {
        let mut ch = LinkChannel::new(InterBoardLink::ideal());
        assert_eq!(ch.transfer(1 << 40, 7), 7);
        // Instantaneous transfers occupy no wire time beyond their instant.
        assert_eq!(ch.transfer(1 << 40, 9), 9);
    }
}
