//! Inter-board link model.
//!
//! Pipelined shards move fusion-group boundary volumes between boards over a
//! point-to-point link (PCIe/Aurora-class on multi-FPGA hosts). The model is
//! the same shape as the DDR channel: fixed sustained bandwidth plus a fixed
//! per-transfer latency (serialization + switch hop). Bandwidth is expressed
//! in bytes per *accelerator* cycle so link time composes directly with the
//! cycle estimates.

/// A point-to-point inter-board link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterBoardLink {
    pub bytes_per_cycle: f64,
    pub latency_cycles: u64,
}

impl InterBoardLink {
    pub fn new(bytes_per_cycle: f64, latency_cycles: u64) -> InterBoardLink {
        assert!(bytes_per_cycle > 0.0);
        InterBoardLink {
            bytes_per_cycle,
            latency_cycles,
        }
    }

    /// A link so fast it is free — for idealized-scaling experiments.
    pub fn ideal() -> InterBoardLink {
        InterBoardLink {
            bytes_per_cycle: f64::INFINITY,
            latency_cycles: 0,
        }
    }

    /// Cycles to move `bytes` across the link (latency + serialization).
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.latency_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_adds_latency_and_serialization() {
        let l = InterBoardLink::new(16.0, 100);
        assert_eq!(l.transfer_cycles(1600), 100 + 100);
        assert_eq!(l.transfer_cycles(1), 100 + 1);
        assert_eq!(l.transfer_cycles(0), 0, "empty transfer is free");
    }

    #[test]
    fn ideal_link_is_free() {
        let l = InterBoardLink::ideal();
        assert_eq!(l.transfer_cycles(u64::MAX / 2), 0);
    }
}
