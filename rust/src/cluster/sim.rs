//! Fleet request scheduler / queue simulator.
//!
//! Open-loop arrivals (Poisson via [`Rng`], or a saturating burst at t = 0)
//! are dispatched to per-board queues, batched by the coordinator's own
//! [`DynamicBatcher`] (driven here with synthetic deterministic clocks
//! instead of wall time), and served with the shard planner's closed-form
//! batch costs. Off-chip phases stretch under the [`SharedDdr`] contention
//! model; pipelined stages forward batches across [`InterBoardLink`]s.
//! Everything is deterministic from the config's seed.
//!
//! Time is measured in accelerator cycles (u64) and converted to wall time
//! at the platform clock only for reporting.

use std::time::{Duration, Instant};

use crate::config::{AccelConfig, ClusterConfig, ShardMode};
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use crate::fpga::ddr::SharedDdr;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats::percentile_sorted;

use super::link::InterBoardLink;
use super::shard::ShardPlan;

/// Per-board outcome counters.
#[derive(Debug, Clone)]
pub struct BoardStats {
    pub board: usize,
    pub items: u64,
    pub batches: u64,
    pub busy_cycles: u64,
    /// busy / makespan.
    pub utilization: f64,
}

/// Outcome of one fleet simulation.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub mode: ShardMode,
    pub boards: usize,
    pub used_boards: usize,
    pub requests: usize,
    pub completed: usize,
    pub makespan_cycles: u64,
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub per_board: Vec<BoardStats>,
    /// Total bytes moved across inter-board links (0 for replicated).
    pub link_bytes_total: u64,
    /// The shared-DDR slowdown the fleet ran under (1.0 = uncontended).
    pub ddr_slowdown: f64,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let mut boards = Json::Arr(vec![]);
        for b in &self.per_board {
            boards = boards.push(
                Json::obj()
                    .set("board", b.board)
                    .set("items", b.items)
                    .set("batches", b.batches)
                    .set("busy_cycles", b.busy_cycles)
                    .set("utilization", b.utilization),
            );
        }
        Json::obj()
            .set("mode", self.mode.as_str())
            .set("boards", self.boards)
            .set("used_boards", self.used_boards)
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("makespan_cycles", self.makespan_cycles)
            .set("throughput_rps", self.throughput_rps)
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("link_bytes_total", self.link_bytes_total)
            .set("ddr_slowdown", self.ddr_slowdown)
            .set("per_board", boards)
    }
}

/// Open-loop Poisson arrival times in cycles. A non-finite rate means a
/// saturating burst: every request arrives at t = 0.
pub fn poisson_arrivals(n: usize, rps: f64, freq_mhz: f64, seed: u64) -> Vec<u64> {
    if !rps.is_finite() {
        return vec![0; n];
    }
    assert!(rps > 0.0);
    let mean_cycles = freq_mhz * 1e6 / rps;
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // Exponential inter-arrival; 1−u ∈ (0, 1] keeps ln finite.
            t += -(1.0 - rng.next_f64()).ln() * mean_cycles;
            t.round() as u64
        })
        .collect()
}

/// Drive round-robin arrivals through per-queue [`DynamicBatcher`]s: fire
/// any flush deadline that elapsed before each arrival, push (which may trip
/// the size bound), and drain the leftovers at their deadlines. `serve` gets
/// `(queue index, batch, ready cycle)` for every emitted batch, in
/// chronological order per queue.
fn drive_batchers(
    batchers: &mut [DynamicBatcher<usize>],
    arrivals: &[u64],
    to_instant: &impl Fn(u64) -> Instant,
    to_cycles: &impl Fn(Instant) -> u64,
    mut serve: impl FnMut(usize, Vec<usize>, u64),
) {
    for (i, &a) in arrivals.iter().enumerate() {
        let b = i % batchers.len();
        // Fire any batching deadline that elapsed before this arrival.
        while let Some(dl) = batchers[b].next_deadline() {
            if to_cycles(dl) > a {
                break;
            }
            match batchers[b].poll(dl) {
                Some(batch) => serve(b, batch, to_cycles(dl)),
                None => break,
            }
        }
        if let Some(batch) = batchers[b].push(i, to_instant(a)) {
            serve(b, batch, a);
        }
    }
    // Remaining queues flush when their wait deadline fires.
    for (b, batcher) in batchers.iter_mut().enumerate() {
        if let Some(dl) = batcher.next_deadline() {
            let ready = to_cycles(dl);
            let batch = match batcher.poll(dl) {
                Some(batch) => batch,
                None => batcher.flush(),
            };
            serve(b, batch, ready);
        }
    }
}

/// Simulate `ccfg.requests` requests against a sharded fleet.
pub fn simulate_fleet(cfg: &AccelConfig, shard: &ShardPlan, ccfg: &ClusterConfig) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    let freq = cfg.platform.freq_mhz;
    let n = ccfg.requests;
    let arrivals = poisson_arrivals(n, ccfg.arrival_rps, freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let n_active = shard.used_boards();

    // Synthetic clock: the DynamicBatcher speaks `Instant`, the simulator
    // speaks cycles. One fixed origin maps between them deterministically.
    let t0 = Instant::now();
    let ns_per_cycle = 1e3 / freq;
    let to_instant = |c: u64| t0 + Duration::from_nanos((c as f64 * ns_per_cycle).round() as u64);
    let to_cycles =
        |i: Instant| (i.duration_since(t0).as_nanos() as f64 / ns_per_cycle).round() as u64;
    let policy = BatchPolicy {
        max_batch: ccfg.max_batch,
        max_wait: Duration::from_nanos((ccfg.max_wait_us * 1e3).round() as u64),
    };

    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;

    let (busy, batch_counts, item_counts) = match shard.mode {
        ShardMode::Replicated => {
            let nb = shard.used_boards();
            let mut batchers: Vec<DynamicBatcher<usize>> =
                (0..nb).map(|_| DynamicBatcher::new(policy)).collect();
            let mut free_at = vec![0u64; nb];
            let mut busy = vec![0u64; nb];
            drive_batchers(
                &mut batchers,
                &arrivals,
                &to_instant,
                &to_cycles,
                |b, batch, ready| {
                    let bsz = batch.len() as u64;
                    let svc = shard.shards[b].batch_cycles(bsz)
                        + shared.stall_cycles(shard.shards[b].traffic_bytes * bsz, n_active);
                    let start = ready.max(free_at[b]);
                    let done = start + svc;
                    free_at[b] = done;
                    busy[b] += svc;
                    for req in batch {
                        complete[req] = done;
                    }
                },
            );
            let batches: Vec<u64> = batchers.iter().map(|b| b.batches_emitted).collect();
            let items: Vec<u64> = batchers.iter().map(|b| b.items_processed).collect();
            (busy, batches, items)
        }
        ShardMode::Pipelined => {
            let stages = shard.used_boards();
            // One shared entry queue feeds stage 0; a batch then traverses
            // the whole board chain as a unit.
            let mut entry = vec![DynamicBatcher::<usize>::new(policy)];
            let mut free_at = vec![0u64; stages];
            let mut busy = vec![0u64; stages];
            drive_batchers(
                &mut entry,
                &arrivals,
                &to_instant,
                &to_cycles,
                |_, batch, ready| {
                    let bsz = batch.len() as u64;
                    let mut t = ready;
                    for (s, bs) in shard.shards.iter().enumerate() {
                        let svc = bs.batch_cycles(bsz)
                            + shared.stall_cycles(bs.traffic_bytes * bsz, n_active);
                        let start = t.max(free_at[s]);
                        let done = start + svc;
                        free_at[s] = done;
                        busy[s] += svc;
                        t = done;
                        if s + 1 < stages {
                            let bytes = bs.egress_bytes * bsz;
                            link_bytes_total += bytes;
                            t += link.transfer_cycles(bytes);
                        }
                    }
                    for req in batch {
                        complete[req] = t;
                    }
                },
            );
            let batches = vec![entry[0].batches_emitted; stages];
            let items = vec![entry[0].items_processed; stages];
            (busy, batches, items)
        }
    };

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| (c.saturating_sub(a)) as f64 * ns_per_cycle / 1e6)
        .collect();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..shard.used_boards())
        .map(|b| BoardStats {
            board: b,
            items: item_counts[b],
            batches: batch_counts[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
        })
        .collect();

    FleetReport {
        mode: shard.mode,
        boards: shard.boards,
        used_boards: shard.used_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown(n_active),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::engine::Weights;
    use crate::accel::fusion::FusionPlan;
    use crate::config::vgg16_prefix;

    fn setup() -> (AccelConfig, crate::config::Network, Weights) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        (AccelConfig::paper_default(), net, w)
    }

    fn burst_cfg(boards: usize, mode: ShardMode) -> ClusterConfig {
        ClusterConfig {
            boards,
            mode,
            link_bytes_per_cycle: f64::INFINITY,
            link_latency_cycles: 0,
            aggregate_ddr_bytes_per_cycle: None,
            arrival_rps: f64::INFINITY,
            requests: 96,
            seed: 7,
            max_batch: 1,
            max_wait_us: 0.0,
        }
    }

    #[test]
    fn poisson_arrivals_deterministic_and_monotone() {
        let a = poisson_arrivals(64, 1000.0, 120.0, 9);
        let b = poisson_arrivals(64, 1000.0, 120.0, 9);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival ≈ 120e6/1000 = 120k cycles; loose 3σ band.
        let mean = a.last().unwrap() / 64;
        assert!((40_000..400_000).contains(&mean), "mean gap {mean}");
        assert_eq!(poisson_arrivals(5, f64::INFINITY, 120.0, 1), vec![0; 5]);
    }

    #[test]
    fn replicated_burst_splits_work_evenly() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 4);
        let r = simulate_fleet(&cfg, &shard, &burst_cfg(4, ShardMode::Replicated));
        assert_eq!(r.completed, 96);
        assert_eq!(r.per_board.len(), 4);
        for b in &r.per_board {
            assert_eq!(b.items, 24, "round-robin split");
            assert!(b.utilization > 0.9, "burst keeps boards busy: {b:?}");
        }
        assert_eq!(r.link_bytes_total, 0);
        assert_eq!(r.ddr_slowdown, 1.0);
    }

    #[test]
    fn batching_amortizes_overheads() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7); // many groups → big fill/drain
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut c1 = burst_cfg(2, ShardMode::Replicated);
        c1.max_batch = 1;
        let mut c8 = c1.clone();
        c8.max_batch = 8;
        let r1 = simulate_fleet(&cfg, &shard, &c1);
        let r8 = simulate_fleet(&cfg, &shard, &c8);
        assert!(
            r8.throughput_rps > r1.throughput_rps,
            "batch 8 {} ≤ batch 1 {}",
            r8.throughput_rps,
            r1.throughput_rps
        );
    }

    #[test]
    fn contention_never_helps() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 8);
        let free = burst_cfg(8, ShardMode::Replicated);
        let mut tight = free.clone();
        // Pool worth two boards for an 8-board fleet → 4× slowdown.
        tight.aggregate_ddr_bytes_per_cycle = Some(2.0 * cfg.platform.ddr_bytes_per_cycle);
        let r_free = simulate_fleet(&cfg, &shard, &free);
        let r_tight = simulate_fleet(&cfg, &shard, &tight);
        assert!(r_tight.throughput_rps < r_free.throughput_rps);
        assert_eq!(r_tight.ddr_slowdown, 4.0);
        assert!(r_tight.p99_ms > r_free.p99_ms);
    }

    #[test]
    fn pipelined_burst_counts_link_bytes() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let shard = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let ccfg = burst_cfg(3, ShardMode::Pipelined);
        let r = simulate_fleet(&cfg, &shard, &ccfg);
        assert_eq!(r.completed, 96);
        assert_eq!(
            r.link_bytes_total,
            shard.link_bytes_per_item() * 96,
            "every item crosses every interior link exactly once"
        );
    }

    #[test]
    fn low_load_latency_near_service_time() {
        // At a trickle arrival rate with batch=1, each request is served
        // alone: latency ≈ single-inference cycles.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut ccfg = burst_cfg(2, ShardMode::Replicated);
        ccfg.requests = 32;
        ccfg.arrival_rps = 1.0; // one per second ≫ service time apart
        let r = simulate_fleet(&cfg, &shard, &ccfg);
        let svc_ms = shard.shards[0].item_cycles() as f64 / (cfg.platform.freq_mhz * 1e3);
        assert!(
            (r.p50_ms - svc_ms).abs() / svc_ms < 0.05,
            "p50 {} vs svc {}",
            r.p50_ms,
            svc_ms
        );
    }

    #[test]
    fn report_json_shape() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let r = simulate_fleet(&cfg, &shard, &burst_cfg(2, ShardMode::Replicated));
        let j = r.to_json();
        assert_eq!(j.get("mode").as_str(), Some("replicated"));
        assert_eq!(j.get("boards").as_usize(), Some(2));
        assert_eq!(j.get("per_board").as_arr().unwrap().len(), 2);
        assert!(j.get("throughput_rps").as_f64().unwrap() > 0.0);
    }
}
