//! Fleet request scheduler / queue simulator.
//!
//! Open-loop arrivals (Poisson via [`Rng`], or a saturating burst at t = 0,
//! optionally with mid-run load steps) are dispatched to per-board queues,
//! batched, and served with the shard planner's closed-form batch costs.
//! Boards may be heterogeneous: each shard carries its own clock and DDR
//! share, and all service times are converted onto one reference-clock
//! timeline. Off-chip phases stretch under the [`SharedDdr`] contention
//! model; pipelined stages forward batches across capacity-limited
//! [`LinkChannel`]s that serialize concurrent transfers — the link itself
//! can be the bottleneck stage. Everything is deterministic from the
//! config's seed.
//!
//! Two simulators share the reporting types:
//!
//! * [`simulate_fleet`] — the static scheduler: one shard plan for the whole
//!   run, per-board [`crate::coordinator::batcher::DynamicBatcher`]s driven
//!   with synthetic deterministic clocks.
//! * [`simulate_fleet_dynamic`] — the re-shard controller: greedy
//!   work-conserving batching plus a window monitor; when the observed p99
//!   or per-board utilization skew crosses the [`ReshardPolicy`] thresholds
//!   it re-plans the shard (replicated ↔ pipelined or new cut points),
//!   charges a migration bill (weights that change boards + in-flight
//!   activation state, over a link), and continues. Re-shards are reported
//!   as [`ReshardEvent`]s in the [`FleetReport`].
//!
//! Both inner loops are event driven ([`crate::cluster::events`]): batch
//! flush deadlines drain from a [`DeadlineQueue`] in time order, and the
//! dynamic dispatcher picks boards from a [`BoardPool`] busy/idle heap pair
//! instead of re-scanning the fleet per arrival — O(n log boards) for a
//! 16-board × 100k-arrival sweep. Reports are byte-identical to the
//! pre-rewrite linear walks, which survive in
//! [`crate::cluster::sim_legacy`] as the differential oracle.
//!
//! Time is measured in reference-clock cycles (u64) and converted to wall
//! time only for reporting.

use std::time::{Duration, Instant};

use crate::accel::engine::Weights;
use crate::config::{AccelConfig, ClusterConfig, LoadStep, Network, ReshardPolicy, ShardMode};
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use crate::fpga::ddr::SharedDdr;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats::percentile_sorted;

use super::events::{BoardPool, DeadlineQueue};
use super::link::{InterBoardLink, LinkChannel};
use super::shard::ShardPlan;

/// Per-board outcome counters.
#[derive(Debug, Clone)]
pub struct BoardStats {
    pub board: usize,
    pub items: u64,
    pub batches: u64,
    pub busy_cycles: u64,
    /// busy / makespan.
    pub utilization: f64,
    /// The board's clock — heterogeneous fleets mix generations.
    pub freq_mhz: f64,
}

/// One re-shard decision taken by the controller.
#[derive(Debug, Clone)]
pub struct ReshardEvent {
    /// Reference-clock cycle at which the migration began.
    pub at_cycle: u64,
    /// Labels of the outgoing and incoming shard plans.
    pub from: String,
    pub to: String,
    /// Which threshold fired.
    pub reason: String,
    /// Migration bill: weight bytes newly hosted per board plus in-flight
    /// activation state, after the policy's `migration_factor`.
    pub migration_bytes: u64,
    /// Cycles the whole fleet stalled while state moved.
    pub stall_cycles: u64,
}

impl ReshardEvent {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("at_cycle", self.at_cycle)
            .set("from", self.from.as_str())
            .set("to", self.to.as_str())
            .set("reason", self.reason.as_str())
            .set("migration_bytes", self.migration_bytes)
            .set("stall_cycles", self.stall_cycles)
    }
}

/// Outcome of one fleet simulation.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub mode: ShardMode,
    pub boards: usize,
    pub used_boards: usize,
    /// Provisioned boards left without work — a pipelined plan with fewer
    /// stages than boards wastes the difference.
    pub idle_boards: usize,
    pub requests: usize,
    pub completed: usize,
    pub makespan_cycles: u64,
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// [`simulate_fleet`] reports one entry per board the (fixed) plan
    /// uses; [`simulate_fleet_dynamic`] reports every provisioned board —
    /// under re-sharding a board idle in the *final* plan may still have
    /// served work earlier, so its counters must not be dropped. Consumers
    /// averaging utilization should filter on `busy_cycles > 0`.
    pub per_board: Vec<BoardStats>,
    /// Total bytes moved across inter-board links (0 for replicated).
    pub link_bytes_total: u64,
    /// The shared-DDR slowdown the fleet ran under (1.0 = uncontended).
    pub ddr_slowdown: f64,
    /// Re-shard decisions taken during the run (empty for the static
    /// scheduler).
    pub reshard_events: Vec<ReshardEvent>,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let mut boards = Json::Arr(vec![]);
        for b in &self.per_board {
            boards = boards.push(
                Json::obj()
                    .set("board", b.board)
                    .set("items", b.items)
                    .set("batches", b.batches)
                    .set("busy_cycles", b.busy_cycles)
                    .set("utilization", b.utilization)
                    .set("freq_mhz", b.freq_mhz),
            );
        }
        let mut events = Json::Arr(vec![]);
        for e in &self.reshard_events {
            events = events.push(e.to_json());
        }
        Json::obj()
            .set("mode", self.mode.as_str())
            .set("boards", self.boards)
            .set("used_boards", self.used_boards)
            .set("idle_boards", self.idle_boards)
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("makespan_cycles", self.makespan_cycles)
            .set("throughput_rps", self.throughput_rps)
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("link_bytes_total", self.link_bytes_total)
            .set("ddr_slowdown", self.ddr_slowdown)
            .set("reshard_events", events)
            .set("per_board", boards)
    }
}

/// Open-loop Poisson arrival times in cycles. A non-finite rate means a
/// saturating burst: every request arrives at t = 0.
pub fn poisson_arrivals(n: usize, rps: f64, freq_mhz: f64, seed: u64) -> Vec<u64> {
    arrivals_with_steps(n, rps, &[], freq_mhz, seed)
}

/// Poisson arrivals with traffic shifts: the rate starts at `base_rps` and
/// switches at each [`LoadStep`]'s request index. A non-finite rate makes
/// the affected requests arrive instantaneously (at the current clock —
/// t = 0 when the base rate is a burst). Deterministic in `seed`; the
/// no-step form is exactly [`poisson_arrivals`].
pub fn arrivals_with_steps(
    n: usize,
    base_rps: f64,
    steps: &[LoadStep],
    freq_mhz: f64,
    seed: u64,
) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut rate = base_rps;
    let mut step_i = 0usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        while step_i < steps.len() && steps[step_i].at_request <= i {
            rate = steps[step_i].rps;
            step_i += 1;
        }
        if rate.is_finite() {
            assert!(rate > 0.0);
            let mean_cycles = freq_mhz * 1e6 / rate;
            // Exponential inter-arrival; 1−u ∈ (0, 1] keeps ln finite.
            t += -(1.0 - rng.next_f64()).ln() * mean_cycles;
        }
        out.push(t.round() as u64);
    }
    out
}

/// Drive round-robin arrivals through per-queue [`DynamicBatcher`]s with an
/// event queue: a queue schedules one flush-deadline event whenever it turns
/// non-empty, and events drain fleet-wide in time order interleaved with
/// arrivals (instead of the old lazy per-queue re-check on every arrival).
/// `serve` gets `(queue index, batch, ready cycle)` for every emitted batch,
/// chronologically per queue — queues are independent, so the global
/// reordering leaves every served batch, and therefore the report,
/// byte-identical to the lazy walk (`sim_legacy` keeps that walk; the
/// equivalence tests diff the two).
fn drive_batchers(
    batchers: &mut [DynamicBatcher<usize>],
    arrivals: &[u64],
    to_instant: &impl Fn(u64) -> Instant,
    to_cycles: &impl Fn(Instant) -> u64,
    mut serve: impl FnMut(usize, Vec<usize>, u64),
) {
    let mut deadlines = DeadlineQueue::new();
    // Fire the deadline event for queue `q` at cycle `at`. Events can be
    // stale (a size-bound flush beat them); compare against the batcher's
    // live deadline before flushing. A later live deadline always has its
    // own event: one is scheduled on every empty→non-empty transition.
    let fire = |batchers: &mut [DynamicBatcher<usize>],
                q: usize,
                at: u64,
                serve: &mut dyn FnMut(usize, Vec<usize>, u64)| {
        match batchers[q].next_deadline() {
            Some(dl) if to_cycles(dl) == at => {
                let batch = match batchers[q].poll(dl) {
                    Some(batch) => batch,
                    None => batchers[q].flush(),
                };
                serve(q, batch, at);
            }
            _ => {} // stale event — the queue flushed by size in between
        }
    };

    for (i, &a) in arrivals.iter().enumerate() {
        let b = i % batchers.len();
        while let Some((at, q)) = deadlines.next_at_or_before(a) {
            fire(batchers, q, at, &mut serve);
        }
        let was_empty = batchers[b].is_empty();
        if let Some(batch) = batchers[b].push(i, to_instant(a)) {
            serve(b, batch, a);
        } else if was_empty {
            if let Some(dl) = batchers[b].next_deadline() {
                deadlines.schedule(to_cycles(dl), b);
            }
        }
    }
    // Drain: remaining non-empty queues flush at their scheduled deadlines.
    while let Some((at, q)) = deadlines.pop() {
        fire(batchers, q, at, &mut serve);
    }
}

/// Aggregate off-chip demand of a plan's active boards, in bytes per
/// reference cycle (each board's provisioned rate rescaled by its clock).
pub(crate) fn fleet_demand(plan: &ShardPlan, ref_freq: f64) -> f64 {
    plan.shards
        .iter()
        .map(|s| s.ddr_bytes_per_cycle * s.freq_mhz / ref_freq)
        .sum()
}

/// Simulate `ccfg.requests` requests against a sharded fleet with a fixed
/// plan for the whole run.
pub fn simulate_fleet(cfg: &AccelConfig, shard: &ShardPlan, ccfg: &ClusterConfig) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    let ref_freq = cfg.platform.freq_mhz;
    let n = ccfg.requests;
    let arrivals = arrivals_with_steps(n, ccfg.arrival_rps, &ccfg.load_steps, ref_freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let demand = fleet_demand(shard, ref_freq);

    // Synthetic clock: the DynamicBatcher speaks `Instant`, the simulator
    // speaks cycles. One fixed origin maps between them deterministically.
    let t0 = Instant::now();
    let ns_per_cycle = 1e3 / ref_freq;
    let to_instant = |c: u64| t0 + Duration::from_nanos((c as f64 * ns_per_cycle).round() as u64);
    let to_cycles =
        |i: Instant| (i.duration_since(t0).as_nanos() as f64 / ns_per_cycle).round() as u64;
    let policy = BatchPolicy {
        max_batch: ccfg.max_batch,
        max_wait: Duration::from_nanos((ccfg.max_wait_us * 1e3).round() as u64),
    };

    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;

    let service =
        |s: &super::shard::BoardShard, bsz: u64| s.service_cycles(bsz, ref_freq, &shared, demand);

    let (busy, batch_counts, item_counts) = match shard.mode {
        ShardMode::Replicated => {
            let nb = shard.used_boards();
            let mut batchers: Vec<DynamicBatcher<usize>> =
                (0..nb).map(|_| DynamicBatcher::new(policy)).collect();
            let mut free_at = vec![0u64; nb];
            let mut busy = vec![0u64; nb];
            drive_batchers(
                &mut batchers,
                &arrivals,
                &to_instant,
                &to_cycles,
                |b, batch, ready| {
                    let bsz = batch.len() as u64;
                    let svc = service(&shard.shards[b], bsz);
                    let start = ready.max(free_at[b]);
                    let done = start + svc;
                    free_at[b] = done;
                    busy[b] += svc;
                    for req in batch {
                        complete[req] = done;
                    }
                },
            );
            let batches: Vec<u64> = batchers.iter().map(|b| b.batches_emitted).collect();
            let items: Vec<u64> = batchers.iter().map(|b| b.items_processed).collect();
            (busy, batches, items)
        }
        ShardMode::Pipelined => {
            let stages = shard.used_boards();
            // One shared entry queue feeds stage 0; a batch then traverses
            // the whole board chain as a unit, and each cut's transfers
            // serialize on that cut's own capacity-limited channel.
            let mut entry = vec![DynamicBatcher::<usize>::new(policy)];
            let mut free_at = vec![0u64; stages];
            let mut busy = vec![0u64; stages];
            let mut links: Vec<LinkChannel> = (0..stages.saturating_sub(1))
                .map(|_| LinkChannel::new(link))
                .collect();
            drive_batchers(
                &mut entry,
                &arrivals,
                &to_instant,
                &to_cycles,
                |_, batch, ready| {
                    let bsz = batch.len() as u64;
                    let mut t = ready;
                    for (s, bs) in shard.shards.iter().enumerate() {
                        let svc = service(bs, bsz);
                        let start = t.max(free_at[s]);
                        let done = start + svc;
                        free_at[s] = done;
                        busy[s] += svc;
                        t = done;
                        if s + 1 < stages {
                            let bytes = bs.egress_bytes * bsz;
                            link_bytes_total += bytes;
                            t = links[s].transfer(bytes, t);
                        }
                    }
                    for req in batch {
                        complete[req] = t;
                    }
                },
            );
            let batches = vec![entry[0].batches_emitted; stages];
            let items = vec![entry[0].items_processed; stages];
            (busy, batches, items)
        }
    };

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| (c.saturating_sub(a)) as f64 * ns_per_cycle / 1e6)
        .collect();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..shard.used_boards())
        .map(|b| BoardStats {
            board: b,
            items: item_counts[b],
            batches: batch_counts[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: shard.shards[b].freq_mhz,
        })
        .collect();

    FleetReport {
        mode: shard.mode,
        boards: shard.boards,
        used_boards: shard.used_boards(),
        idle_boards: shard.idle_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events: Vec::new(),
    }
}

/// Map `[board][layer] → hosted?` for a plan (replicated shards host every
/// layer; pipelined shards host their stage's range).
fn hosting(plan: &ShardPlan, n_layers: usize, nb: usize) -> Vec<Vec<bool>> {
    let mut h = vec![vec![false; n_layers]; nb];
    for s in &plan.shards {
        for l in s.layers.clone() {
            h[s.board][l] = true;
        }
    }
    h
}

/// Bytes a plan switch moves over links: weights for every layer a board
/// newly hosts, plus one pipeline's worth of in-flight activation state at
/// the new cuts. Per-layer weight bytes are derived once up front
/// ([`Weights::per_layer_bytes`]) instead of re-walking the banks inside
/// the boards × layers loop.
pub(crate) fn migration_bytes(
    old: &ShardPlan,
    new: &ShardPlan,
    weights: &Weights,
    word_bytes: usize,
    n_layers: usize,
    nb: usize,
) -> u64 {
    let oldh = hosting(old, n_layers, nb);
    let newh = hosting(new, n_layers, nb);
    let layer_bytes = weights.per_layer_bytes(word_bytes);
    let mut bytes = new.link_bytes_per_item();
    for b in 0..nb {
        for l in 0..n_layers {
            if newh[b][l] && !oldh[b][l] {
                bytes += layer_bytes[l];
            }
        }
    }
    bytes
}

/// Simulate a fleet under the re-shard controller.
///
/// Starts from `initial` (which may be deliberately naive — e.g. cuts
/// balanced under a homogeneous-fleet assumption) and processes arrivals
/// with greedy work-conserving batching: a board takes up to `max_batch`
/// requests that have arrived by the time it can start. After every
/// [`ReshardPolicy::window`] completions the controller evaluates the
/// window's p99 and per-board utilization skew; past a threshold it
/// re-plans on the actual fleet, bills the migration (weights + activation
/// state over a link, fleet-wide stall), swaps plans, and continues. With
/// `ccfg.reshard = None` this is a plain greedy-batching simulator — use
/// the same engine for the static baseline when comparing against the
/// controller.
pub fn simulate_fleet_dynamic(
    cfg: &AccelConfig,
    fleet: &[AccelConfig],
    net: &Network,
    weights: &Weights,
    initial: ShardPlan,
    ccfg: &ClusterConfig,
) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    assert!(!fleet.is_empty());
    assert!(
        initial.used_boards() <= fleet.len(),
        "initial plan uses more boards than the fleet has"
    );
    let ref_freq = cfg.platform.freq_mhz;
    let ns_per_cycle = 1e3 / ref_freq;
    let n = ccfg.requests;
    let arrivals = arrivals_with_steps(n, ccfg.arrival_rps, &ccfg.load_steps, ref_freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let nb = fleet.len();
    let word_bytes = cfg.platform.word_bytes;
    let n_layers = net.layers.len();

    let mut plan = initial;
    let mut links: Vec<LinkChannel> = (0..plan.used_boards().saturating_sub(1))
        .map(|_| LinkChannel::new(link))
        .collect();
    let mut demand = fleet_demand(&plan, ref_freq);

    // Earliest-start board selection for the replicated arm: a busy/idle
    // heap pair instead of scanning every shard per batch. Rebuilt on every
    // plan swap (shard set and free_at both change).
    let pool_of = |plan: &ShardPlan, free_at: &[u64]| {
        BoardPool::from_slots(plan.shards.iter().map(|s| (s.freq_mhz, free_at[s.board])))
    };

    let mut free_at = vec![0u64; nb];
    let mut busy = vec![0u64; nb];
    let mut items = vec![0u64; nb];
    let mut batches = vec![0u64; nb];
    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;
    let mut events: Vec<ReshardEvent> = Vec::new();

    // Controller window state. `sim_now` is the furthest completion seen —
    // batch completions are not themselves monotone on a heterogeneous
    // fleet (a fast board finishes later-dispatched work earlier), and the
    // window span must never collapse to zero.
    let policy: Option<ReshardPolicy> = ccfg.reshard.clone();
    let mut win_lat_ms: Vec<f64> = Vec::new();
    let mut win_start = 0u64;
    let mut win_busy0 = busy.clone();
    let mut cooldown = 0usize;
    let mut sim_now = 0u64;
    let mut pool = pool_of(&plan, &free_at);

    let mut i = 0usize;
    while i < n {
        // ---- dispatch one batch, greedy and work-conserving ----
        let (batch_done, batch_len) = match plan.mode {
            ShardMode::Replicated => {
                let a = arrivals[i];
                // The board that can start soonest; ties go to the faster
                // clock, then the lower index (the pool reproduces the old
                // linear scan's tie-breaks exactly).
                let (pick, start) = pool.pick(a);
                let s = &plan.shards[pick];
                let mut k = 1usize;
                while i + k < n && k < ccfg.max_batch && arrivals[i + k] <= start {
                    k += 1;
                }
                let bsz = k as u64;
                let svc = s.service_cycles(bsz, ref_freq, &shared, demand);
                let done = start + svc;
                free_at[s.board] = done;
                pool.release(pick, done);
                busy[s.board] += svc;
                items[s.board] += bsz;
                batches[s.board] += 1;
                for c in complete.iter_mut().skip(i).take(k) {
                    *c = done;
                }
                (done, k)
            }
            ShardMode::Pipelined => {
                let a = arrivals[i];
                let first = plan.shards[0].board;
                let start0 = free_at[first].max(a);
                let mut k = 1usize;
                while i + k < n && k < ccfg.max_batch && arrivals[i + k] <= start0 {
                    k += 1;
                }
                let bsz = k as u64;
                let stages = plan.used_boards();
                let mut t = start0;
                for (si, s) in plan.shards.iter().enumerate() {
                    let svc = s.service_cycles(bsz, ref_freq, &shared, demand);
                    let start = t.max(free_at[s.board]);
                    let done = start + svc;
                    free_at[s.board] = done;
                    busy[s.board] += svc;
                    items[s.board] += bsz;
                    batches[s.board] += 1;
                    t = done;
                    if si + 1 < stages {
                        let bytes = s.egress_bytes * bsz;
                        link_bytes_total += bytes;
                        t = links[si].transfer(bytes, t);
                    }
                }
                for c in complete.iter_mut().skip(i).take(k) {
                    *c = t;
                }
                (t, k)
            }
        };

        for j in i..i + batch_len {
            win_lat_ms
                .push(complete[j].saturating_sub(arrivals[j]) as f64 * ns_per_cycle / 1e6);
        }
        i += batch_len;
        sim_now = sim_now.max(batch_done);

        // ---- controller: evaluate the window ----
        let Some(pol) = &policy else { continue };
        if win_lat_ms.len() < pol.window {
            continue;
        }
        let now = sim_now;
        let span = now.saturating_sub(win_start);
        let mut sorted = win_lat_ms.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let p99 = percentile_sorted(&sorted, 99.0);
        let mut skew = 0.0f64;
        if span > 0 {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for s in &plan.shards {
                let u = busy[s.board].saturating_sub(win_busy0[s.board]) as f64 / span as f64;
                lo = lo.min(u);
                hi = hi.max(u);
            }
            skew = hi - lo;
        }
        if cooldown > 0 {
            cooldown -= 1;
        } else if p99 > pol.p99_ms || skew > pol.util_skew {
            let reason = if p99 > pol.p99_ms {
                format!("window p99 {p99:.1} ms > {:.1} ms", pol.p99_ms)
            } else {
                format!("utilization skew {skew:.2} > {:.2}", pol.util_skew)
            };
            // Re-plan on the actual fleet: both modes, ranked by predicted
            // capacity; only feasible candidates compete.
            let mut best: Option<(f64, ShardPlan)> = None;
            for cand in [
                ShardPlan::replicated_fleet(fleet, net, weights, &plan.plan),
                ShardPlan::pipelined_fleet(fleet, net, weights, &plan.plan),
            ] {
                if !cand.fits() {
                    continue;
                }
                let cap = cand.capacity_rps(ccfg.max_batch, &link, ref_freq);
                let better = match &best {
                    None => true,
                    Some((b, _)) => cap > *b,
                };
                if better {
                    best = Some((cap, cand));
                }
            }
            if let Some((_, new_plan)) = best {
                if new_plan.label() != plan.label() {
                    let raw = migration_bytes(&plan, &new_plan, weights, word_bytes, n_layers, nb);
                    let bill = (raw as f64 * pol.migration_factor).round() as u64;
                    let stall = link.transfer_cycles(bill);
                    // The whole fleet pauses: drain to the latest busy
                    // board, move state, resume together.
                    let sync = free_at.iter().copied().max().unwrap_or(now).max(now);
                    for f in &mut free_at {
                        *f = sync + stall;
                    }
                    events.push(ReshardEvent {
                        at_cycle: sync,
                        from: plan.label(),
                        to: new_plan.label(),
                        reason,
                        migration_bytes: bill,
                        stall_cycles: stall,
                    });
                    links = (0..new_plan.used_boards().saturating_sub(1))
                        .map(|_| LinkChannel::new(link))
                        .collect();
                    plan = new_plan;
                    demand = fleet_demand(&plan, ref_freq);
                    pool = pool_of(&plan, &free_at);
                    cooldown = pol.cooldown_windows;
                }
            }
        }
        win_lat_ms.clear();
        win_start = now;
        win_busy0.copy_from_slice(&busy);
    }

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| c.saturating_sub(a) as f64 * ns_per_cycle / 1e6)
        .collect();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..nb)
        .map(|b| BoardStats {
            board: b,
            items: items[b],
            batches: batches[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: fleet[b].platform.freq_mhz,
        })
        .collect();

    FleetReport {
        mode: plan.mode,
        boards: nb,
        used_boards: plan.used_boards(),
        idle_boards: nb - plan.used_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events: events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::engine::Weights;
    use crate::accel::fusion::FusionPlan;
    use crate::config::{vgg16_prefix, Platform};

    fn setup() -> (AccelConfig, crate::config::Network, Weights) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        (AccelConfig::paper_default(), net, w)
    }

    fn slow_gen() -> AccelConfig {
        AccelConfig {
            platform: Platform::virtex7_older_gen(),
            ..AccelConfig::paper_default()
        }
    }

    fn burst_cfg(boards: usize, mode: ShardMode) -> ClusterConfig {
        ClusterConfig {
            boards,
            mode,
            board_specs: vec![],
            link_bytes_per_cycle: f64::INFINITY,
            link_latency_cycles: 0,
            aggregate_ddr_bytes_per_cycle: None,
            arrival_rps: f64::INFINITY,
            load_steps: vec![],
            requests: 96,
            seed: 7,
            max_batch: 1,
            max_wait_us: 0.0,
            reshard: None,
        }
    }

    #[test]
    fn poisson_arrivals_deterministic_and_monotone() {
        let a = poisson_arrivals(64, 1000.0, 120.0, 9);
        let b = poisson_arrivals(64, 1000.0, 120.0, 9);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival ≈ 120e6/1000 = 120k cycles; loose 3σ band.
        let mean = a.last().unwrap() / 64;
        assert!((40_000..400_000).contains(&mean), "mean gap {mean}");
        assert_eq!(poisson_arrivals(5, f64::INFINITY, 120.0, 1), vec![0; 5]);
    }

    #[test]
    fn poisson_arrivals_seed_sensitivity() {
        // Same seed → bit-identical; different seeds → different sample
        // paths (the determinism CI leans on).
        let a = poisson_arrivals(128, 500.0, 120.0, 42);
        let b = poisson_arrivals(128, 500.0, 120.0, 42);
        let c = poisson_arrivals(128, 500.0, 120.0, 43);
        assert_eq!(a, b);
        assert_ne!(a, c, "distinct seeds must sample distinct paths");
        // And the empty-steps form is exactly the classic generator.
        let d = arrivals_with_steps(128, 500.0, &[], 120.0, 42);
        assert_eq!(a, d);
    }

    #[test]
    fn load_step_speeds_up_arrivals() {
        let steps = [LoadStep {
            at_request: 64,
            rps: 4000.0,
        }];
        let a = arrivals_with_steps(128, 200.0, &steps, 120.0, 5);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "monotone");
        // Mean gap before the step ≫ mean gap after it.
        let pre_span = (a[63] - a[0]) as f64 / 63.0;
        let post_span = (a[127] - a[64]) as f64 / 63.0;
        assert!(
            pre_span > 4.0 * post_span,
            "step must densify arrivals: pre {pre_span:.0} post {post_span:.0}"
        );
        // Deterministic too.
        assert_eq!(a, arrivals_with_steps(128, 200.0, &steps, 120.0, 5));
    }

    #[test]
    fn replicated_burst_splits_work_evenly() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 4);
        let r = simulate_fleet(&cfg, &shard, &burst_cfg(4, ShardMode::Replicated));
        assert_eq!(r.completed, 96);
        assert_eq!(r.per_board.len(), 4);
        for b in &r.per_board {
            assert_eq!(b.items, 24, "round-robin split");
            assert!(b.utilization > 0.9, "burst keeps boards busy: {b:?}");
        }
        assert_eq!(r.link_bytes_total, 0);
        assert_eq!(r.ddr_slowdown, 1.0);
        assert_eq!(r.idle_boards, 0);
        assert!(r.reshard_events.is_empty());
    }

    #[test]
    fn batching_amortizes_overheads() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7); // many groups → big fill/drain
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut c1 = burst_cfg(2, ShardMode::Replicated);
        c1.max_batch = 1;
        let mut c8 = c1.clone();
        c8.max_batch = 8;
        let r1 = simulate_fleet(&cfg, &shard, &c1);
        let r8 = simulate_fleet(&cfg, &shard, &c8);
        assert!(
            r8.throughput_rps > r1.throughput_rps,
            "batch 8 {} ≤ batch 1 {}",
            r8.throughput_rps,
            r1.throughput_rps
        );
    }

    #[test]
    fn contention_never_helps() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 8);
        let free = burst_cfg(8, ShardMode::Replicated);
        let mut tight = free.clone();
        // Pool worth two boards for an 8-board fleet → 4× slowdown.
        tight.aggregate_ddr_bytes_per_cycle = Some(2.0 * cfg.platform.ddr_bytes_per_cycle);
        let r_free = simulate_fleet(&cfg, &shard, &free);
        let r_tight = simulate_fleet(&cfg, &shard, &tight);
        assert!(r_tight.throughput_rps < r_free.throughput_rps);
        assert_eq!(r_tight.ddr_slowdown, 4.0);
        assert!(r_tight.p99_ms > r_free.p99_ms);
    }

    #[test]
    fn pipelined_burst_counts_link_bytes() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let shard = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let ccfg = burst_cfg(3, ShardMode::Pipelined);
        let r = simulate_fleet(&cfg, &shard, &ccfg);
        assert_eq!(r.completed, 96);
        assert_eq!(
            r.link_bytes_total,
            shard.link_bytes_per_item() * 96,
            "every item crosses every interior link exactly once"
        );
    }

    #[test]
    fn finite_links_serialize_and_slow_the_pipeline() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let shard = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let ideal = burst_cfg(3, ShardMode::Pipelined);
        let mut tight = ideal.clone();
        tight.link_bytes_per_cycle = 0.05; // starved wire
        tight.link_latency_cycles = 500;
        let r_ideal = simulate_fleet(&cfg, &shard, &ideal);
        let r_tight = simulate_fleet(&cfg, &shard, &tight);
        assert!(
            r_tight.throughput_rps < r_ideal.throughput_rps,
            "a starved link must become the bottleneck: {} vs {}",
            r_tight.throughput_rps,
            r_ideal.throughput_rps
        );
        assert_eq!(r_tight.link_bytes_total, r_ideal.link_bytes_total);
    }

    #[test]
    fn hetero_fleet_slower_boards_do_less_replicated_work() {
        // 2 fast + 2 slow replicated boards under the dynamic greedy
        // dispatcher: the fast boards absorb more items.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), cfg.clone(), slow_gen(), slow_gen()];
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated_fleet(&fleet, &net, &w, &plan);
        let mut ccfg = burst_cfg(4, ShardMode::Replicated);
        ccfg.requests = 128;
        ccfg.max_batch = 4;
        let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &ccfg);
        assert_eq!(r.completed, 128);
        let fast_items: u64 = r.per_board[..2].iter().map(|b| b.items).sum();
        let slow_items: u64 = r.per_board[2..].iter().map(|b| b.items).sum();
        assert!(
            fast_items > slow_items,
            "fast boards must absorb more work: {fast_items} vs {slow_items}"
        );
    }

    #[test]
    fn low_load_latency_near_service_time() {
        // At a trickle arrival rate with batch=1, each request is served
        // alone: latency ≈ single-inference cycles.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut ccfg = burst_cfg(2, ShardMode::Replicated);
        ccfg.requests = 32;
        ccfg.arrival_rps = 1.0; // one per second ≫ service time apart
        let r = simulate_fleet(&cfg, &shard, &ccfg);
        let svc_ms = shard.shards[0].item_cycles() as f64 / (cfg.platform.freq_mhz * 1e3);
        assert!(
            (r.p50_ms - svc_ms).abs() / svc_ms < 0.05,
            "p50 {} vs svc {}",
            r.p50_ms,
            svc_ms
        );
    }

    #[test]
    fn dynamic_without_policy_is_a_plain_scheduler() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let fleet = vec![cfg.clone(); 3];
        let shard = ShardPlan::pipelined_fleet(&fleet, &net, &w, &plan);
        let mut ccfg = burst_cfg(3, ShardMode::Pipelined);
        ccfg.requests = 48;
        let r1 = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard.clone(), &ccfg);
        let r2 = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &ccfg);
        assert_eq!(r1.completed, 48);
        assert!(r1.reshard_events.is_empty());
        assert_eq!(r1.makespan_cycles, r2.makespan_cycles, "deterministic");
        assert!(r1.throughput_rps > 0.0);
    }

    #[test]
    fn controller_reshards_away_from_a_bad_plan() {
        // Start from a deliberately terrible pipelined split on a hetero
        // fleet and set a hair-trigger p99 threshold: the controller must
        // fire, migrate, and end on a different plan.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), slow_gen()];
        let plan = FusionPlan::unfused(7);
        // Worst naive cut: everything but one group on the slow board.
        let bad = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &w, &plan, &[0, 1, 7]);
        let mut ccfg = burst_cfg(2, ShardMode::Pipelined);
        ccfg.requests = 160;
        ccfg.max_batch = 4;
        ccfg.reshard = Some(ReshardPolicy {
            window: 16,
            util_skew: 0.9,
            p99_ms: 0.001, // anything trips it
            cooldown_windows: 1,
            migration_factor: 1.0,
        });
        let from_label = bad.label();
        let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, bad, &ccfg);
        assert!(
            !r.reshard_events.is_empty(),
            "hair-trigger policy must fire at least once"
        );
        let e = &r.reshard_events[0];
        assert_eq!(e.from, from_label);
        assert_ne!(e.from, e.to);
        assert!(e.migration_bytes > 0);
        assert!(e.stall_cycles > 0 || ccfg.link_latency_cycles == 0);
        // JSON carries the events and idle-board accounting.
        let j = r.to_json();
        assert_eq!(
            j.get("reshard_events").as_arr().unwrap().len(),
            r.reshard_events.len()
        );
        assert_eq!(
            j.get("idle_boards").as_usize(),
            Some(r.idle_boards),
        );
    }

    /// Full-report byte equality between the event-queue simulator and the
    /// pre-rewrite linear walk (`sim_legacy`), across the scenario classes:
    /// burst and Poisson arrivals, both shard modes, finite links, load
    /// steps, time-based batch flushes.
    #[test]
    fn event_queue_static_sim_is_byte_identical_to_legacy() {
        let (cfg, net, w) = setup();
        let fused = FusionPlan::fully_fused(7);
        let unfused = FusionPlan::unfused(7);

        // Poisson arrivals with batching deadlines (time flushes fire).
        let mut poisson = burst_cfg(3, ShardMode::Replicated);
        poisson.arrival_rps = 2000.0;
        poisson.requests = 200;
        poisson.max_batch = 8;
        poisson.max_wait_us = 150.0;
        // Pipelined over finite serializing links.
        let mut piped = burst_cfg(3, ShardMode::Pipelined);
        piped.link_bytes_per_cycle = 8.0;
        piped.link_latency_cycles = 200;
        piped.max_batch = 4;
        // Load-step traffic with contention.
        let mut stepped = burst_cfg(2, ShardMode::Replicated);
        stepped.arrival_rps = 500.0;
        stepped.load_steps = vec![LoadStep {
            at_request: 48,
            rps: 4000.0,
        }];
        stepped.requests = 128;
        stepped.max_batch = 8;
        stepped.max_wait_us = 200.0;
        stepped.aggregate_ddr_bytes_per_cycle = Some(96.0);

        let scenarios: Vec<(ShardPlan, ClusterConfig)> = vec![
            (
                ShardPlan::replicated(&cfg, &net, &w, &fused, 4),
                burst_cfg(4, ShardMode::Replicated),
            ),
            (ShardPlan::replicated(&cfg, &net, &w, &fused, 3), poisson),
            (ShardPlan::pipelined(&cfg, &net, &w, &unfused, 3), piped),
            (ShardPlan::replicated(&cfg, &net, &w, &fused, 2), stepped),
        ];

        for (i, (shard, ccfg)) in scenarios.into_iter().enumerate() {
            let fast = simulate_fleet(&cfg, &shard, &ccfg).to_json().to_string_pretty();
            let slow = crate::cluster::sim_legacy::simulate_fleet(&cfg, &shard, &ccfg)
                .to_json()
                .to_string_pretty();
            assert_eq!(fast, slow, "scenario {i} diverged from the legacy simulator");
        }
    }

    #[test]
    fn event_queue_dynamic_sim_is_byte_identical_to_legacy() {
        let (cfg, net, w) = setup();
        let fused = FusionPlan::fully_fused(7);
        let fleet = vec![cfg.clone(), cfg.clone(), slow_gen(), slow_gen()];

        // Greedy hetero dispatch, no controller.
        let shard = ShardPlan::replicated_fleet(&fleet, &net, &w, &fused);
        let mut ccfg = burst_cfg(4, ShardMode::Replicated);
        ccfg.requests = 160;
        ccfg.max_batch = 4;
        let fast = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard.clone(), &ccfg)
            .to_json()
            .to_string_pretty();
        let slow =
            crate::cluster::sim_legacy::simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &ccfg)
                .to_json()
                .to_string_pretty();
        assert_eq!(fast, slow, "hetero greedy dispatch diverged");

        // Controller firing: bad pipelined cuts + hair-trigger policy (the
        // PR-2 re-shard fixture) — plan swaps, pool rebuilds, stall billing.
        let plan = FusionPlan::unfused(7);
        let hetero2 = vec![cfg.clone(), slow_gen()];
        let bad = ShardPlan::pipelined_fleet_with_cuts(&hetero2, &net, &w, &plan, &[0, 1, 7]);
        let mut dyn_cfg = burst_cfg(2, ShardMode::Pipelined);
        dyn_cfg.requests = 160;
        dyn_cfg.max_batch = 4;
        dyn_cfg.reshard = Some(ReshardPolicy {
            window: 16,
            util_skew: 0.9,
            p99_ms: 0.001,
            cooldown_windows: 1,
            migration_factor: 1.0,
        });
        let fast = simulate_fleet_dynamic(&cfg, &hetero2, &net, &w, bad.clone(), &dyn_cfg);
        assert!(!fast.reshard_events.is_empty(), "fixture must exercise a re-shard");
        let slow = crate::cluster::sim_legacy::simulate_fleet_dynamic(
            &cfg, &hetero2, &net, &w, bad, &dyn_cfg,
        );
        assert_eq!(
            fast.to_json().to_string_pretty(),
            slow.to_json().to_string_pretty(),
            "re-shard controller diverged"
        );
    }

    #[test]
    fn report_json_shape() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let r = simulate_fleet(&cfg, &shard, &burst_cfg(2, ShardMode::Replicated));
        let j = r.to_json();
        assert_eq!(j.get("mode").as_str(), Some("replicated"));
        assert_eq!(j.get("boards").as_usize(), Some(2));
        assert_eq!(j.get("idle_boards").as_usize(), Some(0));
        assert_eq!(j.get("per_board").as_arr().unwrap().len(), 2);
        assert!(j.get("throughput_rps").as_f64().unwrap() > 0.0);
        assert!(j.get("reshard_events").as_arr().unwrap().is_empty());
    }
}
