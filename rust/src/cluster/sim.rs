//! Fleet request scheduler / queue simulator.
//!
//! Open-loop arrivals (Poisson via [`Rng`], or a saturating burst at t = 0,
//! optionally with mid-run load steps) are dispatched to per-board queues,
//! batched, and served with the shard planner's closed-form batch costs.
//! Boards may be heterogeneous: each shard carries its own clock and DDR
//! share, and all service times are converted onto one reference-clock
//! timeline. Off-chip phases stretch under the [`SharedDdr`] contention
//! model; pipelined stages forward batches across capacity-limited
//! [`LinkChannel`]s that serialize concurrent transfers — the link itself
//! can be the bottleneck stage. Everything is deterministic from the
//! config's seed.
//!
//! Three simulators share the reporting types:
//!
//! * [`simulate_fleet`] — the static scheduler: one shard plan for the whole
//!   run, per-board [`crate::coordinator::batcher::DynamicBatcher`]s driven
//!   with synthetic deterministic clocks.
//! * [`simulate_fleet_dynamic`] — the re-shard controller: greedy
//!   work-conserving batching plus a window monitor; when the observed p99
//!   or per-board utilization skew crosses the [`ReshardPolicy`] thresholds
//!   it re-plans the shard (replicated ↔ pipelined or new cut points),
//!   charges a migration bill (weights that change boards + in-flight
//!   activation state, over a link), and continues. Re-shards are reported
//!   as [`ReshardEvent`]s in the [`FleetReport`].
//! * [`simulate_fleet_multi_tenant`] — the unified control plane: several
//!   networks sharing one fleet under strict priorities, with
//!   deficit-weighted round-robin fair sharing *within* a class
//!   (`SloPolicy::weight`), work-preserving or restart preemption of
//!   lower-priority batches (`PreemptMode`), and — when `ccfg.reshard` is
//!   armed — the window triggers of the dynamic controller made
//!   tenant-aware: per-tenant window p99 against each tenant's own SLO,
//!   mid-run `place_tenants` re-runs biased toward the coolest boards with
//!   SLO-missing tenants uncapped, migration billing per tenant, and
//!   per-tenant [`ReshardEvent`]s. Per-tenant p50/p99/SLO attainment lands
//!   in [`FleetReport::tenants`] as [`TenantStats`].
//!
//! All inner loops are event driven ([`crate::cluster::events`]): batch
//! flush deadlines drain from a [`DeadlineQueue`] in time order, and the
//! dynamic dispatcher picks boards from a [`BoardPool`] busy/idle heap pair
//! instead of re-scanning the fleet per arrival — O(n log boards) for a
//! 16-board × 100k-arrival sweep. The pre-rewrite linear walks retired once
//! the event-queue forms proved byte-identical; the committed golden
//! fixtures under `tests/fixtures/` are the regression oracle now.
//!
//! Time is measured in reference-clock cycles (u64) and converted to wall
//! time only for reporting.
//!
//! Every simulator has a `*_traced` twin taking a
//! [`super::telemetry::TraceSink`]; the plain entry points forward a
//! disabled sink, so tracing costs one branch per record site unless armed
//! — which is what keeps the committed golden fixtures byte-identical.
//! With an armed sink the run additionally emits typed [`TraceEvent`]s,
//! [`WindowSample`] time-series at the controller's window boundaries, and
//! per-tenant latency sketches, and the report carries a
//! [`super::telemetry::TelemetrySummary`].

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::accel::engine::Weights;
use crate::accel::fusion::FusionPlan;
use crate::config::{
    AccelConfig, ClusterConfig, FaultEvent, LoadStep, Network, PreemptMode, ReshardPolicy,
    ShardMode, TenantSpec,
};
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use crate::fpga::ddr::SharedDdr;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats::percentile_sorted;

use super::events::{BoardPool, DeadlineQueue};
use super::fabric::{Fabric, FabricSummary};
use super::link::{InterBoardLink, LinkChannel};
use super::shard::{place_tenants_capacity_fabric, ShardPlan, TenantWorkload};
use super::telemetry::{QuantileSketch, TelemetrySummary, TraceEvent, TraceSink, WindowSample};

/// Sort a latency population for percentile extraction. `total_cmp` instead
/// of `partial_cmp(..).unwrap()`: healthy populations are finite and
/// non-negative, but a degenerate window (e.g. a 0-capacity degrade
/// producing a NaN-adjacent ratio) must degrade to a defined order — NaNs
/// sort last under the IEEE-754 total order — rather than panic mid-run.
/// On NaN-free data the order is identical to the comparator it replaced.
fn sort_latencies(v: &mut [f64]) {
    v.sort_by(f64::total_cmp);
}

/// Checked f64 → u64 conversion shared by every wall-clock → cycle (and
/// byte-bill) rounding below. A bare `as u64` silently saturates on
/// negative, NaN, or overflowing inputs — producing a billion-year timeline
/// instead of an error — so reject anything outside the representable
/// range with the offending value in the panic message.
pub(crate) fn checked_round_u64(x: f64, what: &str) -> u64 {
    let r = x.round();
    assert!(
        r.is_finite() && r >= 0.0 && r < u64::MAX as f64,
        "{what}: {x} does not round into the u64 timeline"
    );
    r as u64
}

/// Wall-clock milliseconds onto the reference-cycle timeline. Exactly
/// `(ms * ref_freq_mhz * 1e3).round()` — the arithmetic the fault-timeline
/// tests pin — with the saturating cast replaced by [`checked_round_u64`].
pub(crate) fn ms_to_cycles_checked(ms: f64, ref_freq_mhz: f64) -> u64 {
    checked_round_u64(ms * ref_freq_mhz * 1e3, "ms_to_cycles")
}

/// Reusable scratch buffers for the simulator inner loops — reset, never
/// reallocated, at each window boundary / dispatch round. One instance
/// lives per simulation run; only the allocations survive a use site, the
/// values never do.
#[derive(Debug, Default)]
struct SimScratch {
    /// Window latency sort buffer for the exact percentile paths.
    sort_buf: Vec<f64>,
    /// DRR candidate ordering, rebuilt per admission pass.
    cands: Vec<usize>,
    /// Recycled `Running::reqs` backing vectors.
    req_lists: Vec<Vec<usize>>,
    /// Recycled `Running::prefix_done` backing vectors.
    prefix_lists: Vec<Vec<u64>>,
}

impl SimScratch {
    /// Copy + sort a window population into the reusable buffer and hand it
    /// back for percentile extraction.
    fn sorted(&mut self, pop: &[f64]) -> &[f64] {
        self.sort_buf.clear();
        self.sort_buf.extend_from_slice(pop);
        sort_latencies(&mut self.sort_buf);
        &self.sort_buf
    }

    fn take_reqs(&mut self) -> Vec<usize> {
        self.req_lists.pop().unwrap_or_default()
    }

    fn put_reqs(&mut self, mut v: Vec<usize>) {
        v.clear();
        self.req_lists.push(v);
    }

    fn take_prefix(&mut self) -> Vec<u64> {
        self.prefix_lists.pop().unwrap_or_default()
    }

    fn put_prefix(&mut self, mut v: Vec<u64>) {
        v.clear();
        self.prefix_lists.push(v);
    }
}

/// Per-board outcome counters.
#[derive(Debug, Clone)]
pub struct BoardStats {
    pub board: usize,
    /// Items served to completion on this board (a pipelined item counts
    /// once per stage board it visits).
    pub items: u64,
    /// Batches dispatched on this board. In the multi-tenant simulator this
    /// counts dispatch *attempts*: a batch aborted by preemption is counted
    /// here (the board really ran it) and counted again when its items are
    /// re-served, so `items / batches` understates batch size under
    /// preemption. The static/dynamic simulators never abort, so there the
    /// count equals served batches.
    pub batches: u64,
    pub busy_cycles: u64,
    /// busy / makespan.
    pub utilization: f64,
    /// The board's clock — heterogeneous fleets mix generations.
    pub freq_mhz: f64,
}

/// One re-shard decision taken by the controller.
#[derive(Debug, Clone)]
pub struct ReshardEvent {
    /// Reference-clock cycle at which the migration began.
    pub at_cycle: u64,
    /// Labels of the outgoing and incoming shard plans.
    pub from: String,
    pub to: String,
    /// Which threshold fired.
    pub reason: String,
    /// Migration bill: weight bytes newly hosted per board plus in-flight
    /// activation state, after the policy's `migration_factor`.
    pub migration_bytes: u64,
    /// Cycles the whole fleet stalled while state moved. The unified
    /// multi-tenant engine emits one event per migrated tenant of a single
    /// migration; those events share one `at_cycle` and each carries the
    /// same fleet-wide stall (`migration_bytes` is per tenant) — do not sum
    /// stalls across events with an equal `at_cycle`.
    pub stall_cycles: u64,
    /// Tenant whose placement moved (the unified multi-tenant control plane
    /// emits one event per migrated tenant; the single-network dynamic
    /// controller leaves this `None` and its JSON shape unchanged).
    pub tenant: Option<String>,
}

impl ReshardEvent {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("at_cycle", self.at_cycle)
            .set("from", self.from.as_str())
            .set("to", self.to.as_str())
            .set("reason", self.reason.as_str())
            .set("migration_bytes", self.migration_bytes)
            .set("stall_cycles", self.stall_cycles);
        if let Some(t) = &self.tenant {
            j = j.set("tenant", t.as_str());
        }
        j
    }
}

/// Per-tenant outcome of a multi-tenant run.
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub name: String,
    pub priority: u8,
    pub requests: usize,
    pub completed: usize,
    /// Items served to completion (conservation: equals `completed` — a
    /// preempted batch's items are re-queued, never dropped or
    /// double-counted).
    pub items: u64,
    /// Batches of this tenant aborted mid-service by a higher-priority
    /// tenant.
    pub preemptions: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Completed items over the span to this tenant's last completion.
    pub throughput_rps: f64,
    /// The tenant's SLO target, echoed for report consumers.
    pub slo_p99_ms: f64,
    /// Simulated p99 within the SLO target.
    pub slo_met: bool,
    /// p99 over the final `ReshardPolicy::window` completions — the
    /// steady-state tail after any re-shards have settled. Only reported by
    /// the unified control plane (re-shard policy armed); `None` keeps the
    /// pre-unification report JSON byte-identical.
    pub tail_p99_ms: Option<f64>,
    /// Fraction of this tenant's requests that completed inside an outage
    /// window (board down → recovery or end of run) with latency within the
    /// SLO target — the SLO-attainment-through-outage metric. `1.0` when no
    /// completion overlapped an outage; `None` (key absent) when no
    /// [`crate::config::FaultScript`] was configured, which keeps the
    /// fault-free report JSON byte-identical.
    pub slo_attainment_outage: Option<f64>,
    /// Presentations rejected by this tenant's
    /// [`crate::config::OverloadPolicy`] admission check (a request sheds
    /// once per attempt, so this counts attempts, not distinct requests).
    /// `None` (key absent) when no tenant carries an overload policy — the
    /// policy-free report JSON stays byte-identical.
    pub shed: Option<u64>,
    /// Retry re-arrivals that fired (the client backoff model re-presents a
    /// shed request after a deterministic exponential backoff).
    pub retried: Option<u64>,
    /// Requests dropped after exhausting
    /// [`crate::config::RetryPolicy::max_attempts`] retries.
    pub abandoned: Option<u64>,
    /// Completed requests over the span to this tenant's last completion —
    /// the shed-aware companion to `throughput_rps` (which echoes offered
    /// load). Differs from `throughput_rps` exactly when abandons occurred.
    pub goodput_rps: Option<f64>,
}

impl TenantStats {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("name", self.name.as_str())
            .set("priority", self.priority as usize)
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("items", self.items)
            .set("preemptions", self.preemptions)
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("throughput_rps", self.throughput_rps)
            .set("slo_p99_ms", self.slo_p99_ms)
            .set("slo_met", self.slo_met);
        if let Some(v) = self.tail_p99_ms {
            j = j.set("tail_p99_ms", v);
        }
        if let Some(v) = self.slo_attainment_outage {
            j = j.set("slo_attainment_outage", v);
        }
        if let Some(v) = self.shed {
            j = j.set("shed", v);
        }
        if let Some(v) = self.retried {
            j = j.set("retried", v);
        }
        if let Some(v) = self.abandoned {
            j = j.set("abandoned", v);
        }
        if let Some(v) = self.goodput_rps {
            j = j.set("goodput_rps", v);
        }
        j
    }
}

/// Fleet-wide fault-tolerance summary of a run with a configured
/// [`crate::config::FaultScript`]. Lives on [`FleetReport::faults`]; `None`
/// (and the JSON key absent) when no script was configured — faults are
/// strictly opt-in and the healthy report stays byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSummary {
    /// Boards taken down by the script (deduplicated per `BoardDown` that
    /// actually fired against an up board).
    pub board_failures: u64,
    pub board_recoveries: u64,
    /// Link degrade windows that opened.
    pub link_degrades: u64,
    /// Clock derate events applied (including factor-1.0 restores).
    pub clock_derates: u64,
    /// `ComputeDegrade` onsets applied — partial-capacity brownouts that
    /// stretch the compute phase of the cost model while the off-chip phase
    /// keeps its healthy arithmetic.
    pub compute_degrades: u64,
    /// Emergency re-shards: placements re-run outside the controller window
    /// because a board death severed a chain or drained a tenant to zero
    /// replicas (or a recovery restored a stranded tenant).
    pub emergency_reshards: u64,
    /// In-flight items thrown back to their tenants' queues by board
    /// failures (the unfinished remainder under `Resume`, whole batches
    /// under `Restart`).
    pub items_requeued: u64,
    /// Sum over failures of (recovery instant − failure instant); an
    /// unrecovered board bills to the end of the run.
    pub downtime_cycles: u64,
    /// Fleet-wide p99 latency over completions strictly before the first
    /// fault instant (`None` when nothing completed that early).
    pub pre_fault_p99_ms: Option<f64>,
    /// Fleet-wide p99 latency over completions at/after the last fault
    /// instant in the script — failure, recovery, or degrade end, whichever
    /// is latest (`None` when nothing completed that late). The chaos
    /// battery bounds `recovery_p99_ms / pre_fault_p99_ms`.
    pub recovery_p99_ms: Option<f64>,
    /// Recovery-time objective: wall-clock from the first fault instant to
    /// the first controller window whose fleet-wide window p99 returned
    /// within 1.25× the pre-fault p99. Needs an armed
    /// [`crate::config::ReshardPolicy`] (windows are the measurement
    /// cadence) and at least one pre-fault completion; `None` (key absent)
    /// otherwise, or when no window re-attained the bar before the run
    /// drained.
    pub recovery_time_ms: Option<f64>,
}

impl FaultSummary {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("board_failures", self.board_failures)
            .set("board_recoveries", self.board_recoveries)
            .set("link_degrades", self.link_degrades)
            .set("clock_derates", self.clock_derates)
            .set("compute_degrades", self.compute_degrades)
            .set("emergency_reshards", self.emergency_reshards)
            .set("items_requeued", self.items_requeued)
            .set("downtime_cycles", self.downtime_cycles);
        if let Some(v) = self.pre_fault_p99_ms {
            j = j.set("pre_fault_p99_ms", v);
        }
        if let Some(v) = self.recovery_p99_ms {
            j = j.set("recovery_p99_ms", v);
        }
        if let Some(v) = self.recovery_time_ms {
            j = j.set("recovery_time_ms", v);
        }
        j
    }
}

/// Outcome of one fleet simulation.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// The fleet's shard mode. Multi-tenant runs mix modes per tenant;
    /// there this echoes the first tenant's mode and the authoritative
    /// per-tenant modes live in the tenant specs (consumers should read
    /// [`FleetReport::tenants`] when it is non-empty).
    pub mode: ShardMode,
    pub boards: usize,
    pub used_boards: usize,
    /// Provisioned boards left without work — a pipelined plan with fewer
    /// stages than boards wastes the difference.
    pub idle_boards: usize,
    pub requests: usize,
    pub completed: usize,
    pub makespan_cycles: u64,
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// [`simulate_fleet`] reports one entry per board the (fixed) plan
    /// uses; [`simulate_fleet_dynamic`] reports every provisioned board —
    /// under re-sharding a board idle in the *final* plan may still have
    /// served work earlier, so its counters must not be dropped. Consumers
    /// averaging utilization should filter on `busy_cycles > 0`.
    pub per_board: Vec<BoardStats>,
    /// Total bytes moved across inter-board links (0 for replicated).
    pub link_bytes_total: u64,
    /// The shared-DDR slowdown the fleet ran under (1.0 = uncontended).
    pub ddr_slowdown: f64,
    /// Re-shard decisions taken during the run (empty for the static
    /// scheduler).
    pub reshard_events: Vec<ReshardEvent>,
    /// Per-tenant outcomes ([`simulate_fleet_multi_tenant`]; empty for the
    /// single-network simulators).
    pub tenants: Vec<TenantStats>,
    /// Fleet-wide overload rollups: sums of the per-tenant shed / retry /
    /// abandon counters, and completed requests per second over the
    /// makespan. All `None` (keys absent) when no tenant carries an
    /// [`crate::config::OverloadPolicy`] — the policy-free report JSON
    /// stays byte-identical.
    pub shed_total: Option<u64>,
    pub retried_total: Option<u64>,
    pub abandoned_total: Option<u64>,
    pub goodput_rps: Option<f64>,
    /// Fault-tolerance summary when a [`crate::config::FaultScript`] was
    /// configured (multi-tenant engine only); `None` and the JSON key
    /// absent otherwise — faults are strictly opt-in.
    pub faults: Option<FaultSummary>,
    /// Aggregated telemetry when the run was traced with an armed
    /// [`TraceSink`]. `None` (and the JSON key absent) when tracing is
    /// disabled — the default for every plain entry point, which keeps the
    /// committed fixtures byte-identical.
    pub telemetry: Option<TelemetrySummary>,
    /// Per-segment interconnect counters when the run was fabric-armed
    /// ([`crate::config::ClusterConfig::fabric`]). `None` (and the JSON
    /// key absent) with no fabric — the point-to-point report stays
    /// byte-identical.
    pub fabric: Option<FabricSummary>,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let mut boards = Json::Arr(vec![]);
        for b in &self.per_board {
            boards = boards.push(
                Json::obj()
                    .set("board", b.board)
                    .set("items", b.items)
                    .set("batches", b.batches)
                    .set("busy_cycles", b.busy_cycles)
                    .set("utilization", b.utilization)
                    .set("freq_mhz", b.freq_mhz),
            );
        }
        let mut events = Json::Arr(vec![]);
        for e in &self.reshard_events {
            events = events.push(e.to_json());
        }
        let mut tenants = Json::Arr(vec![]);
        for t in &self.tenants {
            tenants = tenants.push(t.to_json());
        }
        let mut j = Json::obj()
            .set("mode", self.mode.as_str())
            .set("boards", self.boards)
            .set("used_boards", self.used_boards)
            .set("idle_boards", self.idle_boards)
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("makespan_cycles", self.makespan_cycles)
            .set("throughput_rps", self.throughput_rps)
            .set("mean_ms", self.mean_ms)
            .set("p50_ms", self.p50_ms)
            .set("p99_ms", self.p99_ms)
            .set("link_bytes_total", self.link_bytes_total)
            .set("ddr_slowdown", self.ddr_slowdown)
            .set("reshard_events", events)
            .set("tenants", tenants)
            .set("per_board", boards);
        if let Some(v) = self.shed_total {
            j = j.set("shed_total", v);
        }
        if let Some(v) = self.retried_total {
            j = j.set("retried_total", v);
        }
        if let Some(v) = self.abandoned_total {
            j = j.set("abandoned_total", v);
        }
        if let Some(v) = self.goodput_rps {
            j = j.set("goodput_rps", v);
        }
        if let Some(f) = &self.faults {
            j = j.set("faults", f.to_json());
        }
        if let Some(t) = &self.telemetry {
            j = j.set("telemetry", t.to_json());
        }
        if let Some(f) = &self.fabric {
            j = j.set("fabric", f.to_json());
        }
        j
    }
}

/// Open-loop Poisson arrival times in cycles. A non-finite rate means a
/// saturating burst: every request arrives at t = 0.
pub fn poisson_arrivals(n: usize, rps: f64, freq_mhz: f64, seed: u64) -> Vec<u64> {
    arrivals_with_steps(n, rps, &[], freq_mhz, seed)
}

/// Poisson arrivals with traffic shifts: the rate starts at `base_rps` and
/// switches at each [`LoadStep`]'s request index. A non-finite rate makes
/// the affected requests arrive instantaneously (at the current clock —
/// t = 0 when the base rate is a burst). Deterministic in `seed` *and*
/// across platforms: the exponential sampler goes through the portable
/// [`crate::util::math::ln_det`] rather than the platform libm, so the
/// committed golden fixtures reproduce bit-for-bit everywhere. The no-step
/// form is exactly [`poisson_arrivals`].
pub fn arrivals_with_steps(
    n: usize,
    base_rps: f64,
    steps: &[LoadStep],
    freq_mhz: f64,
    seed: u64,
) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    let mut rate = base_rps;
    let mut step_i = 0usize;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        while step_i < steps.len() && steps[step_i].at_request <= i {
            rate = steps[step_i].rps;
            step_i += 1;
        }
        if rate.is_finite() {
            assert!(rate > 0.0);
            let mean_cycles = freq_mhz * 1e6 / rate;
            // Exponential inter-arrival; 1−u ∈ (0, 1] keeps ln finite.
            t += -crate::util::math::ln_det(1.0 - rng.next_f64()) * mean_cycles;
        }
        out.push(t.round() as u64);
    }
    out
}

/// Drive round-robin arrivals through per-queue [`DynamicBatcher`]s with an
/// event queue: a queue schedules one flush-deadline event whenever it turns
/// non-empty, and events drain fleet-wide in time order interleaved with
/// arrivals (instead of the old lazy per-queue re-check on every arrival).
/// `serve` gets `(queue index, batch, ready cycle)` for every emitted batch,
/// chronologically per queue — queues are independent, so the global
/// reordering leaves every served batch, and therefore the report,
/// byte-identical to the lazy per-queue walk it replaced (now retired; the
/// golden fixtures under `tests/fixtures/` pin this behavior).
fn drive_batchers(
    batchers: &mut [DynamicBatcher<usize>],
    arrivals: &[u64],
    to_instant: &impl Fn(u64) -> Instant,
    to_cycles: &impl Fn(Instant) -> u64,
    mut serve: impl FnMut(usize, Vec<usize>, u64),
) {
    let mut deadlines = DeadlineQueue::new();
    // Fire the deadline event for queue `q` at cycle `at`. Events can be
    // stale (a size-bound flush beat them); compare against the batcher's
    // live deadline before flushing. A later live deadline always has its
    // own event: one is scheduled on every empty→non-empty transition.
    let fire = |batchers: &mut [DynamicBatcher<usize>],
                q: usize,
                at: u64,
                serve: &mut dyn FnMut(usize, Vec<usize>, u64)| {
        match batchers[q].next_deadline() {
            Some(dl) if to_cycles(dl) == at => {
                let batch = match batchers[q].poll(dl) {
                    Some(batch) => batch,
                    None => batchers[q].flush(),
                };
                serve(q, batch, at);
            }
            _ => {} // stale event — the queue flushed by size in between
        }
    };

    for (i, &a) in arrivals.iter().enumerate() {
        let b = i % batchers.len();
        while let Some((at, q)) = deadlines.next_at_or_before(a) {
            fire(batchers, q, at, &mut serve);
        }
        let was_empty = batchers[b].is_empty();
        if let Some(batch) = batchers[b].push(i, to_instant(a)) {
            serve(b, batch, a);
        } else if was_empty {
            if let Some(dl) = batchers[b].next_deadline() {
                deadlines.schedule(to_cycles(dl), b);
            }
        }
    }
    // Drain: remaining non-empty queues flush at their scheduled deadlines.
    while let Some((at, q)) = deadlines.pop() {
        fire(batchers, q, at, &mut serve);
    }
}

/// Aggregate off-chip demand of a plan's active boards, in bytes per
/// reference cycle (each board's provisioned rate rescaled by its clock).
pub(crate) fn fleet_demand(plan: &ShardPlan, ref_freq: f64) -> f64 {
    plan.shards
        .iter()
        .map(|s| s.ddr_bytes_per_cycle * s.freq_mhz / ref_freq)
        .sum()
}

/// Script-driven fault state for the single-network simulators: admission
/// blackout windows plus stepwise clock factors per fleet board. Only
/// `board_down` and `clock_derate` are supported here — the batcher-driven
/// loops have no re-routing or preemption, so an outage blocks *new* batch
/// starts on the board (a batch already in service runs to completion) and
/// `board_down` must carry `recover_ms` (a permanent loss would strand the
/// board's share of the round-robin forever). The multi-tenant engine has
/// its own event-driven implementation with aborts and re-shards.
struct SingleNetFaults {
    /// Per fleet board: `(down_at, recover_at)` cycles, sorted by onset.
    outages: Vec<Vec<(u64, u64)>>,
    /// Per fleet board: `(at, factor)` derate steps, sorted by instant.
    derates: Vec<Vec<(u64, f64)>>,
    n_down: u64,
    n_recover: u64,
    n_derate: u64,
    first_at: Option<u64>,
    /// Latest end instant across all scripted disturbances.
    boundary: u64,
}

impl SingleNetFaults {
    /// `None` when the config has no script — the healthy paths stay
    /// byte-identical. Panics on events the single-network semantics cannot
    /// honor (the config layer already rejects them for tenant-less
    /// configs; this guards the multi-tenant-config-through-single-sim
    /// path).
    fn from_config(ccfg: &ClusterConfig, nb: usize, ref_freq: f64) -> Option<SingleNetFaults> {
        let script = ccfg.faults.as_ref()?;
        let ms_to_cycles = |ms: f64| ms_to_cycles_checked(ms, ref_freq);
        let mut f = SingleNetFaults {
            outages: vec![Vec::new(); nb],
            derates: vec![Vec::new(); nb],
            n_down: 0,
            n_recover: 0,
            n_derate: 0,
            first_at: None,
            boundary: 0,
        };
        for ev in &script.events {
            let at = ms_to_cycles(ev.at_ms());
            f.first_at = Some(f.first_at.map_or(at, |x: u64| x.min(at)));
            match ev {
                FaultEvent::BoardDown { board, at_ms, recover_ms } => {
                    let rec = recover_ms.expect(
                        "single-network simulators cannot re-route: board_down needs recover_ms",
                    );
                    assert!(
                        *board < nb,
                        "board_down board {board} out of range for this plan/fleet"
                    );
                    let (a, r) = (ms_to_cycles(*at_ms), ms_to_cycles(rec));
                    f.outages[*board].push((a, r));
                    f.n_down += 1;
                    f.n_recover += 1;
                    f.boundary = f.boundary.max(r);
                }
                FaultEvent::ClockDerate { board, factor, at_ms } => {
                    assert!(
                        *board < nb,
                        "clock_derate board {board} out of range for this plan/fleet"
                    );
                    f.derates[*board].push((ms_to_cycles(*at_ms), *factor));
                    f.n_derate += 1;
                    f.boundary = f.boundary.max(ms_to_cycles(*at_ms));
                }
                FaultEvent::LinkDegrade { .. }
                | FaultEvent::ComputeDegrade { .. }
                | FaultEvent::RackDown { .. } => {
                    panic!(
                        "single-network simulators support board_down and clock_derate only"
                    );
                }
            }
        }
        for w in &mut f.outages {
            w.sort_unstable();
        }
        for d in &mut f.derates {
            d.sort_by(|a, b| a.0.cmp(&b.0));
        }
        Some(f)
    }

    /// Push a batch start out of any outage window on `board`. Windows may
    /// chain (a recovery can land inside the next outage), so apply until a
    /// fixed point.
    fn admit_at(&self, board: usize, mut start: u64) -> u64 {
        loop {
            let mut moved = false;
            for &(a, r) in &self.outages[board] {
                if start >= a && start < r {
                    start = r;
                    moved = true;
                }
            }
            if !moved {
                return start;
            }
        }
    }

    /// Service cycles on `board` for a batch starting at `start`: the last
    /// derate step at or before the start instant applies (factor 1.0 —
    /// including "no step yet" — keeps the integer arithmetic exact).
    fn scale(&self, board: usize, start: u64, raw: u64) -> u64 {
        let f = self.derates[board]
            .iter()
            .rev()
            .find(|&&(at, _)| at <= start)
            .map_or(1.0, |&(_, f)| f);
        if f == 1.0 {
            raw
        } else {
            (raw as f64 / f).ceil() as u64
        }
    }

    /// Mirror of the multi-tenant [`FaultSummary`], restricted to what the
    /// single-network semantics can observe: no re-shards, no requeues, no
    /// RTO (there is no controller window here unless the dynamic policy
    /// is armed, and even then windows measure one network only).
    fn summary(&self, complete: &[u64], arrivals: &[u64], ns_per_cycle: f64) -> FaultSummary {
        let mut pre: Vec<f64> = Vec::new();
        let mut post: Vec<f64> = Vec::new();
        for (&c, &a) in complete.iter().zip(arrivals) {
            let l = c.saturating_sub(a) as f64 * ns_per_cycle / 1e6;
            if let Some(ff) = self.first_at {
                if c < ff {
                    pre.push(l);
                }
            }
            if c >= self.boundary {
                post.push(l);
            }
        }
        sort_latencies(&mut pre);
        sort_latencies(&mut post);
        FaultSummary {
            board_failures: self.n_down,
            board_recoveries: self.n_recover,
            link_degrades: 0,
            clock_derates: self.n_derate,
            compute_degrades: 0,
            emergency_reshards: 0,
            items_requeued: 0,
            downtime_cycles: self
                .outages
                .iter()
                .flatten()
                .map(|&(a, r)| r.saturating_sub(a))
                .sum(),
            pre_fault_p99_ms: if pre.is_empty() {
                None
            } else {
                Some(percentile_sorted(&pre, 99.0))
            },
            recovery_p99_ms: if post.is_empty() {
                None
            } else {
                Some(percentile_sorted(&post, 99.0))
            },
            recovery_time_ms: None,
        }
    }
}

/// Simulate `ccfg.requests` requests against a sharded fleet with a fixed
/// plan for the whole run.
pub fn simulate_fleet(cfg: &AccelConfig, shard: &ShardPlan, ccfg: &ClusterConfig) -> FleetReport {
    simulate_fleet_traced(cfg, shard, ccfg, &mut TraceSink::disabled())
}

/// [`simulate_fleet`] with a caller-supplied [`TraceSink`]. With an armed
/// sink every batch dispatch and flush is recorded per board and each
/// request latency feeds the tenant-0 quantile sketch; with
/// [`TraceSink::disabled`] this is exactly [`simulate_fleet`].
pub fn simulate_fleet_traced(
    cfg: &AccelConfig,
    shard: &ShardPlan,
    ccfg: &ClusterConfig,
    sink: &mut TraceSink,
) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    let ref_freq = cfg.platform.freq_mhz;
    let n = ccfg.requests;
    let arrivals = arrivals_with_steps(n, ccfg.arrival_rps, &ccfg.load_steps, ref_freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let demand = fleet_demand(shard, ref_freq);
    // Fault script (board_down + clock_derate only): admission blackouts
    // and derate steps applied per batch start. `None` without a script —
    // every branch below short-circuits and the run is byte-identical.
    let snf = SingleNetFaults::from_config(ccfg, shard.boards, ref_freq);

    // Synthetic clock: the DynamicBatcher speaks `Instant`, the simulator
    // speaks cycles. One fixed origin maps between them deterministically.
    let t0 = Instant::now();
    let ns_per_cycle = 1e3 / ref_freq;
    let to_instant = |c: u64| {
        t0 + Duration::from_nanos(checked_round_u64(c as f64 * ns_per_cycle, "synthetic clock ns"))
    };
    let to_cycles =
        |i: Instant| (i.duration_since(t0).as_nanos() as f64 / ns_per_cycle).round() as u64;
    let policy = BatchPolicy {
        max_batch: ccfg.max_batch,
        max_wait: Duration::from_nanos(checked_round_u64(ccfg.max_wait_us * 1e3, "max_wait ns")),
    };

    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;
    // Fabric-armed runs bill every boundary transfer over its routed
    // segment path instead of a private per-cut channel; `None` keeps the
    // point-to-point arithmetic byte-identical.
    let mut fabric = ccfg.fabric.as_ref().map(|s| Fabric::new(s, shard.boards));

    let service =
        |s: &super::shard::BoardShard, bsz: u64| s.service_cycles(bsz, ref_freq, &shared, demand);

    let (busy, batch_counts, item_counts) = match shard.mode {
        ShardMode::Replicated => {
            let nb = shard.used_boards();
            let mut batchers: Vec<DynamicBatcher<usize>> =
                (0..nb).map(|_| DynamicBatcher::new(policy)).collect();
            let mut free_at = vec![0u64; nb];
            let mut busy = vec![0u64; nb];
            drive_batchers(
                &mut batchers,
                &arrivals,
                &to_instant,
                &to_cycles,
                |b, batch, ready| {
                    let bsz = batch.len() as u64;
                    let mut start = ready.max(free_at[b]);
                    let mut svc = service(&shard.shards[b], bsz);
                    if let Some(f) = &snf {
                        let fb = shard.shards[b].board;
                        start = f.admit_at(fb, start);
                        svc = f.scale(fb, start, svc);
                    }
                    let done = start + svc;
                    free_at[b] = done;
                    busy[b] += svc;
                    let k = batch.len();
                    sink.record(|| TraceEvent::Dispatch {
                        at: start,
                        tenant: 0,
                        board: b,
                        items: k,
                        done,
                    });
                    sink.record(|| TraceEvent::Flush { at: done, tenant: 0, board: b, items: k });
                    for req in batch {
                        complete[req] = done;
                    }
                },
            );
            let batches: Vec<u64> = batchers.iter().map(|b| b.batches_emitted).collect();
            let items: Vec<u64> = batchers.iter().map(|b| b.items_processed).collect();
            (busy, batches, items)
        }
        ShardMode::Pipelined => {
            let stages = shard.used_boards();
            // One shared entry queue feeds stage 0; a batch then traverses
            // the whole board chain as a unit, and each cut's transfers
            // serialize on that cut's own capacity-limited channel.
            let mut entry = vec![DynamicBatcher::<usize>::new(policy)];
            let mut free_at = vec![0u64; stages];
            let mut busy = vec![0u64; stages];
            let mut links: Vec<LinkChannel> = (0..stages.saturating_sub(1))
                .map(|_| LinkChannel::new(link))
                .collect();
            drive_batchers(
                &mut entry,
                &arrivals,
                &to_instant,
                &to_cycles,
                |_, batch, ready| {
                    let bsz = batch.len() as u64;
                    let k = batch.len();
                    let mut t = ready;
                    for (s, bs) in shard.shards.iter().enumerate() {
                        let mut svc = service(bs, bsz);
                        let mut start = t.max(free_at[s]);
                        if let Some(f) = &snf {
                            start = f.admit_at(bs.board, start);
                            svc = f.scale(bs.board, start, svc);
                        }
                        let done = start + svc;
                        free_at[s] = done;
                        busy[s] += svc;
                        sink.record(|| TraceEvent::Dispatch {
                            at: start,
                            tenant: 0,
                            board: s,
                            items: k,
                            done,
                        });
                        t = done;
                        if s + 1 < stages {
                            let bytes = bs.egress_bytes * bsz;
                            link_bytes_total += bytes;
                            t = match fabric.as_mut() {
                                Some(f) => {
                                    let (src, dst) = (bs.board, shard.shards[s + 1].board);
                                    let route = f.route(src, dst);
                                    let end = f.transfer_route(&route, bytes, t);
                                    sink.record(|| TraceEvent::RouteTransfer {
                                        at: end,
                                        src,
                                        dst,
                                        bytes,
                                        hops: route.len(),
                                        class: "boundary",
                                    });
                                    end
                                }
                                None => links[s].transfer(bytes, t),
                            };
                        }
                    }
                    sink.record(|| TraceEvent::Flush {
                        at: t,
                        tenant: 0,
                        board: stages - 1,
                        items: k,
                    });
                    for req in batch {
                        complete[req] = t;
                    }
                },
            );
            let batches = vec![entry[0].batches_emitted; stages];
            let items = vec![entry[0].items_processed; stages];
            (busy, batches, items)
        }
    };

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| (c.saturating_sub(a)) as f64 * ns_per_cycle / 1e6)
        .collect();
    if sink.is_enabled() {
        for &l in &lat_ms {
            sink.observe_latency_ms(0, l);
        }
    }
    sort_latencies(&mut lat_ms);
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..shard.used_boards())
        .map(|b| BoardStats {
            board: b,
            items: item_counts[b],
            batches: batch_counts[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: shard.shards[b].freq_mhz,
        })
        .collect();

    FleetReport {
        mode: shard.mode,
        boards: shard.boards,
        used_boards: shard.used_boards(),
        idle_boards: shard.idle_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events: Vec::new(),
        tenants: Vec::new(),
        shed_total: None,
        retried_total: None,
        abandoned_total: None,
        goodput_rps: None,
        faults: snf.as_ref().map(|f| f.summary(&complete, &arrivals, ns_per_cycle)),
        telemetry: sink.summary(),
        fabric: fabric.as_ref().map(|f| f.summary(makespan_cycles)),
    }
}

/// Map `[board][layer] → hosted?` for a plan (replicated shards host every
/// layer; pipelined shards host their stage's range).
fn hosting(plan: &ShardPlan, n_layers: usize, nb: usize) -> Vec<Vec<bool>> {
    let mut h = vec![vec![false; n_layers]; nb];
    for s in &plan.shards {
        for l in s.layers.clone() {
            h[s.board][l] = true;
        }
    }
    h
}

/// Bytes a plan switch moves over links: weights for every layer a board
/// newly hosts, plus one pipeline's worth of in-flight activation state at
/// the new cuts. Per-layer weight bytes are derived once up front
/// ([`Weights::per_layer_bytes`]) instead of re-walking the banks inside
/// the boards × layers loop.
pub(crate) fn migration_bytes(
    old: &ShardPlan,
    new: &ShardPlan,
    weights: &Weights,
    word_bytes: usize,
    n_layers: usize,
    nb: usize,
) -> u64 {
    let oldh = hosting(old, n_layers, nb);
    let newh = hosting(new, n_layers, nb);
    let layer_bytes = weights.per_layer_bytes(word_bytes);
    let mut bytes = new.link_bytes_per_item();
    for b in 0..nb {
        for l in 0..n_layers {
            if newh[b][l] && !oldh[b][l] {
                bytes += layer_bytes[l];
            }
        }
    }
    bytes
}

/// Thread accumulated wire state from an outgoing plan's stage channels
/// onto a freshly built set. A re-shard replaces the channel *objects*
/// (stage boundaries moved), but where the same ordered `(src, dst)` board
/// pair still carries a boundary the physical wire between those boards
/// neither forgets its byte odometer nor drains an in-flight transfer
/// early — so the new channel inherits both via
/// [`LinkChannel::restore_state`]. Genuinely new pairs start fresh.
/// Degrade windows are the caller's business (they are baked per source
/// board at build time, before this carry).
pub(crate) fn carry_link_state(
    old_plan: &ShardPlan,
    old_links: &[LinkChannel],
    new_plan: &ShardPlan,
    new_links: &mut [LinkChannel],
) {
    for (si, ch) in new_links.iter_mut().enumerate() {
        let pair = (new_plan.shards[si].board, new_plan.shards[si + 1].board);
        for (oi, och) in old_links.iter().enumerate() {
            if (old_plan.shards[oi].board, old_plan.shards[oi + 1].board) == pair {
                ch.restore_state(och.bytes_moved, och.busy_until());
                break;
            }
        }
    }
}

/// Simulate a fleet under the re-shard controller.
///
/// Starts from `initial` (which may be deliberately naive — e.g. cuts
/// balanced under a homogeneous-fleet assumption) and processes arrivals
/// with greedy work-conserving batching: a board takes up to `max_batch`
/// requests that have arrived by the time it can start. After every
/// [`ReshardPolicy::window`] completions the controller evaluates the
/// window's p99 and per-board utilization skew; past a threshold it
/// re-plans on the actual fleet, bills the migration (weights + activation
/// state over a link, fleet-wide stall), swaps plans, and continues. With
/// `ccfg.reshard = None` this is a plain greedy-batching simulator — use
/// the same engine for the static baseline when comparing against the
/// controller.
pub fn simulate_fleet_dynamic(
    cfg: &AccelConfig,
    fleet: &[AccelConfig],
    net: &Network,
    weights: &Weights,
    initial: ShardPlan,
    ccfg: &ClusterConfig,
) -> FleetReport {
    let mut sink = TraceSink::disabled();
    simulate_fleet_dynamic_traced(cfg, fleet, net, weights, initial, ccfg, &mut sink)
}

/// [`simulate_fleet_dynamic`] with a caller-supplied [`TraceSink`]. An armed
/// sink records every dispatch/flush, a [`TraceEvent::WindowRollup`] plus a
/// [`WindowSample`] at each controller window boundary, and the full reshard
/// lifecycle (trigger → stall → wake); with [`TraceSink::disabled`] this is
/// exactly [`simulate_fleet_dynamic`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_dynamic_traced(
    cfg: &AccelConfig,
    fleet: &[AccelConfig],
    net: &Network,
    weights: &Weights,
    initial: ShardPlan,
    ccfg: &ClusterConfig,
    sink: &mut TraceSink,
) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    assert!(!fleet.is_empty());
    assert!(
        initial.used_boards() <= fleet.len(),
        "initial plan uses more boards than the fleet has"
    );
    let ref_freq = cfg.platform.freq_mhz;
    let ns_per_cycle = 1e3 / ref_freq;
    let n = ccfg.requests;
    let arrivals = arrivals_with_steps(n, ccfg.arrival_rps, &ccfg.load_steps, ref_freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let nb = fleet.len();
    let word_bytes = cfg.platform.word_bytes;
    let n_layers = net.layers.len();
    // Fault script (board_down + clock_derate only), same semantics as the
    // static scheduler: outages block new batch starts, derates stretch
    // batches starting at/after their instant. Inert without a script.
    let snf = SingleNetFaults::from_config(ccfg, nb, ref_freq);

    let mut plan = initial;
    let mut links: Vec<LinkChannel> = (0..plan.used_boards().saturating_sub(1))
        .map(|_| LinkChannel::new(link))
        .collect();
    let mut demand = fleet_demand(&plan, ref_freq);

    let mut free_at = vec![0u64; nb];
    let mut busy = vec![0u64; nb];
    let mut items = vec![0u64; nb];
    let mut batches = vec![0u64; nb];
    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;
    let mut events: Vec<ReshardEvent> = Vec::new();
    // Routed interconnect, armed only when `ccfg.fabric` is set: boundary
    // and migration traffic then serializes over shared rack segments
    // instead of the per-stage point-to-point channels. The fabric is
    // physical state — it survives every plan swap below.
    let mut fabric = ccfg.fabric.as_ref().map(|s| Fabric::new(s, nb));

    // Controller window state. `sim_now` is the furthest completion seen —
    // batch completions are not themselves monotone on a heterogeneous
    // fleet (a fast board finishes later-dispatched work earlier), and the
    // window span must never collapse to zero.
    let policy: Option<ReshardPolicy> = ccfg.reshard.clone();
    let mut win_lat_ms: Vec<f64> = Vec::new();
    let mut win_start = 0u64;
    let mut win_busy0 = busy.clone();
    let mut cooldown = 0usize;
    let mut sim_now = 0u64;
    let mut scratch = SimScratch::default();
    // Earliest-start board selection for the replicated arm: a busy/idle
    // heap pair instead of scanning every shard per batch. Re-seeded in
    // place on every plan swap (shard set and free_at both change).
    let mut pool =
        BoardPool::from_slots(plan.shards.iter().map(|s| (s.freq_mhz, free_at[s.board])));

    let mut i = 0usize;
    while i < n {
        // ---- dispatch one batch, greedy and work-conserving ----
        let (batch_done, batch_len) = match plan.mode {
            ShardMode::Replicated => {
                let a = arrivals[i];
                // The board that can start soonest; ties go to the faster
                // clock, then the lower index (the pool reproduces the old
                // linear scan's tie-breaks exactly).
                let (pick, mut start) = pool.pick(a);
                let s = &plan.shards[pick];
                let mut k = 1usize;
                while i + k < n && k < ccfg.max_batch && arrivals[i + k] <= start {
                    k += 1;
                }
                let bsz = k as u64;
                let mut svc = s.service_cycles(bsz, ref_freq, &shared, demand);
                if let Some(f) = &snf {
                    start = f.admit_at(s.board, start);
                    svc = f.scale(s.board, start, svc);
                }
                let done = start + svc;
                let sb = s.board;
                free_at[sb] = done;
                pool.release(pick, done);
                busy[sb] += svc;
                items[sb] += bsz;
                batches[sb] += 1;
                sink.record(|| TraceEvent::Dispatch {
                    at: start,
                    tenant: 0,
                    board: sb,
                    items: k,
                    done,
                });
                sink.record(|| TraceEvent::Flush { at: done, tenant: 0, board: sb, items: k });
                for c in complete.iter_mut().skip(i).take(k) {
                    *c = done;
                }
                (done, k)
            }
            ShardMode::Pipelined => {
                let a = arrivals[i];
                let first = plan.shards[0].board;
                let start0 = free_at[first].max(a);
                let mut k = 1usize;
                while i + k < n && k < ccfg.max_batch && arrivals[i + k] <= start0 {
                    k += 1;
                }
                let bsz = k as u64;
                let stages = plan.used_boards();
                let mut t = start0;
                for (si, s) in plan.shards.iter().enumerate() {
                    let mut svc = s.service_cycles(bsz, ref_freq, &shared, demand);
                    let mut start = t.max(free_at[s.board]);
                    if let Some(f) = &snf {
                        start = f.admit_at(s.board, start);
                        svc = f.scale(s.board, start, svc);
                    }
                    let done = start + svc;
                    let sb = s.board;
                    free_at[sb] = done;
                    busy[sb] += svc;
                    items[sb] += bsz;
                    batches[sb] += 1;
                    sink.record(|| TraceEvent::Dispatch {
                        at: start,
                        tenant: 0,
                        board: sb,
                        items: k,
                        done,
                    });
                    t = done;
                    if si + 1 < stages {
                        let bytes = s.egress_bytes * bsz;
                        link_bytes_total += bytes;
                        t = match fabric.as_mut() {
                            Some(f) => {
                                let (src, dst) = (s.board, plan.shards[si + 1].board);
                                let route = f.route(src, dst);
                                let end = f.transfer_route(&route, bytes, t);
                                sink.record(|| TraceEvent::RouteTransfer {
                                    at: end,
                                    src,
                                    dst,
                                    bytes,
                                    hops: route.len(),
                                    class: "boundary",
                                });
                                end
                            }
                            None => links[si].transfer(bytes, t),
                        };
                    }
                }
                let lastb = plan.shards[stages - 1].board;
                sink.record(|| TraceEvent::Flush { at: t, tenant: 0, board: lastb, items: k });
                for c in complete.iter_mut().skip(i).take(k) {
                    *c = t;
                }
                (t, k)
            }
        };

        for j in i..i + batch_len {
            win_lat_ms
                .push(complete[j].saturating_sub(arrivals[j]) as f64 * ns_per_cycle / 1e6);
        }
        i += batch_len;
        sim_now = sim_now.max(batch_done);

        // ---- controller: evaluate the window ----
        let Some(pol) = &policy else { continue };
        if win_lat_ms.len() < pol.window {
            continue;
        }
        let now = sim_now;
        let span = now.saturating_sub(win_start);
        // Exact window p99 (the re-shard trigger the fixtures pin), sorted
        // into the reusable scratch buffer instead of a fresh clone.
        let p99 = percentile_sorted(scratch.sorted(&win_lat_ms), 99.0);
        let mut skew = 0.0f64;
        if span > 0 {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for s in &plan.shards {
                let u = busy[s.board].saturating_sub(win_busy0[s.board]) as f64 / span as f64;
                lo = lo.min(u);
                hi = hi.max(u);
            }
            skew = hi - lo;
        }
        let win_requests = win_lat_ms.len() as u64;
        sink.record(|| TraceEvent::WindowRollup { at: now, requests: win_requests });
        sink.sample_window(|| WindowSample {
            at: now,
            busy_frac: (0..nb)
                .map(|b| {
                    if span == 0 {
                        0.0
                    } else {
                        busy[b].saturating_sub(win_busy0[b]) as f64 / span as f64
                    }
                })
                .collect(),
            queue_depth: vec![n - i],
            window_p99_ms: vec![p99],
        });
        if cooldown > 0 {
            cooldown -= 1;
        } else if p99 > pol.p99_ms || skew > pol.util_skew {
            let reason = if p99 > pol.p99_ms {
                format!("window p99 {p99:.1} ms > {:.1} ms", pol.p99_ms)
            } else {
                format!("utilization skew {skew:.2} > {:.2}", pol.util_skew)
            };
            sink.record(|| TraceEvent::ReshardTrigger { at: now, reason: reason.clone() });
            // Re-plan on the actual fleet: both modes, ranked by predicted
            // capacity; only feasible candidates compete.
            let mut best: Option<(f64, ShardPlan)> = None;
            for cand in [
                ShardPlan::replicated_fleet(fleet, net, weights, &plan.plan),
                ShardPlan::pipelined_fleet(fleet, net, weights, &plan.plan),
            ] {
                if !cand.fits() {
                    continue;
                }
                let cap = cand.capacity_rps(ccfg.max_batch, &link, ref_freq);
                let better = match &best {
                    None => true,
                    Some((b, _)) => cap > *b,
                };
                if better {
                    best = Some((cap, cand));
                }
            }
            if let Some((_, new_plan)) = best {
                if new_plan.label() != plan.label() {
                    let raw = migration_bytes(&plan, &new_plan, weights, word_bytes, n_layers, nb);
                    let bill =
                        checked_round_u64(raw as f64 * pol.migration_factor, "migration bill");
                    // The whole fleet pauses: drain to the latest busy
                    // board, move state, resume together.
                    let sync = free_at.iter().copied().max().unwrap_or(now).max(now);
                    let stall = match fabric.as_mut() {
                        Some(f) => {
                            // Bill the move over its actual route (entry
                            // stage to entry stage): queueing behind
                            // boundary traffic already on the shared
                            // segments lengthens the stall.
                            let (src, dst) = (plan.shards[0].board, new_plan.shards[0].board);
                            let route = f.route(src, dst);
                            let end = f.transfer_route(&route, bill, sync);
                            sink.record(|| TraceEvent::RouteTransfer {
                                at: end,
                                src,
                                dst,
                                bytes: bill,
                                hops: route.len(),
                                class: "migration",
                            });
                            end.saturating_sub(sync)
                        }
                        None => link.transfer_cycles(bill),
                    };
                    for f in &mut free_at {
                        *f = sync + stall;
                    }
                    sink.record(|| TraceEvent::ReshardStall {
                        at: sync,
                        tenant: None,
                        bytes: bill,
                        stall_cycles: stall,
                    });
                    sink.record(|| TraceEvent::ReshardWake { at: sync + stall });
                    events.push(ReshardEvent {
                        at_cycle: sync,
                        from: plan.label(),
                        to: new_plan.label(),
                        reason,
                        migration_bytes: bill,
                        stall_cycles: stall,
                        tenant: None,
                    });
                    let mut new_links: Vec<LinkChannel> =
                        (0..new_plan.used_boards().saturating_sub(1))
                            .map(|_| LinkChannel::new(link))
                            .collect();
                    // The wires between surviving board pairs keep their
                    // odometers and in-flight occupancy across the swap.
                    carry_link_state(&plan, &links, &new_plan, &mut new_links);
                    links = new_links;
                    plan = new_plan;
                    demand = fleet_demand(&plan, ref_freq);
                    pool.rebuild(plan.shards.iter().map(|s| (s.freq_mhz, free_at[s.board])));
                    cooldown = pol.cooldown_windows;
                }
            }
        }
        win_lat_ms.clear();
        win_start = now;
        win_busy0.copy_from_slice(&busy);
    }

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| c.saturating_sub(a) as f64 * ns_per_cycle / 1e6)
        .collect();
    if sink.is_enabled() {
        for &l in &lat_ms {
            sink.observe_latency_ms(0, l);
        }
    }
    sort_latencies(&mut lat_ms);
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..nb)
        .map(|b| BoardStats {
            board: b,
            items: items[b],
            batches: batches[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: fleet[b].platform.freq_mhz,
        })
        .collect();

    FleetReport {
        mode: plan.mode,
        boards: nb,
        used_boards: plan.used_boards(),
        idle_boards: nb - plan.used_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events: events,
        tenants: Vec::new(),
        shed_total: None,
        retried_total: None,
        abandoned_total: None,
        goodput_rps: None,
        faults: snf.as_ref().map(|f| f.summary(&complete, &arrivals, ns_per_cycle)),
        telemetry: sink.summary(),
        fabric: fabric.as_ref().map(|f| f.summary(makespan_cycles)),
    }
}

/// A replicated batch in service on one board (the preemptible unit).
#[derive(Debug, Clone)]
struct Running {
    tenant: usize,
    start: u64,
    done: u64,
    reqs: Vec<usize>,
    /// Reference-cycle instants at which each item of the batch (in queue
    /// order) has been fully served, priced at dispatch time. Populated only
    /// under [`PreemptMode::Resume`], where a preemption completes the
    /// finished prefix on the spot instead of re-queueing and re-running it.
    prefix_done: Vec<u64>,
}

/// Derive the per-tenant arrival seed from the cluster seed: every tenant
/// samples an independent, deterministic path.
pub fn tenant_seed(cluster_seed: u64, tenant: usize) -> u64 {
    cluster_seed ^ (tenant as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Simulate several tenants sharing one fleet — the unified control plane.
///
/// Each tenant drives its own open-loop stream
/// ([`arrivals_with_steps`], seeded per tenant via [`tenant_seed`]); all
/// streams merge with board completions on one [`DeadlineQueue`], so the
/// whole run is a single time-ordered event drain. Dispatch at every event
/// instant is greedy, priority-ordered, and weighted-fair within a class:
///
/// 1. **Admission**: priority classes are served in descending order.
///    *Within* a class, admission is deficit-weighted round-robin on
///    [`crate::config::SloPolicy::weight`]: every tenant carries a deficit
///    counter of normalized service (billed reference cycles divided by its
///    weight) and the pending tenant with the smallest deficit is admitted
///    first (ties to the lower tenant index), so equal-class peers share
///    boards in proportion to their weights instead of draining in tenant
///    order — the starvation mode of the previous strict-FIFO admission.
///    A preempted victim's deficit is refunded for the service it did not
///    receive (all of it under `Restart`, the unfinished remainder under
///    `Resume`), so being preempted by a higher class never costs a tenant
///    its fair share against its own peers.
///    Within a tenant, boards are picked with the [`BoardPool`] tie-breaks —
///    fastest clock, then lowest index. Batches take up to `max_batch`
///    queued requests greedily at each event instant — there is no
///    accumulate-up-to-deadline batcher on this path, so
///    `ClusterConfig::max_wait_us` does not apply (it only shapes the
///    static scheduler's [`DynamicBatcher`]s).
/// 2. **Preemption**: a *replicated* tenant with queued work and no free
///    board may abort a strictly lower-priority replicated batch
///    mid-service (lowest victim priority first, then lowest board index).
///    What happens to the victim depends on
///    [`crate::config::PreemptMode`]:
///    * `Restart` (the original protocol): every item re-queues at the head
///      of the victim's queue and the next service is billed the full batch
///      cost again plus `ClusterConfig::preempt_restart_cycles`;
///    * `Resume` (work-preserving): items whose service had already
///      completed by the preemption instant finish there and then; only the
///      unfinished remainder re-queues, and its next service is billed the
///      remainder's own cost plus `ClusterConfig::preempt_refill_cycles`
///      (the pipeline refill) — strictly cheaper whenever the refill is not
///      dearer than a restart.
///    Pipelined chains sit outside the preemption protocol on both sides:
///    they need their whole stage chain at once, so aborting a single
///    board's batch could not launch them, and once launched they occupy
///    stage boards via the shared timeline and run to completion.
/// 3. **Tenant-aware re-sharding** (with `ccfg.reshard` armed): after every
///    [`ReshardPolicy::window`] completions the controller checks each
///    tenant's window p99 against *that tenant's own*
///    [`crate::config::SloPolicy::p99_ms`] (the policy's global `p99_ms`
///    threshold is superseded by the per-tenant targets on this path) and
///    the fleet's utilization skew against `ReshardPolicy::util_skew`. On a
///    trigger it re-runs the placement planner
///    ([`super::shard::place_tenants_biased`]) against the observed load —
///    boards ordered coolest-first by window busy cycles, and every
///    SLO-missing tenant's replica cap lifted (scale-out; sticky for the
///    rest of the run, so an unrelated later trigger cannot shrink a
///    recovered tenant back and oscillate) — then bills each
///    migrated tenant's weight + activation state over a link
///    ([`migration_bytes`]), stalls the fleet for the transfer, and records
///    one [`ReshardEvent`] per migrated tenant (with
///    [`ReshardEvent::tenant`] set). In-flight batches drain at their
///    scheduled completions; new admissions wait for the migration stall.
///    With `ccfg.reshard = None` the engine is exactly the pre-unification
///    multi-tenant simulator (the committed fixtures pin this).
///
/// Co-residency is billed through [`SharedDdr`]: the contention demand is
/// the sum of *every* tenant's provisioned draw, so packing more networks
/// onto one backplane stretches everyone's off-chip phases. `weights[t]` is
/// each tenant's weight set — used only to price migrations, so the
/// no-reshard path never reads it.
///
/// `plans[t]` must come from the fleet-wide placement planner
/// ([`super::shard::place_tenants`]) — `BoardShard::board` fields index
/// `fleet`. Reports per-tenant p50/p99/throughput/SLO attainment and
/// preemption counts in [`FleetReport::tenants`] (plus the post-settle
/// [`TenantStats::tail_p99_ms`] when the controller is armed), and
/// re-shard decisions in [`FleetReport::reshard_events`]. Deterministic
/// from `ccfg.seed`.
pub fn simulate_fleet_multi_tenant(
    cfg: &AccelConfig,
    fleet: &[AccelConfig],
    specs: &[TenantSpec],
    weights: &[Weights],
    plans: &[ShardPlan],
    ccfg: &ClusterConfig,
) -> FleetReport {
    let mut sink = TraceSink::disabled();
    simulate_fleet_multi_tenant_traced(cfg, fleet, specs, weights, plans, ccfg, &mut sink)
}

/// [`simulate_fleet_multi_tenant`] with a caller-supplied [`TraceSink`]. An
/// armed sink records the full control-plane decision stream — admission
/// with the DRR deficit at decision time, per-board dispatch/flush,
/// preemption with the refunded deficit, the reshard lifecycle with
/// per-tenant migration billing, and window rollups — plus per-tenant
/// latency sketches and the simulator's own event-loop stats; with
/// [`TraceSink::disabled`] this is exactly [`simulate_fleet_multi_tenant`].
///
/// # Examples
///
/// ```
/// use decoilfnet::cluster::{plan_tenants, simulate_fleet_multi_tenant_traced, TraceSink};
/// use decoilfnet::config::{tiny_vgg, AccelConfig, ClusterConfig, ShardMode, SloPolicy, TenantSpec};
///
/// let cfg = AccelConfig::paper_default();
/// let mut ccfg = ClusterConfig::fleet_default();
/// ccfg.boards = 2;
/// ccfg.tenants = vec![TenantSpec {
///     name: "burst".to_string(),
///     network: tiny_vgg(),
///     weights_seed: 1,
///     arrival_rps: f64::INFINITY,
///     requests: 16,
///     load_steps: vec![],
///     mode: ShardMode::Replicated,
///     replicas: None,
///     slo: SloPolicy { p99_ms: 10.0, priority: 1, weight: 1.0, overload: None },
/// }];
/// let fleet = ccfg.board_configs(&cfg);
/// let (weights, plans) = plan_tenants(&cfg, &ccfg).unwrap();
/// let mut sink = TraceSink::enabled();
/// let report = simulate_fleet_multi_tenant_traced(
///     &cfg, &fleet, &ccfg.tenants, &weights, &plans, &ccfg, &mut sink,
/// );
/// assert_eq!(report.completed, 16);
/// assert!(report.telemetry.is_some(), "armed sink → telemetry summary");
/// assert!(!sink.events.is_empty(), "the decision stream was recorded");
/// ```
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_multi_tenant_traced(
    cfg: &AccelConfig,
    fleet: &[AccelConfig],
    specs: &[TenantSpec],
    weights: &[Weights],
    plans: &[ShardPlan],
    ccfg: &ClusterConfig,
    sink: &mut TraceSink,
) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    assert!(!fleet.is_empty());
    assert!(!specs.is_empty(), "multi-tenant sim needs at least one tenant");
    // `specs` is usually passed alongside (not inside) `ccfg`, so validate
    // each tenant here too — a zero-request or NaN-rate spec should fail
    // with its config error, not deep inside reporting.
    for s in specs {
        s.validate().expect("invalid tenant spec");
    }
    assert_eq!(specs.len(), plans.len());
    assert_eq!(
        specs.len(),
        weights.len(),
        "one Weights per tenant (the re-shard controller prices migrations)"
    );
    let nb = fleet.len();
    let nt = specs.len();
    for p in plans {
        assert_eq!(p.boards, nb, "plan not placed on this fleet");
        assert!(p.shards.iter().all(|s| s.board < nb));
    }

    let ref_freq = cfg.platform.freq_mhz;
    let ns_per_cycle = 1e3 / ref_freq;
    let word_bytes = cfg.platform.word_bytes;
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);

    // ---- fault injection (inert when `ccfg.faults` is None) ----
    // The script's wall-clock instants convert onto the reference timeline
    // once, up front; each timeline entry is scheduled as its own event in
    // the third id space of the shared queue (ids >= nb + nt), so fault
    // timing composes with arrivals, completions, and reshard wakes.
    enum FaultAction {
        Fail(usize),
        Recover(usize),
        /// (source board, factor, until-cycle) — the slow windows are baked
        /// into the link channels at build time; this event only emits the
        /// trace record and wakes the dispatcher.
        Degrade(usize, f64, u64),
        Derate(usize, f64),
        /// (board, capacity fraction, recovery cycle if any): a partial-
        /// capacity brownout. The fraction scales the compute phase of the
        /// cost model and demotes the board in the capacity-aware
        /// placement rank.
        CapDegrade(usize, f64, Option<u64>),
        CapRestore(usize),
    }
    let faults_armed = ccfg.faults.is_some();
    let ms_to_cycles = |ms: f64| ms_to_cycles_checked(ms, ref_freq);
    let mut fault_timeline: Vec<(u64, FaultAction)> = Vec::new();
    // Degrade windows by source board, absolute cycles: (start, end, factor).
    let mut link_degrades: Vec<(u64, u64, f64, usize)> = Vec::new();
    if let Some(script) = &ccfg.faults {
        for ev in &script.events {
            match ev {
                FaultEvent::BoardDown { board, at_ms, recover_ms } => {
                    fault_timeline.push((ms_to_cycles(*at_ms), FaultAction::Fail(*board)));
                    if let Some(rec) = recover_ms {
                        fault_timeline.push((ms_to_cycles(*rec), FaultAction::Recover(*board)));
                    }
                }
                FaultEvent::LinkDegrade { link, factor, at_ms, until_ms } => {
                    let (a, u) = (ms_to_cycles(*at_ms), ms_to_cycles(*until_ms));
                    fault_timeline.push((a, FaultAction::Degrade(*link, *factor, u)));
                    link_degrades.push((a, u, *factor, *link));
                }
                FaultEvent::ClockDerate { board, factor, at_ms } => {
                    fault_timeline.push((ms_to_cycles(*at_ms), FaultAction::Derate(*board, *factor)));
                }
                FaultEvent::ComputeDegrade { board, capacity_fraction, at_ms, recover_ms } => {
                    let rec = recover_ms.map(ms_to_cycles);
                    fault_timeline.push((
                        ms_to_cycles(*at_ms),
                        FaultAction::CapDegrade(*board, *capacity_fraction, rec),
                    ));
                    if let Some(r) = rec {
                        fault_timeline.push((r, FaultAction::CapRestore(*board)));
                    }
                }
                FaultEvent::RackDown { rack, at_ms, recover_ms } => {
                    // A rack-scoped correlated failure is board_down over
                    // the rack's members: shared power/cooling/uplink takes
                    // every board of the failure domain out at once (the
                    // config layer guarantees a fabric is armed, which is
                    // what defines rack membership).
                    let fb = ccfg.fabric.as_ref().expect("validated: rack_down needs a fabric");
                    for b in (0..nb).filter(|&b| fb.rack_of(b) == *rack) {
                        fault_timeline.push((ms_to_cycles(*at_ms), FaultAction::Fail(b)));
                        if let Some(rec) = recover_ms {
                            fault_timeline.push((ms_to_cycles(*rec), FaultAction::Recover(b)));
                        }
                    }
                }
            }
        }
        // Scripts are ordered by start instant, but recovery instants
        // interleave freely; the event queue needs the global order.
        fault_timeline.sort_by_key(|e| e.0);
    }
    let first_fault_at: Option<u64> = fault_timeline.first().map(|e| e.0);
    // The battery's recovery measurement starts once every scripted
    // disturbance is over: the latest of all failure, recovery, derate, and
    // degrade-end instants.
    let recovery_boundary: u64 = ccfg
        .faults
        .as_ref()
        .and_then(|s| {
            s.events
                .iter()
                .map(|ev| match ev {
                    FaultEvent::BoardDown { at_ms, recover_ms, .. } => {
                        ms_to_cycles(recover_ms.unwrap_or(*at_ms))
                    }
                    FaultEvent::LinkDegrade { until_ms, .. } => ms_to_cycles(*until_ms),
                    FaultEvent::ClockDerate { at_ms, .. } => ms_to_cycles(*at_ms),
                    FaultEvent::ComputeDegrade { at_ms, recover_ms, .. } => {
                        ms_to_cycles(recover_ms.unwrap_or(*at_ms))
                    }
                    FaultEvent::RackDown { at_ms, recover_ms, .. } => {
                        ms_to_cycles(recover_ms.unwrap_or(*at_ms))
                    }
                })
                .max()
        })
        .unwrap_or(0);

    // The placement is mutable state now: the controller may swap it.
    let mut cur_plans: Vec<ShardPlan> = plans.to_vec();
    // Co-residency bill: the whole fleet's provisioned draw, all tenants.
    let mut demand: f64 = cur_plans.iter().map(|p| fleet_demand(p, ref_freq)).sum();

    let arrivals: Vec<Vec<u64>> = specs
        .iter()
        .enumerate()
        .map(|(t, s)| {
            arrivals_with_steps(
                s.requests,
                s.arrival_rps,
                &s.load_steps,
                ref_freq,
                tenant_seed(ccfg.seed, t),
            )
        })
        .collect();

    // shard_idx[t][b] → index into cur_plans[t].shards hosted on board b.
    let build_idx = |plans: &[ShardPlan]| -> Vec<Vec<Option<usize>>> {
        let mut idx = vec![vec![None; nb]; nt];
        for (t, p) in plans.iter().enumerate() {
            for (i, s) in p.shards.iter().enumerate() {
                idx[t][s.board] = Some(i);
            }
        }
        idx
    };
    let mut shard_idx = build_idx(&cur_plans);
    let prio: Vec<u8> = specs.iter().map(|s| s.slo.priority).collect();
    let w_of: Vec<f64> = specs.iter().map(|s| s.slo.weight).collect();
    let mut t_order: Vec<usize> = (0..nt).collect();
    t_order.sort_by_key(|&t| (std::cmp::Reverse(prio[t]), t));
    // Consecutive equal-priority runs of `t_order` — the DRR classes.
    let mut classes: Vec<Vec<usize>> = Vec::new();
    for &t in &t_order {
        match classes.last_mut() {
            Some(c) if prio[c[0]] == prio[t] => c.push(t),
            _ => classes.push(vec![t]),
        }
    }

    let rebuild_links = |plans: &[ShardPlan]| -> Vec<Vec<LinkChannel>> {
        plans
            .iter()
            .map(|p| {
                (0..p.used_boards().saturating_sub(1))
                    .map(|si| {
                        let mut ch = LinkChannel::new(link);
                        // Bake the script's absolute-time degrade windows
                        // into every channel whose source board matches —
                        // the no-faults path leaves the channel untouched
                        // (and the healthy arithmetic byte-identical).
                        if !link_degrades.is_empty() {
                            let src = p.shards[si].board;
                            let windows: Vec<(u64, u64, f64)> = link_degrades
                                .iter()
                                .filter(|d| d.3 == src)
                                .map(|d| (d.0, d.1, d.2))
                                .collect();
                            if !windows.is_empty() {
                                ch.set_degrades(windows);
                            }
                        }
                        ch
                    })
                    .collect()
            })
            .collect()
    };
    let mut links_t = rebuild_links(&cur_plans);

    let mut free_at = vec![0u64; nb];
    let mut busy = vec![0u64; nb];
    let mut items = vec![0u64; nb];
    let mut batches = vec![0u64; nb];
    let mut board_state: Vec<Option<Running>> = vec![None; nb];
    // Pending queue per tenant: (request index, billed-penalty flag). Every
    // queued entry is dispatchable now — arrivals enter at their event and
    // preempted work re-enters at the preemption instant.
    let mut pend: Vec<VecDeque<(usize, bool)>> = vec![VecDeque::new(); nt];
    let mut complete: Vec<Vec<u64>> = specs.iter().map(|s| vec![0u64; s.requests]).collect();
    let mut done_mask: Vec<Vec<bool>> = specs.iter().map(|s| vec![false; s.requests]).collect();
    // Items actually served to completion per tenant — measured, not echoed
    // from the spec, so the conservation checks in the report are real.
    let mut served = vec![0u64; nt];

    // ---- overload shedding (inert unless some tenant carries a policy) ----
    // Admission happens at arrival and retry re-arrival only: a request the
    // policy predicts will miss its deadline (or that finds the queue at
    // max_queue) is shed and re-presented by the client model after a
    // deterministic exponential backoff; exhausting the retry budget
    // abandons it. Conservation becomes
    // `served + abandoned == requests` per tenant.
    let overload_armed = specs.iter().any(|s| s.slo.overload.is_some());
    let mut abandon_mask: Vec<Vec<bool>> =
        specs.iter().map(|s| vec![false; s.requests]).collect();
    let mut n_shed = vec![0u64; nt];
    let mut n_retried = vec![0u64; nt];
    let mut n_abandoned = vec![0u64; nt];
    // The fourth id space of the shared event queue grows as sheds happen:
    // entry i = (tenant, request, retry attempt) re-arriving as event id
    // `nb + nt + nf + i`.
    let mut retry_table: Vec<(usize, usize, u32)> = Vec::new();
    let mut preemptions = vec![0u64; nt];
    // Deficit counters of the within-class weighted round-robin: billed
    // reference cycles per tenant, compared normalized by SLO weight.
    let mut charge = vec![0u64; nt];
    let mut link_bytes_total = 0u64;

    // Routed interconnect, armed only when `ccfg.fabric` is set. Physical
    // state: it persists across every controller and emergency re-plan, so
    // its per-segment byte odometers conserve across plan switches by
    // construction. A scripted link degrade on a board's egress arms the
    // board's rack backplane — rack-local media is shared, so co-racked
    // boards' windows merge onto one segment.
    let mut fabric = ccfg.fabric.as_ref().map(|spec| {
        let mut f = Fabric::new(spec, nb);
        if !link_degrades.is_empty() {
            let mut by_rack: Vec<Vec<(u64, u64, f64)>> = vec![Vec::new(); spec.n_racks(nb)];
            for &(a, u, factor, src) in &link_degrades {
                by_rack[spec.rack_of(src)].push((a, u, factor));
            }
            for (r, windows) in by_rack.into_iter().enumerate() {
                if !windows.is_empty() {
                    // Any member board addresses its rack's backplane.
                    f.set_board_degrades(r * spec.boards_per_rack, windows);
                }
            }
        }
        f
    });

    // One event queue for everything: ids < nb are board events (batch
    // completions / stage-release / post-migration wakes), ids in
    // [nb, nb + nt) are per-tenant arrival cursors (id - nb = tenant), ids
    // in [nb + nt, nb + nt + nf) index the fault timeline, and ids >=
    // nb + nt + nf index `retry_table` (shed requests re-arriving after
    // backoff — that table grows during the run, the other ranges are
    // fixed).
    let nf = fault_timeline.len();
    // Coalesced heap: one entry per live id, so depth stays O(nb + nt)
    // regardless of in-flight items (the retry table appends ids past the
    // pre-sized range as sheds happen).
    let mut events = DeadlineQueue::with_capacity(nb + nt + nf);
    let mut cursor = vec![0usize; nt];
    for (t, a) in arrivals.iter().enumerate() {
        if !a.is_empty() {
            events.schedule(a[0], nb + t);
        }
    }
    for (fi, e) in fault_timeline.iter().enumerate() {
        events.schedule(e.0, nb + nt + fi);
    }

    // Live fault state. All-up / factor-1.0 are the healthy identities the
    // hot paths short-circuit on, so a run without a script executes the
    // pre-fault arithmetic exactly.
    let mut board_up = vec![true; nb];
    let mut clock_factor = vec![1.0f64; nb];
    // Partial-capacity brownouts: fraction of the board's compute columns
    // still alive. 1.0 is the healthy identity the cost-model scaling and
    // the capacity-aware placement both short-circuit on.
    let mut capacity_factor = vec![1.0f64; nb];
    // A recovered board waits for the next controller window to be re-fed
    // coolest-first; this flag arms that trigger (always false without a
    // script, keeping the controller's fault-free behavior byte-identical).
    let mut readmit_pending = false;
    // A capacity change (brownout onset or restore) also wants the next
    // controller window to re-place — around the degraded board, or back
    // onto the restored one. Always false without a script.
    let mut capacity_pending = false;
    // FaultSummary accounting.
    let mut n_board_failures = 0u64;
    let mut n_board_recoveries = 0u64;
    let mut n_link_degrades = 0u64;
    let mut n_clock_derates = 0u64;
    let mut n_compute_degrades = 0u64;
    let mut n_emergency_reshards = 0u64;
    let mut items_requeued = 0u64;
    // (failure instant, recovery instant if any, board).
    let mut fault_log: Vec<(u64, Option<u64>, usize)> = Vec::new();

    // Controller state (inert when the policy is absent — the engine is then
    // byte-identical to the pre-unification multi-tenant simulator).
    let policy: Option<ReshardPolicy> = ccfg.reshard.clone();
    let mut reshard_events: Vec<ReshardEvent> = Vec::new();
    // Completions since the window opened (the trigger cadence); per-tenant
    // latencies live in `win_t` — no fleet-wide latency vector is needed.
    let mut win_count = 0usize;
    let mut win_t: Vec<Vec<f64>> = vec![Vec::new(); nt];
    // Post-settle tail: only the last `window` completions per tenant feed
    // `tail_p99_ms`, so a bounded ring replaces the old full per-tenant
    // latency log — O(window) resident instead of O(requests).
    let tail_cap = policy.as_ref().map_or(1, |p| p.window.max(1));
    let mut tail_lat: Vec<VecDeque<f64>> =
        vec![VecDeque::with_capacity(tail_cap.min(1024)); nt];
    let mut win_start = 0u64;
    let mut win_busy0 = vec![0u64; nb];
    let mut cooldown = 0usize;
    // Scale-out decisions are sticky: once a tenant's replica cap is lifted
    // it stays lifted for the rest of the run. Without this, an unrelated
    // later trigger (skew, another tenant's SLO) would re-apply the spec
    // cap, shrink the recovered tenant back, and oscillate scale-in/out
    // with a full-fleet migration stall on every flip.
    let mut uncapped = vec![false; nt];
    // Recovery-time objective: completions before the first fault seed the
    // baseline; after the fault, the first controller window whose
    // fleet-wide p99 is back within 1.25× that baseline stamps the
    // recovery instant. Inert unless both a script and a policy are armed.
    let mut pre_fault_lat: Vec<f64> = Vec::new();
    let mut recovery_at: Option<u64> = None;
    // The baseline p99 is computed once, lazily, at the first post-fault
    // window — `pre_fault_lat` stops growing at fault onset, so one sort
    // replaces the old per-window clone + re-sort of the whole baseline.
    let mut baseline_p99: Option<f64> = None;
    // Fleet-wide window latencies for the recovery check, as a ≤1%-error
    // log-scale sketch instead of a per-window flatten + sort of every
    // tenant's window population. Fed only while a script and a policy are
    // both armed; reset (not reallocated) at each window boundary.
    let mut win_sketch = QuantileSketch::new();
    // Reusable inner-loop buffers (window sorts, DRR candidate order,
    // recycled dispatch work-lists).
    let mut scratch = SimScratch::default();

    // Mark request `req` of tenant `t` complete at cycle `at` (exactly once
    // per request — the conservation asserts below keep that honest).
    macro_rules! record_done {
        ($t:expr, $req:expr, $at:expr) => {{
            let (t, req, at) = ($t, $req, $at);
            complete[t][req] = at;
            done_mask[t][req] = true;
            served[t] += 1;
            if policy.is_some() || sink.is_enabled() {
                let lat = at.saturating_sub(arrivals[t][req]) as f64 * ns_per_cycle / 1e6;
                sink.observe_latency_ms(t, lat);
                if policy.is_some() {
                    win_count += 1;
                    win_t[t].push(lat);
                    let ring = &mut tail_lat[t];
                    if ring.len() == tail_cap {
                        ring.pop_front();
                    }
                    ring.push_back(lat);
                    if faults_armed {
                        win_sketch.record(lat);
                        if first_fault_at.map_or(false, |ff| at < ff) {
                            pre_fault_lat.push(lat);
                        }
                    }
                }
            }
        }};
    }

    // Service cycles on board `b` after clock derating: a derated clock
    // stretches the board's service time by 1/factor. The factor-1.0 check
    // keeps the healthy path's integer arithmetic exact (no float rounding
    // on an undisturbed run).
    macro_rules! svc_on {
        ($b:expr, $raw:expr) => {{
            let (b, raw): (usize, u64) = ($b, $raw);
            if clock_factor[b] == 1.0 {
                raw
            } else {
                (raw as f64 / clock_factor[b]).ceil() as u64
            }
        }};
    }

    // Admission for one presentation of request `req` of tenant `t` at
    // instant `at` (attempt 0 = fresh arrival, attempt n = n-th retry).
    // Without an `OverloadPolicy` this is exactly the old unconditional
    // enqueue. With one, the predicted completion — the earliest up
    // hosting board's availability, plus draining the queue ahead of this
    // request in `max_batch` batches, plus the DRR deficit this tenant
    // must burn down relative to its class's least-charged member, plus
    // one batch of its own service — is checked against the policy
    // deadline, and `max_queue` bounds the queue unconditionally. A shed
    // request re-arrives after `backoff_base_ms · 2^attempt · (1+jitter·u)`
    // with `u` deterministic in (seed, tenant, request, attempt); past
    // `max_attempts` retries it is abandoned.
    macro_rules! admit {
        ($t:expr, $req:expr, $attempt:expr, $at:expr) => {{
            let (t, req, attempt, at): (usize, usize, u32, u64) = ($t, $req, $attempt, $at);
            match &specs[t].slo.overload {
                None => pend[t].push_back((req, false)),
                Some(opol) => {
                    let depth = pend[t].len();
                    // Earliest up hosting board and its full-batch service.
                    let mut avail: Option<(u64, u64)> = None;
                    for s in &cur_plans[t].shards {
                        let b = s.board;
                        if !board_up[b] {
                            continue;
                        }
                        let ready = free_at[b].max(at);
                        if avail.map_or(true, |(r, _)| ready < r) {
                            let per = svc_on!(
                                b,
                                s.service_cycles_capped(
                                    ccfg.max_batch as u64,
                                    ref_freq,
                                    &shared,
                                    demand,
                                    capacity_factor[b]
                                )
                            );
                            avail = Some((ready, per));
                        }
                    }
                    // Cycles of service the class grants its least-charged
                    // member before this tenant's DRR turn comes around
                    // again (weight-normalized deficit gap).
                    let gap = {
                        let members = classes
                            .iter()
                            .find(|c| c.iter().any(|&m| m == t))
                            .expect("every tenant is in a class");
                        let min_norm = members
                            .iter()
                            .map(|&m| charge[m] as f64 / w_of[m])
                            .fold(f64::INFINITY, f64::min);
                        ((charge[t] as f64 / w_of[t]) - min_norm).max(0.0)
                    };
                    let predicted_ms = match avail {
                        // No live replica: no deadline can be met.
                        None => f64::INFINITY,
                        Some((ready, per)) => {
                            let batches_ahead = (depth / ccfg.max_batch) as u64;
                            let done = ready + batches_ahead.saturating_mul(per) + per;
                            (done.saturating_sub(at) as f64 + gap) * ns_per_cycle / 1e6
                        }
                    };
                    if depth < opol.max_queue && predicted_ms <= opol.deadline_ms {
                        pend[t].push_back((req, false));
                    } else {
                        n_shed[t] += 1;
                        sink.record(|| TraceEvent::Shed {
                            at,
                            tenant: t,
                            attempt,
                            queue_depth: depth,
                        });
                        if attempt >= opol.retry.max_attempts {
                            n_abandoned[t] += 1;
                            abandon_mask[t][req] = true;
                            sink.record(|| TraceEvent::Abandon {
                                at,
                                tenant: t,
                                attempts: attempt,
                            });
                        } else {
                            let next = attempt + 1;
                            let u = Rng::new(
                                tenant_seed(ccfg.seed, t)
                                    ^ (req as u64).wrapping_mul(0xA24BAED4963EE407)
                                    ^ (next as u64).wrapping_mul(0x9FB21C651E98DF25),
                            )
                            .next_f64();
                            let backoff_ms = opol.retry.backoff_base_ms
                                * (1u64 << attempt.min(20)) as f64
                                * (1.0 + opol.retry.jitter * u);
                            let idx = retry_table.len();
                            retry_table.push((t, req, next));
                            events
                                .schedule(at + ms_to_cycles(backoff_ms).max(1), nb + nt + nf + idx);
                        }
                    }
                }
            }
        }};
    }

    // Dispatch one replicated batch of tenant `t` on free board `b` at `at`.
    macro_rules! dispatch_replicated {
        ($t:expr, $b:expr, $at:expr) => {{
            let (t, b, at) = ($t, $b, $at);
            let k = pend[t].len().min(ccfg.max_batch);
            let mut reqs = scratch.take_reqs();
            reqs.reserve(k);
            let mut penalized = false;
            for _ in 0..k {
                let (r, p) = pend[t].pop_front().expect("non-empty");
                penalized |= p;
                reqs.push(r);
            }
            let s = &cur_plans[t].shards[shard_idx[t][b].expect("hosted")];
            let penalty = if penalized {
                match ccfg.preempt_mode {
                    PreemptMode::Restart => ccfg.preempt_restart_cycles,
                    PreemptMode::Resume => ccfg.preempt_refill_cycles,
                }
            } else {
                0
            };
            let svc = svc_on!(
                b,
                s.service_cycles_capped(k as u64, ref_freq, &shared, demand, capacity_factor[b])
            ) + penalty;
            // Per-item completion instants, so a later preemption can keep
            // the finished prefix (Resume only — Restart re-does the work).
            let prefix_done: Vec<u64> = if ccfg.preempt_mode == PreemptMode::Resume {
                let mut pd = scratch.take_prefix();
                pd.extend((1..=k as u64).map(|j| {
                    at + penalty
                        + svc_on!(
                            b,
                            s.service_cycles_capped(
                                j,
                                ref_freq,
                                &shared,
                                demand,
                                capacity_factor[b]
                            )
                        )
                }));
                pd
            } else {
                Vec::new()
            };
            let done = at + svc;
            free_at[b] = done;
            batches[b] += 1;
            // Deficit is logged as it stood when admission was decided —
            // before this dispatch's own bill lands.
            let deficit = charge[t];
            sink.record(|| TraceEvent::Admit { at, tenant: t, board: b, items: k, deficit });
            sink.record(|| TraceEvent::Dispatch { at, tenant: t, board: b, items: k, done });
            board_state[b] = Some(Running {
                tenant: t,
                start: at,
                done,
                reqs,
                prefix_done,
            });
            events.schedule(done, b);
            charge[t] += svc;
        }};
    }

    // The pending members of one DRR class, ordered by ascending normalized
    // deficit (billed cycles / weight; cross-multiplied so no division),
    // ties to the lower tenant index. A singleton class reduces to the old
    // strict per-tenant drain. Fills `scratch.cands` in place (one
    // allocation for the whole run); `total_cmp` keeps the order defined
    // even if a degenerate weight product escapes to non-finite.
    macro_rules! class_candidates {
        ($members:expr) => {{
            scratch.cands.clear();
            scratch
                .cands
                .extend($members.iter().copied().filter(|&t| !pend[t].is_empty()));
            scratch.cands.sort_by(|&a, &b| {
                (charge[a] as f64 * w_of[b])
                    .total_cmp(&(charge[b] as f64 * w_of[a]))
                    .then(a.cmp(&b))
            });
        }};
    }

    // Run every tenant's admission/preemption at event instant `at` until a
    // full pass dispatches nothing.
    macro_rules! dispatch_all {
        ($at:expr) => {{
            let at = $at;
            loop {
                let mut dispatched = false;
                // Phase 1: free-board admission — classes in priority order,
                // deficit-weighted round-robin within a class.
                for members in &classes {
                    loop {
                        class_candidates!(members);
                        let mut advanced = false;
                        // Index walk: the dispatch macros inside reborrow
                        // `scratch` for their recycled work-lists.
                        for ci in 0..scratch.cands.len() {
                            let t = scratch.cands[ci];
                            match specs[t].mode {
                                ShardMode::Replicated => {
                                    // Fastest free hosting board, then lowest
                                    // index — the BoardPool idle tie-breaks,
                                    // done as a scan over the tenant's hosting
                                    // set: co-residency invalidates a per-tenant
                                    // heap on every foreign dispatch/preemption,
                                    // and hosting sets are at most `boards` wide,
                                    // so the scan is the simpler O(boards) here.
                                    let mut pick: Option<usize> = None;
                                    for s in &cur_plans[t].shards {
                                        let b = s.board;
                                        if board_up[b]
                                            && board_state[b].is_none()
                                            && free_at[b] <= at
                                        {
                                            let better = match pick {
                                                None => true,
                                                Some(p) => {
                                                    fleet[b].platform.freq_mhz
                                                        > fleet[p].platform.freq_mhz
                                                }
                                            };
                                            if better {
                                                pick = Some(b);
                                            }
                                        }
                                    }
                                    if let Some(b) = pick {
                                        dispatch_replicated!(t, b, at);
                                        advanced = true;
                                    }
                                }
                                ShardMode::Pipelined => {
                                    // A chain launches when its entry stage is
                                    // free; later stages serialize on the
                                    // shared timeline. Every stage board must
                                    // be up: a chain needs its whole board set
                                    // at once, so a dead stage blocks new
                                    // launches until recovery or an emergency
                                    // re-shard moves the chain.
                                    let first = cur_plans[t].shards[0].board;
                                    let chain_up =
                                        cur_plans[t].shards.iter().all(|s| board_up[s.board]);
                                    if chain_up
                                        && board_state[first].is_none()
                                        && free_at[first] <= at
                                    {
                                        let k = pend[t].len().min(ccfg.max_batch);
                                        let mut reqs = scratch.take_reqs();
                                        reqs.reserve(k);
                                        let mut penalized = false;
                                        for _ in 0..k {
                                            let (r, p) =
                                                pend[t].pop_front().expect("non-empty");
                                            penalized |= p;
                                            reqs.push(r);
                                        }
                                        let bsz = k as u64;
                                        let stages = cur_plans[t].used_boards();
                                        let deficit = charge[t];
                                        sink.record(|| TraceEvent::Admit {
                                            at,
                                            tenant: t,
                                            board: first,
                                            items: k,
                                            deficit,
                                        });
                                        let mut tcur = at;
                                        let mut billed = 0u64;
                                        for (si, s) in cur_plans[t].shards.iter().enumerate() {
                                            let mut svc = svc_on!(
                                                s.board,
                                                s.service_cycles_capped(
                                                    bsz,
                                                    ref_freq,
                                                    &shared,
                                                    demand,
                                                    capacity_factor[s.board]
                                                )
                                            );
                                            if si == 0 && penalized {
                                                svc += match ccfg.preempt_mode {
                                                    PreemptMode::Restart => {
                                                        ccfg.preempt_restart_cycles
                                                    }
                                                    PreemptMode::Resume => {
                                                        ccfg.preempt_refill_cycles
                                                    }
                                                };
                                            }
                                            let start = tcur.max(free_at[s.board]);
                                            let done = start + svc;
                                            let sb = s.board;
                                            free_at[sb] = done;
                                            busy[sb] += svc;
                                            items[sb] += bsz;
                                            batches[sb] += 1;
                                            billed += svc;
                                            events.schedule(done, sb);
                                            sink.record(|| TraceEvent::Dispatch {
                                                at: start,
                                                tenant: t,
                                                board: sb,
                                                items: k,
                                                done,
                                            });
                                            tcur = done;
                                            if si + 1 < stages {
                                                let bytes = s.egress_bytes * bsz;
                                                link_bytes_total += bytes;
                                                tcur = match fabric.as_mut() {
                                                    Some(f) => {
                                                        let (src, dst) = (
                                                            sb,
                                                            cur_plans[t].shards[si + 1].board,
                                                        );
                                                        let route = f.route(src, dst);
                                                        let end =
                                                            f.transfer_route(&route, bytes, tcur);
                                                        sink.record(|| {
                                                            TraceEvent::RouteTransfer {
                                                                at: end,
                                                                src,
                                                                dst,
                                                                bytes,
                                                                hops: route.len(),
                                                                class: "boundary",
                                                            }
                                                        });
                                                        end
                                                    }
                                                    None => {
                                                        links_t[t][si].transfer(bytes, tcur)
                                                    }
                                                };
                                            }
                                        }
                                        charge[t] += billed;
                                        for &r in &reqs {
                                            record_done!(t, r, tcur);
                                        }
                                        scratch.put_reqs(reqs);
                                        let lastb = cur_plans[t].shards[stages - 1].board;
                                        sink.record(|| TraceEvent::Flush {
                                            at: tcur,
                                            tenant: t,
                                            board: lastb,
                                            items: k,
                                        });
                                        advanced = true;
                                    }
                                }
                            }
                            if advanced {
                                break;
                            }
                        }
                        if !advanced {
                            break;
                        }
                        dispatched = true;
                    }
                }
                // Phase 2: preemption — a still-starved tenant may abort a
                // strictly lower-priority replicated batch (same class
                // ordering as admission; equal classes never preempt each
                // other, so the DRR order only sequences the seekers).
                for members in &classes {
                    loop {
                        class_candidates!(members);
                        let mut advanced = false;
                        for ci in 0..scratch.cands.len() {
                            let t = scratch.cands[ci];
                            if specs[t].mode != ShardMode::Replicated {
                                continue;
                            }
                            let mut victim: Option<(u8, usize)> = None;
                            for s in &cur_plans[t].shards {
                                let b = s.board;
                                if let Some(r) = &board_state[b] {
                                    // Only preempt a victim that holds the
                                    // board's LAST reservation: a co-resident
                                    // pipelined chain may already have booked a
                                    // later stage window (free_at > the
                                    // victim's completion), and reclaiming the
                                    // slot then would double-book the board
                                    // under the chain's reservation.
                                    if prio[r.tenant] < prio[t] && free_at[b] == r.done {
                                        let key = (prio[r.tenant], b);
                                        if victim.is_none() || key < victim.unwrap() {
                                            victim = Some(key);
                                        }
                                    }
                                }
                            }
                            let Some((_, b)) = victim else { continue };
                            let r = board_state[b].take().expect("victim running");
                            busy[b] += at - r.start;
                            preemptions[r.tenant] += 1;
                            let vt = r.tenant;
                            let mut rest = r.reqs;
                            // Refund the victim's DRR deficit for service it
                            // will not receive from this dispatch: restart
                            // re-bills everything on re-dispatch, resume
                            // re-bills only the unfinished remainder.
                            // Without the refund, a repeatedly-preempted
                            // tenant's deficit inflates with zero items
                            // delivered and it loses its fair share against
                            // equal-class peers.
                            let refund;
                            if ccfg.preempt_mode == PreemptMode::Resume {
                                // Work-preserving: the served prefix finishes
                                // here; only the remainder re-queues.
                                let j = r.prefix_done.iter().filter(|&&d| d <= at).count();
                                for &req in &rest[..j] {
                                    record_done!(vt, req, at);
                                }
                                items[b] += j as u64;
                                if j > 0 {
                                    sink.record(|| TraceEvent::Flush {
                                        at,
                                        tenant: vt,
                                        board: b,
                                        items: j,
                                    });
                                }
                                refund = if j == 0 {
                                    r.done - r.start
                                } else {
                                    r.done - r.prefix_done[j - 1]
                                };
                                rest.drain(..j);
                            } else {
                                refund = r.done - r.start;
                            }
                            charge[vt] = charge[vt].saturating_sub(refund);
                            let mode = match ccfg.preempt_mode {
                                PreemptMode::Restart => "restart",
                                PreemptMode::Resume => "resume",
                            };
                            sink.record(|| TraceEvent::Preempt {
                                at,
                                board: b,
                                victim: vt,
                                by: t,
                                mode,
                                refunded_cycles: refund,
                            });
                            for &req in rest.iter().rev() {
                                pend[vt].push_front((req, true));
                            }
                            scratch.put_reqs(rest);
                            scratch.put_prefix(r.prefix_done);
                            free_at[b] = at;
                            dispatch_replicated!(t, b, at);
                            advanced = true;
                            break;
                        }
                        if !advanced {
                            break;
                        }
                        dispatched = true;
                    }
                }
                if !dispatched {
                    break;
                }
            }
        }};
    }

    // Re-place the stranded tenants outside the controller window: a board
    // death severed a pipelined chain (or drained a replicated tenant to
    // zero replicas), or a recovery restored a tenant whose earlier replan
    // failed. Placement runs on the live boards only, biased coolest-first
    // by cumulative busy cycles; only the stranded tenants adopt new plans.
    // No fleet-wide stall is billed — the survivors never stop.
    macro_rules! emergency_replan {
        ($at:expr, $b:expr, $stranded:expr, $reason:expr) => {{
            let (at, b, stranded, reason): (u64, usize, &[usize], String) =
                ($at, $b, $stranded, $reason);
            let fplans: Vec<FusionPlan> = cur_plans.iter().map(|p| p.plan.clone()).collect();
            let workloads: Vec<TenantWorkload> = specs
                .iter()
                .zip(weights)
                .zip(&fplans)
                .enumerate()
                .map(|(t, ((spec, w), fp))| TenantWorkload {
                    name: &spec.name,
                    net: &spec.network,
                    weights: w,
                    plan: fp,
                    mode: spec.mode,
                    priority: spec.slo.priority,
                    replicas: if uncapped[t] { None } else { spec.replicas },
                })
                .collect();
            if let Ok(new_plans) = place_tenants_capacity_fabric(
                fleet,
                &workloads,
                &busy,
                &board_up,
                &capacity_factor,
                ccfg.fabric.as_ref(),
            ) {
                let moved: Vec<(usize, String)> =
                    stranded.iter().map(|&t| (t, cur_plans[t].label())).collect();
                let prev_plans = cur_plans.clone();
                for &t in stranded {
                    cur_plans[t] = new_plans[t].clone();
                }
                shard_idx = build_idx(&cur_plans);
                let prev_links = std::mem::take(&mut links_t);
                links_t = rebuild_links(&cur_plans);
                // Survivors keep their in-flight wire state; only pairs the
                // re-plan actually severed start fresh.
                for t in 0..nt {
                    carry_link_state(
                        &prev_plans[t],
                        &prev_links[t],
                        &cur_plans[t],
                        &mut links_t[t],
                    );
                }
                demand = cur_plans.iter().map(|p| fleet_demand(p, ref_freq)).sum();
                n_emergency_reshards += 1;
                let nst = moved.len();
                sink.record(|| TraceEvent::EmergencyReshard { at, board: b, tenants: nst });
                for (t, from) in moved {
                    reshard_events.push(ReshardEvent {
                        at_cycle: at,
                        from,
                        to: cur_plans[t].label(),
                        reason: reason.clone(),
                        migration_bytes: 0,
                        stall_cycles: 0,
                        tenant: Some(specs[t].name.clone()),
                    });
                }
            }
            // A failed placement leaves the stranded tenants' queues
            // waiting; recovery (or a later controller window) retries.
        }};
    }

    // Handle one event; dispatching happens once per instant, after every
    // event at that instant has been folded in.
    macro_rules! handle {
        ($at:expr, $id:expr) => {{
            let (at, id) = ($at, $id);
            if id >= nb + nt + nf {
                // ---- retry re-arrival (client backoff model) ----
                let (t, req, attempt) = retry_table[id - nb - nt - nf];
                n_retried[t] += 1;
                sink.record(|| TraceEvent::Retry { at, tenant: t, attempt });
                admit!(t, req, attempt, at);
            } else if id >= nb + nt {
                // ---- scripted fault ----
                match &fault_timeline[id - nb - nt].1 {
                    FaultAction::Fail(fb) => {
                        let b = *fb;
                        if board_up[b] {
                            board_up[b] = false;
                            n_board_failures += 1;
                            fault_log.push((at, None, b));
                            // Abort the board's in-flight replicated batch
                            // with the preemption protocol's accounting:
                            // under Resume the finished prefix completes on
                            // the spot, the remainder re-queues at the head
                            // with the penalty flag; under Restart the whole
                            // batch re-queues.
                            let mut requeued = 0usize;
                            let mut drained_tenant: Option<usize> = None;
                            if let Some(r) = board_state[b].take() {
                                busy[b] += at - r.start;
                                let vt = r.tenant;
                                drained_tenant = Some(vt);
                                let mut rest = r.reqs;
                                let refund;
                                if ccfg.preempt_mode == PreemptMode::Resume {
                                    let j =
                                        r.prefix_done.iter().filter(|&&d| d <= at).count();
                                    for &req in &rest[..j] {
                                        record_done!(vt, req, at);
                                    }
                                    items[b] += j as u64;
                                    if j > 0 {
                                        sink.record(|| TraceEvent::Flush {
                                            at,
                                            tenant: vt,
                                            board: b,
                                            items: j,
                                        });
                                    }
                                    refund = if j == 0 {
                                        r.done - r.start
                                    } else {
                                        r.done - r.prefix_done[j - 1]
                                    };
                                    rest.drain(..j);
                                } else {
                                    refund = r.done - r.start;
                                }
                                charge[vt] = charge[vt].saturating_sub(refund);
                                requeued = rest.len();
                                for &req in rest.iter().rev() {
                                    pend[vt].push_front((req, true));
                                }
                                scratch.put_reqs(rest);
                                scratch.put_prefix(r.prefix_done);
                                free_at[b] = at;
                            }
                            items_requeued += requeued as u64;
                            sink.record(|| TraceEvent::BoardFail { at, board: b, requeued });
                            // Replicated tenants drain to surviving peers by
                            // dropping the dead replica; a tenant losing its
                            // last replica — or any pipelined chain with a
                            // stage here — is stranded and needs an
                            // emergency re-shard excluding the dead board.
                            let mut stranded: Vec<usize> = Vec::new();
                            for t in 0..nt {
                                if shard_idx[t][b].is_none() {
                                    continue;
                                }
                                match specs[t].mode {
                                    ShardMode::Replicated => {
                                        cur_plans[t].shards.retain(|s| s.board != b);
                                        if cur_plans[t].shards.is_empty() {
                                            stranded.push(t);
                                        }
                                    }
                                    ShardMode::Pipelined => stranded.push(t),
                                }
                            }
                            // The retain above shifted shard indexes; keep
                            // the hosting map honest even when the replan
                            // below fails (survivors' link channels keep
                            // their occupancy state on this path).
                            shard_idx = build_idx(&cur_plans);
                            demand = cur_plans.iter().map(|p| fleet_demand(p, ref_freq)).sum();
                            // Drain-to-peers: the aborted batch's re-queued
                            // input state rides the fabric from the dead
                            // board to the tenant's first surviving replica
                            // (one input activation per re-queued request;
                            // a severed chain re-plans below instead).
                            if requeued > 0 {
                                if let (Some(f), Some(vt)) = (fabric.as_mut(), drained_tenant) {
                                    if specs[vt].mode == ShardMode::Replicated {
                                        if let Some(peer) = cur_plans[vt].shards.first() {
                                            let item = (specs[vt].network.shapes()[0].elems()
                                                * word_bytes)
                                                as u64;
                                            let bytes = requeued as u64 * item;
                                            let dst = peer.board;
                                            let route = f.route(b, dst);
                                            let end = f.transfer_route(&route, bytes, at);
                                            sink.record(|| TraceEvent::RouteTransfer {
                                                at: end,
                                                src: b,
                                                dst,
                                                bytes,
                                                hops: route.len(),
                                                class: "drain",
                                            });
                                        }
                                    }
                                }
                            }
                            if !stranded.is_empty() {
                                emergency_replan!(at, b, &stranded, format!("board {b} down"));
                            }
                        }
                    }
                    FaultAction::Recover(fb) => {
                        let b = *fb;
                        if !board_up[b] {
                            board_up[b] = true;
                            n_board_recoveries += 1;
                            if let Some(e) =
                                fault_log.iter_mut().rev().find(|e| e.2 == b && e.1.is_none())
                            {
                                e.1 = Some(at);
                            }
                            free_at[b] = free_at[b].max(at);
                            // Re-admission into the rotation happens at the
                            // next controller window (coolest-first bias
                            // favors the idle returner); tenants stranded by
                            // a failed replan while the board was down are
                            // restored immediately.
                            readmit_pending = true;
                            sink.record(|| TraceEvent::BoardRecover { at, board: b });
                            let stranded: Vec<usize> = (0..nt)
                                .filter(|&t| cur_plans[t].shards.is_empty())
                                .collect();
                            if !stranded.is_empty() {
                                emergency_replan!(
                                    at,
                                    b,
                                    &stranded,
                                    format!("board {b} recovered")
                                );
                            }
                        }
                    }
                    FaultAction::Degrade(src, factor, until) => {
                        // The slow windows are pre-baked into the link
                        // channels; this event marks the start in the trace
                        // and wakes the dispatcher.
                        n_link_degrades += 1;
                        let (src, factor, until) = (*src, *factor, *until);
                        sink.record(|| TraceEvent::LinkDegrade {
                            at,
                            board: src,
                            factor,
                            until,
                        });
                    }
                    FaultAction::Derate(fb, factor) => {
                        clock_factor[*fb] = *factor;
                        n_clock_derates += 1;
                    }
                    FaultAction::CapDegrade(fb, frac, until) => {
                        capacity_factor[*fb] = *frac;
                        n_compute_degrades += 1;
                        capacity_pending = true;
                        let (b, f, u) = (*fb, *frac, *until);
                        sink.record(|| TraceEvent::ComputeDegrade {
                            at,
                            board: b,
                            fraction: f,
                            until: u,
                        });
                    }
                    FaultAction::CapRestore(fb) => {
                        capacity_factor[*fb] = 1.0;
                        capacity_pending = true;
                    }
                }
            } else if id >= nb {
                let t = id - nb;
                let req = cursor[t];
                cursor[t] += 1;
                if cursor[t] < arrivals[t].len() {
                    events.schedule(arrivals[t][cursor[t]], nb + t);
                }
                admit!(t, req, 0, at);
            } else if matches!(&board_state[id], Some(r) if r.done == at) {
                let r = board_state[id].take().expect("running");
                busy[id] += r.done - r.start;
                let k = r.reqs.len();
                items[id] += k as u64;
                let tn = r.tenant;
                for &req in &r.reqs {
                    record_done!(tn, req, at);
                }
                scratch.put_reqs(r.reqs);
                scratch.put_prefix(r.prefix_done);
                sink.record(|| TraceEvent::Flush { at, tenant: tn, board: id, items: k });
            }
            // Post-migration wake events (and stale completions) fall
            // through: the dispatch pass below re-examines the fleet.
        }};
    }

    // Evaluate the controller window at event instant `at`: per-tenant SLO
    // triggers + utilization skew, then a biased re-placement with SLO-
    // missing tenants uncapped.
    macro_rules! controller {
        ($at:expr) => {{
            let at = $at;
            if let Some(pol) = &policy {
                if win_count >= pol.window {
                    let span = at.saturating_sub(win_start);
                    let mut skew = 0.0f64;
                    if span > 0 {
                        let mut lo = f64::INFINITY;
                        let mut hi = 0.0f64;
                        for b in 0..nb {
                            if shard_idx.iter().any(|per_t| per_t[b].is_some()) {
                                let u =
                                    busy[b].saturating_sub(win_busy0[b]) as f64 / span as f64;
                                lo = lo.min(u);
                                hi = hi.max(u);
                            }
                        }
                        if hi >= lo {
                            skew = hi - lo;
                        }
                    }
                    // Tenant-aware trigger: each tenant's window p99 against
                    // its own SLO target.
                    let mut triggered: Vec<(usize, f64)> = Vec::new();
                    let mut win_p99 = vec![f64::NAN; nt];
                    for t in 0..nt {
                        if win_t[t].is_empty() {
                            continue;
                        }
                        // Exact per-tenant window p99 — this is the re-shard
                        // trigger the fixtures pin, so it keeps the sorted
                        // percentile (into the reusable scratch buffer).
                        let p99 = percentile_sorted(scratch.sorted(&win_t[t]), 99.0);
                        win_p99[t] = p99;
                        if p99 > specs[t].slo.p99_ms {
                            triggered.push((t, p99));
                        }
                    }
                    // Recovery-time objective: first window past the fault
                    // onset whose fleet-wide p99 is back within 1.25× the
                    // pre-fault baseline. The window population is read from
                    // the ≤1%-error sketch (report-only value; no fixture
                    // pins it byte-exact) instead of flattening and sorting
                    // every tenant's window each time; the baseline sorts
                    // once — `pre_fault_lat` is frozen after fault onset.
                    if faults_armed && recovery_at.is_none() && !pre_fault_lat.is_empty() {
                        if let Some(ff) = first_fault_at {
                            if at > ff && win_sketch.total() > 0 {
                                let base = *baseline_p99.get_or_insert_with(|| {
                                    sort_latencies(&mut pre_fault_lat);
                                    percentile_sorted(&pre_fault_lat, 99.0)
                                });
                                if win_sketch.quantile(99.0) <= 1.25 * base {
                                    recovery_at = Some(at);
                                }
                            }
                        }
                    }
                    let win_requests = win_count as u64;
                    sink.record(|| TraceEvent::WindowRollup { at, requests: win_requests });
                    sink.sample_window(|| WindowSample {
                        at,
                        busy_frac: (0..nb)
                            .map(|b| {
                                if span == 0 {
                                    0.0
                                } else {
                                    busy[b].saturating_sub(win_busy0[b]) as f64 / span as f64
                                }
                            })
                            .collect(),
                        queue_depth: (0..nt).map(|t| pend[t].len()).collect(),
                        window_p99_ms: win_p99,
                    });
                    if cooldown > 0 {
                        cooldown -= 1;
                    } else if readmit_pending
                        || capacity_pending
                        || !triggered.is_empty()
                        || skew > pol.util_skew
                    {
                        for &(t, _) in &triggered {
                            uncapped[t] = true;
                        }
                        let reason = match triggered.iter().max_by(|a, b| {
                            (a.1 / specs[a.0].slo.p99_ms)
                                .total_cmp(&(b.1 / specs[b.0].slo.p99_ms))
                        }) {
                            Some(&(t, p99)) => format!(
                                "tenant '{}' window p99 {p99:.2} ms > slo {:.2} ms",
                                specs[t].name, specs[t].slo.p99_ms
                            ),
                            None if skew > pol.util_skew => {
                                format!("utilization skew {skew:.2} > {:.2}", pol.util_skew)
                            }
                            None if capacity_pending => {
                                "compute capacity changed - re-placement".to_string()
                            }
                            None => "board recovered - re-admission".to_string(),
                        };
                        readmit_pending = false;
                        capacity_pending = false;
                        sink.record(|| TraceEvent::ReshardTrigger { at, reason: reason.clone() });
                        // Re-place against the observed load: coolest boards
                        // first, SLO-missing tenants uncapped (scale-out).
                        let bias: Vec<u64> = (0..nb)
                            .map(|b| busy[b].saturating_sub(win_busy0[b]))
                            .collect();
                        let fplans: Vec<FusionPlan> =
                            cur_plans.iter().map(|p| p.plan.clone()).collect();
                        let workloads: Vec<TenantWorkload> = specs
                            .iter()
                            .zip(weights)
                            .zip(&fplans)
                            .enumerate()
                            .map(|(t, ((spec, w), fp))| TenantWorkload {
                                name: &spec.name,
                                net: &spec.network,
                                weights: w,
                                plan: fp,
                                mode: spec.mode,
                                priority: spec.slo.priority,
                                replicas: if uncapped[t] { None } else { spec.replicas },
                            })
                            .collect();
                        if let Ok(new_plans) = place_tenants_capacity_fabric(
                            fleet,
                            &workloads,
                            &bias,
                            &board_up,
                            &capacity_factor,
                            ccfg.fabric.as_ref(),
                        ) {
                            let boards_of = |p: &ShardPlan| -> Vec<usize> {
                                p.shards.iter().map(|s| s.board).collect()
                            };
                            let changed: Vec<usize> = (0..nt)
                                .filter(|&t| {
                                    boards_of(&cur_plans[t]) != boards_of(&new_plans[t])
                                        || cur_plans[t].label() != new_plans[t].label()
                                })
                                .collect();
                            if !changed.is_empty() {
                                // Drain to a sync point, move state, resume
                                // together after the transfer stall.
                                let sync =
                                    free_at.iter().copied().max().unwrap_or(at).max(at);
                                let mut bills: Vec<(usize, u64)> = Vec::new();
                                let mut total_bill = 0u64;
                                for &t in &changed {
                                    let raw = migration_bytes(
                                        &cur_plans[t],
                                        &new_plans[t],
                                        &weights[t],
                                        word_bytes,
                                        specs[t].network.layers.len(),
                                        nb,
                                    );
                                    let bill = checked_round_u64(
                                        raw as f64 * pol.migration_factor,
                                        "migration bill",
                                    );
                                    total_bill += bill;
                                    bills.push((t, bill));
                                }
                                let stall = match fabric.as_mut() {
                                    Some(f) => {
                                        // Each changed tenant's state moves
                                        // over its own route (old entry
                                        // stage → new entry stage); the
                                        // fleet resumes when the last drain
                                        // lands on its destination rack.
                                        let mut resume = sync;
                                        for &(t, bill) in &bills {
                                            let (Some(so), Some(sn)) = (
                                                cur_plans[t].shards.first(),
                                                new_plans[t].shards.first(),
                                            ) else {
                                                continue;
                                            };
                                            let (src, dst) = (so.board, sn.board);
                                            let route = f.route(src, dst);
                                            let end = f.transfer_route(&route, bill, sync);
                                            sink.record(|| TraceEvent::RouteTransfer {
                                                at: end,
                                                src,
                                                dst,
                                                bytes: bill,
                                                hops: route.len(),
                                                class: "migration",
                                            });
                                            resume = resume.max(end);
                                        }
                                        resume - sync
                                    }
                                    None => link.transfer_cycles(total_bill),
                                };
                                for (t, bill) in bills {
                                    sink.record(|| TraceEvent::ReshardStall {
                                        at: sync,
                                        tenant: Some(t),
                                        bytes: bill,
                                        stall_cycles: stall,
                                    });
                                    reshard_events.push(ReshardEvent {
                                        at_cycle: sync,
                                        from: cur_plans[t].label(),
                                        to: new_plans[t].label(),
                                        reason: reason.clone(),
                                        migration_bytes: bill,
                                        stall_cycles: stall,
                                        tenant: Some(specs[t].name.clone()),
                                    });
                                }
                                for (b, f) in free_at.iter_mut().enumerate() {
                                    *f = sync + stall;
                                    // Wake the dispatcher when the fleet
                                    // resumes — without this, queued work
                                    // with no future arrival/completion
                                    // event would strand.
                                    events.schedule(sync + stall, b);
                                }
                                sink.record(|| TraceEvent::ReshardWake { at: sync + stall });
                                let prev_plans = std::mem::replace(&mut cur_plans, new_plans);
                                shard_idx = build_idx(&cur_plans);
                                let prev_links = std::mem::take(&mut links_t);
                                links_t = rebuild_links(&cur_plans);
                                // Wires between surviving board pairs keep
                                // their odometers and in-flight occupancy
                                // across the plan swap.
                                for t in 0..nt {
                                    carry_link_state(
                                        &prev_plans[t],
                                        &prev_links[t],
                                        &cur_plans[t],
                                        &mut links_t[t],
                                    );
                                }
                                demand =
                                    cur_plans.iter().map(|p| fleet_demand(p, ref_freq)).sum();
                                cooldown = pol.cooldown_windows;
                            }
                        }
                        // A failed placement keeps the current plans; the
                        // next window may try again.
                    }
                    win_count = 0;
                    for w in &mut win_t {
                        w.clear();
                    }
                    // Window-scoped sketch: reset (bins zeroed in place),
                    // never reallocated. Empty on healthy runs — skip.
                    if win_sketch.total() > 0 {
                        win_sketch.reset();
                    }
                    win_start = at;
                    win_busy0.copy_from_slice(&busy);
                }
            }
        }};
    }

    while let Some((at, id)) = events.pop() {
        sink.note_sim_event(events.len());
        handle!(at, id);
        while let Some((at2, id2)) = events.next_at_or_before(at) {
            sink.note_sim_event(events.len());
            handle!(at2, id2);
        }
        dispatch_all!(at);
        controller!(at);
    }
    debug_assert!(events.is_empty(), "event drain must exhaust the queue");

    for (t, mask) in done_mask.iter().enumerate() {
        // Conservation: every request either completed or was abandoned,
        // exactly one of the two. Without an overload policy the abandon
        // mask is all-false and this is the old all-done assertion.
        assert!(
            mask.iter()
                .zip(&abandon_mask[t])
                .all(|(&d, &a)| d ^ a),
            "tenant '{}' lost requests — scheduler bug",
            specs[t].name
        );
        assert_eq!(
            served[t] + n_abandoned[t],
            specs[t].requests as u64,
            "tenant '{}' offered != completed + abandoned — double service or leak",
            specs[t].name
        );
    }

    // ---- reporting ----
    // Abandoned requests have no completion; the latency populations carry
    // completed requests only (identical to the old all-requests walk when
    // no overload policy is armed).
    let lat_of = |t: usize| -> Vec<f64> {
        complete[t]
            .iter()
            .zip(&arrivals[t])
            .enumerate()
            .filter(|&(i, _)| !abandon_mask[t][i])
            .map(|(_, (&c, &a))| c.saturating_sub(a) as f64 * ns_per_cycle / 1e6)
            .collect()
    };
    let tenants: Vec<TenantStats> = specs
        .iter()
        .enumerate()
        .map(|(t, s)| {
            let mut lat = lat_of(t);
            sort_latencies(&mut lat);
            // An all-abandoned tenant has no latency population; zeros
            // beat NaN (unreachable without an overload policy, so the
            // healthy numbers are untouched).
            let (mean_ms, p50_ms, p99_ms) = if lat.is_empty() {
                (0.0, 0.0, 0.0)
            } else {
                (
                    lat.iter().sum::<f64>() / lat.len() as f64,
                    percentile_sorted(&lat, 50.0),
                    percentile_sorted(&lat, 99.0),
                )
            };
            let span = complete[t].iter().copied().max().unwrap_or(0);
            let span_s = span as f64 * ns_per_cycle / 1e9;
            let completed_n = done_mask[t].iter().filter(|&&d| d).count();
            // Post-settle tail: p99 over the final controller window of
            // completions, in completion order (armed controller only).
            let tail_p99_ms = policy.as_ref().and_then(|_| {
                if tail_lat[t].is_empty() {
                    return None;
                }
                let mut tail: Vec<f64> = tail_lat[t].iter().copied().collect();
                sort_latencies(&mut tail);
                Some(percentile_sorted(&tail, 99.0))
            });
            // SLO attainment through outages: of the requests completing
            // while any board was down, the fraction within this tenant's
            // SLO target (1.0 when no completion overlapped an outage).
            let slo_attainment_outage = if faults_armed {
                let mut in_outage = 0usize;
                let mut within = 0usize;
                for (i, &c) in complete[t].iter().enumerate() {
                    if abandon_mask[t][i] {
                        continue;
                    }
                    let overlaps = fault_log
                        .iter()
                        .any(|&(f, r, _)| c >= f && c < r.unwrap_or(u64::MAX));
                    if overlaps {
                        in_outage += 1;
                        let l = c.saturating_sub(arrivals[t][i]) as f64 * ns_per_cycle / 1e6;
                        if l <= s.slo.p99_ms {
                            within += 1;
                        }
                    }
                }
                Some(if in_outage == 0 {
                    1.0
                } else {
                    within as f64 / in_outage as f64
                })
            } else {
                None
            };
            TenantStats {
                name: s.name.clone(),
                priority: s.slo.priority,
                requests: s.requests,
                // Measured (each request flagged done exactly once; `served`
                // counts completions), not echoed from the spec — the
                // conservation assertions above make these real checks.
                completed: completed_n,
                items: served[t],
                preemptions: preemptions[t],
                mean_ms,
                p50_ms,
                p99_ms,
                throughput_rps: if span_s > 0.0 {
                    s.requests as f64 / span_s
                } else {
                    0.0
                },
                slo_p99_ms: s.slo.p99_ms,
                slo_met: p99_ms <= s.slo.p99_ms,
                tail_p99_ms,
                slo_attainment_outage,
                shed: if overload_armed { Some(n_shed[t]) } else { None },
                retried: if overload_armed { Some(n_retried[t]) } else { None },
                abandoned: if overload_armed { Some(n_abandoned[t]) } else { None },
                goodput_rps: if overload_armed {
                    Some(if span_s > 0.0 {
                        completed_n as f64 / span_s
                    } else {
                        0.0
                    })
                } else {
                    None
                },
            }
        })
        .collect();

    let makespan_cycles = (0..nt)
        .filter_map(|t| complete[t].iter().copied().max())
        .max()
        .unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut all_lat: Vec<f64> = (0..nt).flat_map(lat_of).collect();
    sort_latencies(&mut all_lat);
    let (mean_ms, all_p50, all_p99) = if all_lat.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            all_lat.iter().sum::<f64>() / all_lat.len() as f64,
            percentile_sorted(&all_lat, 50.0),
            percentile_sorted(&all_lat, 99.0),
        )
    };
    let total_requests: usize = specs.iter().map(|s| s.requests).sum();
    let total_completed: usize = served.iter().map(|&s| s as usize).sum();

    let per_board: Vec<BoardStats> = (0..nb)
        .map(|b| BoardStats {
            board: b,
            items: items[b],
            batches: batches[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: fleet[b].platform.freq_mhz,
        })
        .collect();
    let hosted: Vec<bool> = (0..nb)
        .map(|b| shard_idx.iter().any(|per_t| per_t[b].is_some()))
        .collect();
    let used_boards = hosted.iter().filter(|&&h| h).count();

    let faults = if faults_armed {
        // Pre-fault and post-recovery latency populations, fleet-wide.
        let mut pre: Vec<f64> = Vec::new();
        let mut post: Vec<f64> = Vec::new();
        for t in 0..nt {
            for (i, &c) in complete[t].iter().enumerate() {
                let l = c.saturating_sub(arrivals[t][i]) as f64 * ns_per_cycle / 1e6;
                if let Some(ff) = first_fault_at {
                    if c < ff {
                        pre.push(l);
                    }
                }
                if c >= recovery_boundary {
                    post.push(l);
                }
            }
        }
        sort_latencies(&mut pre);
        sort_latencies(&mut post);
        let downtime_cycles = fault_log
            .iter()
            .map(|&(f, r, _)| r.unwrap_or(makespan_cycles).saturating_sub(f))
            .sum();
        Some(FaultSummary {
            board_failures: n_board_failures,
            board_recoveries: n_board_recoveries,
            link_degrades: n_link_degrades,
            clock_derates: n_clock_derates,
            compute_degrades: n_compute_degrades,
            emergency_reshards: n_emergency_reshards,
            items_requeued,
            downtime_cycles,
            pre_fault_p99_ms: if pre.is_empty() {
                None
            } else {
                Some(percentile_sorted(&pre, 99.0))
            },
            recovery_p99_ms: if post.is_empty() {
                None
            } else {
                Some(percentile_sorted(&post, 99.0))
            },
            recovery_time_ms: recovery_at.and_then(|r| {
                first_fault_at.map(|ff| r.saturating_sub(ff) as f64 * ns_per_cycle / 1e6)
            }),
        })
    } else {
        None
    };

    FleetReport {
        mode: cur_plans[0].mode,
        boards: nb,
        used_boards,
        idle_boards: nb - used_boards,
        requests: total_requests,
        completed: total_completed,
        makespan_cycles,
        throughput_rps: if makespan_s > 0.0 {
            total_requests as f64 / makespan_s
        } else {
            0.0
        },
        mean_ms,
        p50_ms: all_p50,
        p99_ms: all_p99,
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events,
        tenants,
        shed_total: if overload_armed {
            Some(n_shed.iter().sum())
        } else {
            None
        },
        retried_total: if overload_armed {
            Some(n_retried.iter().sum())
        } else {
            None
        },
        abandoned_total: if overload_armed {
            Some(n_abandoned.iter().sum())
        } else {
            None
        },
        goodput_rps: if overload_armed {
            Some(if makespan_s > 0.0 {
                total_completed as f64 / makespan_s
            } else {
                0.0
            })
        } else {
            None
        },
        faults,
        telemetry: sink.summary(),
        fabric: fabric.as_ref().map(|f| f.summary(makespan_cycles)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::engine::Weights;
    use crate::accel::fusion::FusionPlan;
    use crate::config::{vgg16_prefix, Platform};

    fn setup() -> (AccelConfig, crate::config::Network, Weights) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        (AccelConfig::paper_default(), net, w)
    }

    fn slow_gen() -> AccelConfig {
        AccelConfig {
            platform: Platform::virtex7_older_gen(),
            ..AccelConfig::paper_default()
        }
    }

    // ---- fast-path hardening: sort + checked-cast units ----

    #[test]
    fn nan_adjacent_population_sorts_without_panic() {
        // Regression: the old `partial_cmp(..).unwrap()` comparator panicked
        // on the first NaN in a latency population. total_cmp must instead
        // produce a defined order with NaNs last.
        let mut lat = vec![3.0, f64::NAN, 1.0, 2.0, f64::NAN, 0.5];
        sort_latencies(&mut lat);
        assert_eq!(&lat[..4], &[0.5, 1.0, 2.0, 3.0]);
        assert!(lat[4].is_nan() && lat[5].is_nan());
        // Percentiles over the finite prefix stay meaningful.
        assert_eq!(percentile_sorted(&lat[..4], 50.0), 1.0);
    }

    #[test]
    fn sort_latencies_matches_old_comparator_on_finite_data() {
        // On NaN-free populations (every committed fixture) the total order
        // is identical to the partial order it replaced, including -0.0/+0.0
        // ties which percentile extraction cannot distinguish.
        let mut rng = Rng::new(41);
        let mut a: Vec<f64> = (0..256).map(|_| rng.next_f64() * 50.0).collect();
        let mut b = a.clone();
        sort_latencies(&mut a);
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn checked_round_u64_accepts_the_representable_range() {
        assert_eq!(checked_round_u64(0.0, "t"), 0);
        assert_eq!(checked_round_u64(0.4, "t"), 0);
        assert_eq!(checked_round_u64(0.5, "t"), 1);
        assert_eq!(checked_round_u64(1e15, "t"), 1_000_000_000_000_000);
        // Largest f64 strictly below 2^64 still converts.
        let below = (u64::MAX as f64) * (1.0 - f64::EPSILON);
        assert!(checked_round_u64(below, "t") > 0);
    }

    #[test]
    #[should_panic(expected = "does not round into the u64 timeline")]
    fn checked_round_u64_rejects_nan() {
        checked_round_u64(f64::NAN, "t");
    }

    #[test]
    #[should_panic(expected = "does not round into the u64 timeline")]
    fn checked_round_u64_rejects_negative() {
        checked_round_u64(-1.0, "t");
    }

    #[test]
    #[should_panic(expected = "does not round into the u64 timeline")]
    fn checked_round_u64_rejects_infinity() {
        checked_round_u64(f64::INFINITY, "t");
    }

    #[test]
    #[should_panic(expected = "does not round into the u64 timeline")]
    fn checked_round_u64_rejects_two_pow_64() {
        checked_round_u64(u64::MAX as f64, "t"); // rounds to exactly 2^64
    }

    #[test]
    fn ms_to_cycles_checked_pins_the_fixture_arithmetic() {
        // The fault-timeline tests pin `(ms * ref_freq * 1e3).round() as u64`
        // — the checked helper must evaluate the identical expression.
        for &(ms, f) in &[(0.2, 150.0), (1.0, 150.0), (2.5, 100.0), (0.05, 75.0)] {
            assert_eq!(ms_to_cycles_checked(ms, f), (ms * f * 1e3).round() as u64);
        }
    }

    #[test]
    #[should_panic(expected = "ms_to_cycles")]
    fn ms_to_cycles_checked_rejects_negative_ms() {
        ms_to_cycles_checked(-0.2, 150.0);
    }

    fn burst_cfg(boards: usize, mode: ShardMode) -> ClusterConfig {
        ClusterConfig {
            boards,
            mode,
            board_specs: vec![],
            link_bytes_per_cycle: f64::INFINITY,
            link_latency_cycles: 0,
            aggregate_ddr_bytes_per_cycle: None,
            arrival_rps: f64::INFINITY,
            load_steps: vec![],
            requests: 96,
            seed: 7,
            max_batch: 1,
            max_wait_us: 0.0,
            reshard: None,
            tenants: vec![],
            preempt_restart_cycles: 500,
            preempt_mode: PreemptMode::Restart,
            preempt_refill_cycles: 100,
            faults: None,
            fabric: None,
        }
    }

    #[test]
    fn poisson_arrivals_deterministic_and_monotone() {
        let a = poisson_arrivals(64, 1000.0, 120.0, 9);
        let b = poisson_arrivals(64, 1000.0, 120.0, 9);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival ≈ 120e6/1000 = 120k cycles; loose 3σ band.
        let mean = a.last().unwrap() / 64;
        assert!((40_000..400_000).contains(&mean), "mean gap {mean}");
        assert_eq!(poisson_arrivals(5, f64::INFINITY, 120.0, 1), vec![0; 5]);
    }

    #[test]
    fn poisson_arrivals_seed_sensitivity() {
        // Same seed → bit-identical; different seeds → different sample
        // paths (the determinism CI leans on).
        let a = poisson_arrivals(128, 500.0, 120.0, 42);
        let b = poisson_arrivals(128, 500.0, 120.0, 42);
        let c = poisson_arrivals(128, 500.0, 120.0, 43);
        assert_eq!(a, b);
        assert_ne!(a, c, "distinct seeds must sample distinct paths");
        // And the empty-steps form is exactly the classic generator.
        let d = arrivals_with_steps(128, 500.0, &[], 120.0, 42);
        assert_eq!(a, d);
    }

    #[test]
    fn load_step_speeds_up_arrivals() {
        let steps = [LoadStep {
            at_request: 64,
            rps: 4000.0,
        }];
        let a = arrivals_with_steps(128, 200.0, &steps, 120.0, 5);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "monotone");
        // Mean gap before the step ≫ mean gap after it.
        let pre_span = (a[63] - a[0]) as f64 / 63.0;
        let post_span = (a[127] - a[64]) as f64 / 63.0;
        assert!(
            pre_span > 4.0 * post_span,
            "step must densify arrivals: pre {pre_span:.0} post {post_span:.0}"
        );
        // Deterministic too.
        assert_eq!(a, arrivals_with_steps(128, 200.0, &steps, 120.0, 5));
    }

    #[test]
    fn replicated_burst_splits_work_evenly() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 4);
        let r = simulate_fleet(&cfg, &shard, &burst_cfg(4, ShardMode::Replicated));
        assert_eq!(r.completed, 96);
        assert_eq!(r.per_board.len(), 4);
        for b in &r.per_board {
            assert_eq!(b.items, 24, "round-robin split");
            assert!(b.utilization > 0.9, "burst keeps boards busy: {b:?}");
        }
        assert_eq!(r.link_bytes_total, 0);
        assert_eq!(r.ddr_slowdown, 1.0);
        assert_eq!(r.idle_boards, 0);
        assert!(r.reshard_events.is_empty());
    }

    #[test]
    fn batching_amortizes_overheads() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7); // many groups → big fill/drain
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut c1 = burst_cfg(2, ShardMode::Replicated);
        c1.max_batch = 1;
        let mut c8 = c1.clone();
        c8.max_batch = 8;
        let r1 = simulate_fleet(&cfg, &shard, &c1);
        let r8 = simulate_fleet(&cfg, &shard, &c8);
        assert!(
            r8.throughput_rps > r1.throughput_rps,
            "batch 8 {} ≤ batch 1 {}",
            r8.throughput_rps,
            r1.throughput_rps
        );
    }

    #[test]
    fn contention_never_helps() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 8);
        let free = burst_cfg(8, ShardMode::Replicated);
        let mut tight = free.clone();
        // Pool worth two boards for an 8-board fleet → 4× slowdown.
        tight.aggregate_ddr_bytes_per_cycle = Some(2.0 * cfg.platform.ddr_bytes_per_cycle);
        let r_free = simulate_fleet(&cfg, &shard, &free);
        let r_tight = simulate_fleet(&cfg, &shard, &tight);
        assert!(r_tight.throughput_rps < r_free.throughput_rps);
        assert_eq!(r_tight.ddr_slowdown, 4.0);
        assert!(r_tight.p99_ms > r_free.p99_ms);
    }

    #[test]
    fn pipelined_burst_counts_link_bytes() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let shard = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let ccfg = burst_cfg(3, ShardMode::Pipelined);
        let r = simulate_fleet(&cfg, &shard, &ccfg);
        assert_eq!(r.completed, 96);
        assert_eq!(
            r.link_bytes_total,
            shard.link_bytes_per_item() * 96,
            "every item crosses every interior link exactly once"
        );
    }

    #[test]
    fn finite_links_serialize_and_slow_the_pipeline() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let shard = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let ideal = burst_cfg(3, ShardMode::Pipelined);
        let mut tight = ideal.clone();
        tight.link_bytes_per_cycle = 0.05; // starved wire
        tight.link_latency_cycles = 500;
        let r_ideal = simulate_fleet(&cfg, &shard, &ideal);
        let r_tight = simulate_fleet(&cfg, &shard, &tight);
        assert!(
            r_tight.throughput_rps < r_ideal.throughput_rps,
            "a starved link must become the bottleneck: {} vs {}",
            r_tight.throughput_rps,
            r_ideal.throughput_rps
        );
        assert_eq!(r_tight.link_bytes_total, r_ideal.link_bytes_total);
    }

    #[test]
    fn hetero_fleet_slower_boards_do_less_replicated_work() {
        // 2 fast + 2 slow replicated boards under the dynamic greedy
        // dispatcher: the fast boards absorb more items.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), cfg.clone(), slow_gen(), slow_gen()];
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated_fleet(&fleet, &net, &w, &plan);
        let mut ccfg = burst_cfg(4, ShardMode::Replicated);
        ccfg.requests = 128;
        ccfg.max_batch = 4;
        let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &ccfg);
        assert_eq!(r.completed, 128);
        let fast_items: u64 = r.per_board[..2].iter().map(|b| b.items).sum();
        let slow_items: u64 = r.per_board[2..].iter().map(|b| b.items).sum();
        assert!(
            fast_items > slow_items,
            "fast boards must absorb more work: {fast_items} vs {slow_items}"
        );
    }

    #[test]
    fn low_load_latency_near_service_time() {
        // At a trickle arrival rate with batch=1, each request is served
        // alone: latency ≈ single-inference cycles.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut ccfg = burst_cfg(2, ShardMode::Replicated);
        ccfg.requests = 32;
        ccfg.arrival_rps = 1.0; // one per second ≫ service time apart
        let r = simulate_fleet(&cfg, &shard, &ccfg);
        let svc_ms = shard.shards[0].item_cycles() as f64 / (cfg.platform.freq_mhz * 1e3);
        assert!(
            (r.p50_ms - svc_ms).abs() / svc_ms < 0.05,
            "p50 {} vs svc {}",
            r.p50_ms,
            svc_ms
        );
    }

    #[test]
    fn dynamic_without_policy_is_a_plain_scheduler() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let fleet = vec![cfg.clone(); 3];
        let shard = ShardPlan::pipelined_fleet(&fleet, &net, &w, &plan);
        let mut ccfg = burst_cfg(3, ShardMode::Pipelined);
        ccfg.requests = 48;
        let r1 = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard.clone(), &ccfg);
        let r2 = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &ccfg);
        assert_eq!(r1.completed, 48);
        assert!(r1.reshard_events.is_empty());
        assert_eq!(r1.makespan_cycles, r2.makespan_cycles, "deterministic");
        assert!(r1.throughput_rps > 0.0);
    }

    #[test]
    fn controller_reshards_away_from_a_bad_plan() {
        // Start from a deliberately terrible pipelined split on a hetero
        // fleet and set a hair-trigger p99 threshold: the controller must
        // fire, migrate, and end on a different plan.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), slow_gen()];
        let plan = FusionPlan::unfused(7);
        // Worst naive cut: everything but one group on the slow board.
        let bad = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &w, &plan, &[0, 1, 7]);
        let mut ccfg = burst_cfg(2, ShardMode::Pipelined);
        ccfg.requests = 160;
        ccfg.max_batch = 4;
        ccfg.reshard = Some(ReshardPolicy {
            window: 16,
            util_skew: 0.9,
            p99_ms: 0.001, // anything trips it
            cooldown_windows: 1,
            migration_factor: 1.0,
        });
        let from_label = bad.label();
        let r = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, bad, &ccfg);
        assert!(
            !r.reshard_events.is_empty(),
            "hair-trigger policy must fire at least once"
        );
        let e = &r.reshard_events[0];
        assert_eq!(e.from, from_label);
        assert_ne!(e.from, e.to);
        assert!(e.migration_bytes > 0);
        assert!(e.stall_cycles > 0 || ccfg.link_latency_cycles == 0);
        // JSON carries the events and idle-board accounting.
        let j = r.to_json();
        assert_eq!(
            j.get("reshard_events").as_arr().unwrap().len(),
            r.reshard_events.len()
        );
        assert_eq!(
            j.get("idle_boards").as_usize(),
            Some(r.idle_boards),
        );
    }

    #[test]
    fn report_json_shape() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let r = simulate_fleet(&cfg, &shard, &burst_cfg(2, ShardMode::Replicated));
        let j = r.to_json();
        assert_eq!(j.get("mode").as_str(), Some("replicated"));
        assert_eq!(j.get("boards").as_usize(), Some(2));
        assert_eq!(j.get("idle_boards").as_usize(), Some(0));
        assert_eq!(j.get("per_board").as_arr().unwrap().len(), 2);
        assert!(j.get("throughput_rps").as_f64().unwrap() > 0.0);
        assert!(j.get("reshard_events").as_arr().unwrap().is_empty());
        assert!(
            j.get("tenants").as_arr().unwrap().is_empty(),
            "single-network reports carry an empty tenants array"
        );
    }

    // ---- multi-tenant simulator ----

    use crate::cluster::shard::{place_tenants, TenantWorkload};
    use crate::config::{tiny_vgg, SloPolicy};

    /// Two small tenants that co-reside on every board: a high-priority
    /// interactive stream and a low-priority burst.
    fn two_tenant_specs(hi_rps: f64, hi_requests: usize, lo_requests: usize) -> Vec<TenantSpec> {
        vec![
            TenantSpec {
                name: "interactive".to_string(),
                network: tiny_vgg(),
                weights_seed: 1,
                arrival_rps: hi_rps,
                requests: hi_requests,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 1.0,
                    priority: 2,
                    weight: 1.0,
                    overload: None,
                },
            },
            TenantSpec {
                name: "batch".to_string(),
                network: tiny_vgg(),
                weights_seed: 2,
                arrival_rps: f64::INFINITY,
                requests: lo_requests,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 1.0,
                    priority: 0,
                    weight: 1.0,
                    overload: None,
                },
            },
        ]
    }

    fn place_two(fleet: &[AccelConfig], specs: &[TenantSpec]) -> (Vec<Weights>, Vec<ShardPlan>) {
        let weights: Vec<Weights> = specs
            .iter()
            .map(|s| Weights::random(&s.network, s.weights_seed))
            .collect();
        let fused = FusionPlan::fully_fused(7);
        let workloads: Vec<TenantWorkload> = specs
            .iter()
            .zip(&weights)
            .map(|(s, w)| TenantWorkload {
                name: &s.name,
                net: &s.network,
                weights: w,
                plan: &fused,
                mode: s.mode,
                priority: s.slo.priority,
                replicas: s.replicas,
            })
            .collect();
        let plans = place_tenants(fleet, &workloads).unwrap();
        (weights, plans)
    }

    fn mt_cfg(boards: usize, max_batch: usize) -> ClusterConfig {
        let mut c = burst_cfg(boards, ShardMode::Replicated);
        c.max_batch = max_batch;
        c.preempt_restart_cycles = 500;
        c
    }

    #[test]
    fn multi_tenant_preemption_protects_high_priority_p99() {
        // A low-priority burst floods both boards at t = 0; a moderate
        // high-priority Poisson stream must cut through via preemption: its
        // p99 stays near a single-batch service time while the burst tenant
        // absorbs the aborted batches. Item counts conserve on both sides.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let ccfg = mt_cfg(2, 8);
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);

        assert_eq!(r.tenants.len(), 2);
        let hi = &r.tenants[0];
        let lo = &r.tenants[1];
        // Conservation: nothing lost, nothing double-served.
        assert_eq!(hi.completed, 24);
        assert_eq!(lo.completed, 64);
        assert_eq!(hi.items, 24);
        assert_eq!(lo.items, 64);
        assert_eq!(r.completed, 88);
        let board_items: u64 = r.per_board.iter().map(|b| b.items).sum();
        assert_eq!(board_items, 88, "per-board items must sum to the total");

        // The burst tenant absorbs the preemptions; the interactive tenant
        // is never preempted and meets its SLO.
        assert!(lo.preemptions > 0, "burst tenant must absorb preemptions");
        assert_eq!(hi.preemptions, 0);
        assert!(hi.slo_met, "hi p99 {} > slo {}", hi.p99_ms, hi.slo_p99_ms);
        assert!(!lo.slo_met, "a flooded burst tenant cannot meet 1 ms p99");
        assert!(hi.p99_ms < lo.p99_ms / 5.0, "priority must separate the tails");
    }

    #[test]
    fn multi_tenant_report_is_deterministic_and_seed_sensitive() {
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(3000.0, 16, 32);
        let (w, plans) = place_two(&fleet, &specs);
        let ccfg = mt_cfg(2, 4);
        let a = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg)
            .to_json()
            .to_string_pretty();
        let b = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg)
            .to_json()
            .to_string_pretty();
        assert_eq!(a, b, "same seed must produce byte-identical reports");

        let mut other = ccfg.clone();
        other.seed = ccfg.seed + 1;
        let c = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &other)
            .to_json()
            .to_string_pretty();
        assert_ne!(a, c, "a different seed must sample different arrivals");
    }

    #[test]
    fn multi_tenant_merge_seeds_are_per_tenant() {
        // Tenants sample independent paths: with identical specs, tenant 0
        // and tenant 1 must not share an arrival sequence.
        let s0 = tenant_seed(7, 0);
        let s1 = tenant_seed(7, 1);
        assert_ne!(s0, s1);
        let a0 = arrivals_with_steps(64, 1000.0, &[], 120.0, s0);
        let a1 = arrivals_with_steps(64, 1000.0, &[], 120.0, s1);
        assert_ne!(a0, a1);
        // And the derivation itself is deterministic.
        assert_eq!(tenant_seed(7, 1), s1);
    }

    #[test]
    fn multi_tenant_without_contention_matches_slo_for_both_when_idle() {
        // At trickle load with no competition, both tenants meet generous
        // SLOs and nobody preempts anybody.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let mut specs = two_tenant_specs(10.0, 8, 8);
        specs[1].arrival_rps = 10.0;
        specs[1].slo.p99_ms = 50.0;
        let (w, plans) = place_two(&fleet, &specs);
        let ccfg = mt_cfg(2, 4);
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        for t in &r.tenants {
            assert_eq!(t.preemptions, 0, "{}", t.name);
            assert!(t.slo_met, "{} p99 {}", t.name, t.p99_ms);
        }
        let j = r.to_json();
        assert_eq!(j.get("tenants").as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("tenants").at(0).get("name").as_str(),
            Some("interactive")
        );
    }

    #[test]
    fn multi_tenant_pipelined_tenant_serves_and_conserves() {
        // A pipelined tenant in the multi-tenant simulator: its burst walks
        // the 2-stage chain (every batch crosses the cut exactly once), a
        // co-resident high-priority replicated tenant weaves through the
        // stage gaps, and neither side preempts — chains sit outside the
        // preemption protocol on both sides.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let tiny = tiny_vgg();
        let w_hi = Weights::random(&tiny, 1);
        let w_piped = Weights::random(&tiny, 2);
        let fused = FusionPlan::fully_fused(7);
        let unfused = FusionPlan::unfused(7);
        let specs = vec![
            TenantSpec {
                name: "hi".to_string(),
                network: tiny.clone(),
                weights_seed: 1,
                arrival_rps: 2000.0,
                requests: 24,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 5.0,
                    priority: 2,
                    weight: 1.0,
                    overload: None,
                },
            },
            TenantSpec {
                name: "piped".to_string(),
                network: tiny.clone(),
                weights_seed: 2,
                arrival_rps: f64::INFINITY,
                requests: 40,
                load_steps: vec![],
                mode: ShardMode::Pipelined,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 5000.0,
                    priority: 1,
                    weight: 1.0,
                    overload: None,
                },
            },
        ];
        let workloads = [
            TenantWorkload {
                name: "hi",
                net: &tiny,
                weights: &w_hi,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 2,
                replicas: None,
            },
            TenantWorkload {
                name: "piped",
                net: &tiny,
                weights: &w_piped,
                plan: &unfused,
                mode: ShardMode::Pipelined,
                priority: 1,
                replicas: None,
            },
        ];
        let plans = place_tenants(&fleet, &workloads).unwrap();
        assert_eq!(plans[1].mode, ShardMode::Pipelined);
        let stages = plans[1].used_boards() as u64;
        assert_eq!(stages, 2, "2 boards → 2 pipeline stages");
        let w = vec![w_hi, w_piped];

        let mut ccfg = mt_cfg(2, 4);
        ccfg.link_bytes_per_cycle = 16.0;
        ccfg.link_latency_cycles = 0;
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let hi = &r.tenants[0];
        let piped = &r.tenants[1];
        assert_eq!(hi.completed, 24);
        assert_eq!(piped.completed, 40);
        assert_eq!(hi.preemptions, 0);
        assert_eq!(piped.preemptions, 0, "chains are not preemptible");
        assert!(hi.slo_met, "hi p99 {} must hold through the chain gaps", hi.p99_ms);
        // Link conservation: every pipelined item crosses every interior
        // cut exactly once; the replicated tenant moves no link bytes.
        assert_eq!(
            r.link_bytes_total,
            plans[1].link_bytes_per_item() * 40,
            "each pipelined item crosses each cut once"
        );
        // Per-board items: replicated items counted once, pipelined items
        // once per stage they visit.
        let board_items: u64 = r.per_board.iter().map(|b| b.items).sum();
        assert_eq!(board_items, 24 + stages * 40);
        // Deterministic too.
        let a = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg)
            .to_json()
            .to_string_pretty();
        assert_eq!(r.to_json().to_string_pretty(), a);
    }

    #[test]
    fn multi_tenant_coresidency_bills_shared_ddr() {
        // Two co-resident tenants draw twice the provisioned rate: with an
        // aggregate pool worth exactly the fleet's single-tenant draw, the
        // co-resident run must report a slowdown > 1 and lower throughput.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 16, 48);
        let (w, plans) = place_two(&fleet, &specs);
        let mut free = mt_cfg(2, 4);
        free.aggregate_ddr_bytes_per_cycle = None;
        let mut tight = mt_cfg(2, 4);
        // Pool covers the two boards once — but four resident shards draw
        // twice that.
        tight.aggregate_ddr_bytes_per_cycle = Some(2.0 * cfg.platform.ddr_bytes_per_cycle);
        let r_free = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &free);
        let r_tight = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &tight);
        assert_eq!(r_free.ddr_slowdown, 1.0);
        assert_eq!(r_tight.ddr_slowdown, 2.0, "4 shards / pool of 2 boards");
        assert!(r_tight.throughput_rps < r_free.throughput_rps);
    }

    // ---- unified control plane ----

    /// Span (cycles to a tenant's last completion) recovered from the
    /// reported throughput: `throughput_rps = requests / span_s`.
    fn span_cycles(t: &TenantStats, ref_freq_mhz: f64) -> f64 {
        t.requests as f64 / t.throughput_rps * ref_freq_mhz * 1e6
    }

    #[test]
    fn drr_shares_a_class_by_weight() {
        // Two equal-priority burst tenants with work proportional to their
        // weights: deficit-weighted round-robin drains both queues in
        // proportion, so they finish together and the throughput ratio
        // tracks the weight ratio. The old strict-FIFO admission drained
        // tenant 0 completely first.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let mut specs = two_tenant_specs(f64::INFINITY, 48, 24);
        specs[0].slo.priority = 1;
        specs[1].slo.priority = 1;
        specs[0].slo.weight = 2.0;
        specs[1].slo.weight = 1.0;
        specs[0].slo.p99_ms = 1e6;
        specs[1].slo.p99_ms = 1e6;
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 4);
        ccfg.seed = 5;
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        assert_eq!(r.tenants[0].preemptions + r.tenants[1].preemptions, 0);
        let ref_freq = cfg.platform.freq_mhz;
        let (sa, sb) = (
            span_cycles(&r.tenants[0], ref_freq),
            span_cycles(&r.tenants[1], ref_freq),
        );
        let slack = 3.0 * plans[0].shards[0].ref_cycles(4, ref_freq) as f64;
        assert!(
            (sa - sb).abs() <= slack,
            "proportional work must finish together: spans {sa:.0} vs {sb:.0}"
        );
        let tp_ratio = r.tenants[0].throughput_rps / r.tenants[1].throughput_rps;
        assert!(
            (tp_ratio - 2.0).abs() < 0.4,
            "throughput ratio {tp_ratio:.2} must track the 2:1 weight ratio"
        );
    }

    #[test]
    fn drr_prevents_equal_class_starvation() {
        // Equal class, equal weights, a big burst at tenant 0 and a small
        // one at tenant 1: the old index-ordered admission starved the
        // small tenant until the big one drained; DRR finishes it early.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let mut specs = two_tenant_specs(f64::INFINITY, 96, 16);
        specs[0].slo.priority = 1;
        specs[1].slo.priority = 1;
        specs[0].slo.p99_ms = 1e6;
        specs[1].slo.p99_ms = 1e6;
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 4);
        ccfg.seed = 5;
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let ref_freq = cfg.platform.freq_mhz;
        let big = span_cycles(&r.tenants[0], ref_freq);
        let small = span_cycles(&r.tenants[1], ref_freq);
        assert!(
            small < 0.6 * big,
            "the small equal-class tenant must not starve: {small:.0} vs {big:.0}"
        );
    }

    #[test]
    fn preemption_refund_keeps_equal_peers_fair() {
        // A high-priority stream pinned to board 0 preempts whatever runs
        // there. Two equal-class bulk peers with equal weights and equal
        // work co-reside on both boards; the one that keeps getting
        // preempted must not lose its fair share — its discarded service is
        // refunded from the DRR deficit, so both peers still finish
        // together (without the refund the victim's deficit inflates with
        // zero items delivered and it drains last).
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let mut specs = two_tenant_specs(f64::INFINITY, 64, 64);
        specs[0].slo.priority = 1;
        specs[1].slo.priority = 1;
        specs[0].slo.p99_ms = 1e9;
        specs[1].slo.p99_ms = 1e9;
        specs.insert(
            0,
            TenantSpec {
                name: "hi".to_string(),
                network: tiny_vgg(),
                weights_seed: 3,
                arrival_rps: 6000.0,
                requests: 64,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: Some(1),
                slo: SloPolicy {
                    p99_ms: 1e9,
                    priority: 2,
                    weight: 1.0,
                    overload: None,
                },
            },
        );
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 4);
        ccfg.seed = 4;
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let (a, b) = (&r.tenants[1], &r.tenants[2]);
        assert!(
            a.preemptions + b.preemptions > 0,
            "the pinned stream must preempt the peers"
        );
        let ref_freq = cfg.platform.freq_mhz;
        let (sa, sb) = (span_cycles(a, ref_freq), span_cycles(b, ref_freq));
        let slack = 4.0 * plans[1].shards[0].ref_cycles(4, ref_freq) as f64;
        assert!(
            (sa - sb).abs() <= slack,
            "preempted peer lost its share: spans {sa:.0} vs {sb:.0} (slack {slack:.0})"
        );
    }

    #[test]
    fn resume_mode_bills_fewer_cycles_and_conserves() {
        // Same seed/trace, both preempt modes: work-preserving resume keeps
        // the victims' finished prefixes, so the fleet burns strictly fewer
        // busy cycles while serving every item exactly once either way.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let restart_cfg = mt_cfg(2, 8);
        let mut resume_cfg = restart_cfg.clone();
        resume_cfg.preempt_mode = PreemptMode::Resume;
        resume_cfg.preempt_refill_cycles = 100;
        let ra = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &restart_cfg);
        let rb = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &resume_cfg);
        for r in [&ra, &rb] {
            assert_eq!(r.tenants[0].completed, 24);
            assert_eq!(r.tenants[1].completed, 64);
            assert_eq!(r.tenants[0].items, 24);
            assert_eq!(r.tenants[1].items, 64);
            let board_items: u64 = r.per_board.iter().map(|b| b.items).sum();
            assert_eq!(board_items, 88);
            assert!(r.tenants[1].preemptions > 0, "flood must trigger preemption");
            assert!(r.tenants[0].slo_met);
        }
        let busy = |r: &FleetReport| r.per_board.iter().map(|b| b.busy_cycles).sum::<u64>();
        assert!(
            busy(&rb) < busy(&ra),
            "resume must bill strictly fewer cycles: {} vs {}",
            busy(&rb),
            busy(&ra)
        );
        // Both reports stay deterministic and distinct.
        assert_ne!(
            ra.to_json().to_string_pretty(),
            rb.to_json().to_string_pretty()
        );
    }

    // ---- telemetry ----

    use crate::cluster::telemetry::{
        flushed_items_per_tenant, last_flush_per_tenant, preemptions_per_tenant,
    };

    #[test]
    fn tracing_never_perturbs_the_simulation() {
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let ccfg = mt_cfg(2, 8);
        let plain = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let mut sink = TraceSink::enabled();
        let traced =
            simulate_fleet_multi_tenant_traced(&cfg, &fleet, &specs, &w, &plans, &ccfg, &mut sink);
        // Bit-identical simulation outcome with the sink armed…
        assert_eq!(plain.makespan_cycles, traced.makespan_cycles);
        assert_eq!(plain.throughput_rps.to_bits(), traced.throughput_rps.to_bits());
        assert_eq!(plain.p99_ms.to_bits(), traced.p99_ms.to_bits());
        // …and the optional `telemetry` key is the only JSON difference:
        // absent when disabled (fixtures stay byte-identical), present when
        // armed.
        assert!(plain.to_json().get("telemetry").is_null());
        assert!(!traced.to_json().get("telemetry").is_null());
        assert!(plain.telemetry.is_none());
        let summary = traced.telemetry.expect("armed sink must summarize");
        assert!(summary.events_total > 0);
        assert_eq!(summary.preemptions, plain.tenants.iter().map(|t| t.preemptions).sum::<u64>());
    }

    #[test]
    fn static_trace_flushes_conserve_items_and_sketch_matches_p99() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let ccfg = burst_cfg(2, ShardMode::Replicated);
        let mut sink = TraceSink::enabled();
        let r = simulate_fleet_traced(&cfg, &shard, &ccfg, &mut sink);
        let flushed = flushed_items_per_tenant(&sink.events, 1);
        assert_eq!(flushed[0] as usize, ccfg.requests, "every request flushes exactly once");
        let sketch_p99 = sink.sketches[0].quantile(99.0);
        let rel = (sketch_p99 - r.p99_ms).abs() / r.p99_ms;
        assert!(rel <= 0.01, "sketch p99 {sketch_p99} vs exact {} (rel {rel})", r.p99_ms);
    }

    #[test]
    fn mt_trace_recomputes_report_aggregates_exactly() {
        // The acceptance bar: per-tenant items, spans → throughput, and
        // preemption counts recomputed from the raw event trace must equal
        // the report's aggregates exactly (throughput bit-for-bit — the
        // recompute replays the same f64 operations).
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        for mode in [PreemptMode::Restart, PreemptMode::Resume] {
            let mut ccfg = mt_cfg(2, 8);
            ccfg.preempt_mode = mode;
            ccfg.preempt_refill_cycles = 100;
            let mut sink = TraceSink::enabled();
            let r = simulate_fleet_multi_tenant_traced(
                &cfg, &fleet, &specs, &w, &plans, &ccfg, &mut sink,
            );
            let nt = specs.len();
            let flushed = flushed_items_per_tenant(&sink.events, nt);
            let spans = last_flush_per_tenant(&sink.events, nt);
            let preempts = preemptions_per_tenant(&sink.events, nt);
            let ns_per_cycle = 1e3 / cfg.platform.freq_mhz;
            for (t, stats) in r.tenants.iter().enumerate() {
                assert_eq!(flushed[t], stats.items, "tenant {t} flushed items");
                assert_eq!(preempts[t], stats.preemptions, "tenant {t} preemptions");
                let span_s = spans[t] as f64 * ns_per_cycle / 1e9;
                let rps = if span_s > 0.0 {
                    stats.requests as f64 / span_s
                } else {
                    0.0
                };
                assert_eq!(
                    rps.to_bits(),
                    stats.throughput_rps.to_bits(),
                    "tenant {t} trace-recomputed throughput must be bit-exact"
                );
            }
        }
    }

    // ---- fault injection ----

    use crate::config::{FaultEvent, FaultScript};

    #[test]
    fn no_fault_script_keeps_report_json_free_of_fault_keys() {
        // Faults are strictly opt-in: without a script the report must not
        // grow any key — the committed golden fixtures rely on this.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &mt_cfg(2, 8));
        assert!(r.faults.is_none());
        let s = r.to_json().to_string_compact();
        assert!(!s.contains("\"faults\""), "no faults key without a script");
        assert!(
            !s.contains("slo_attainment_outage"),
            "no per-tenant outage key without a script"
        );
        // The overload-shedding and brownout fields are equally opt-in:
        // with no `OverloadPolicy` and no `ComputeDegrade` the report JSON
        // must not grow a single new key.
        for key in [
            "\"shed\"",
            "\"retried\"",
            "\"abandoned\"",
            "\"goodput_rps\"",
            "\"compute_degrades\"",
            "\"recovery_time_ms\"",
            "\"shed_total\"",
            "\"retried_total\"",
            "\"abandoned_total\"",
        ] {
            assert!(!s.contains(key), "no-policy run must not grow {key}");
        }
    }

    #[test]
    fn board_down_requeues_in_flight_work_and_recovers() {
        // Board 1 dies mid-burst and recovers later: the aborted batch
        // re-queues (Restart mode re-runs it whole), the survivors keep
        // serving, and every request still completes exactly once. The
        // trace's BoardFail events must agree with the FaultSummary.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 8);
        ccfg.tenants = specs.clone();
        ccfg.faults = Some(FaultScript {
            events: vec![FaultEvent::BoardDown {
                board: 1,
                at_ms: 0.2,
                recover_ms: Some(1.0),
            }],
        });
        let mut sink = TraceSink::enabled();
        let r =
            simulate_fleet_multi_tenant_traced(&cfg, &fleet, &specs, &w, &plans, &ccfg, &mut sink);
        assert_eq!(r.tenants[0].completed, 24);
        assert_eq!(r.tenants[1].completed, 64);
        assert_eq!(r.tenants[0].items, 24);
        assert_eq!(r.tenants[1].items, 64);
        let f = r.faults.as_ref().expect("script armed → summary present");
        assert_eq!(f.board_failures, 1);
        assert_eq!(f.board_recoveries, 1);
        // Downtime is exactly the scripted window (0.2 ms → 1.0 ms).
        let ref_freq = cfg.platform.freq_mhz;
        let expect_down = (1.0 * ref_freq * 1e3).round() as u64 - (0.2 * ref_freq * 1e3).round() as u64;
        assert_eq!(f.downtime_cycles, expect_down);
        // Trace ↔ summary consistency.
        let requeued_in_trace: u64 = sink
            .events
            .iter()
            .map(|ev| match ev {
                TraceEvent::BoardFail { requeued, .. } => *requeued as u64,
                _ => 0,
            })
            .sum();
        assert_eq!(f.items_requeued, requeued_in_trace);
        let fails = sink.events.iter().filter(|e| e.kind() == "board_fail").count();
        let recs = sink.events.iter().filter(|e| e.kind() == "board_recover").count();
        assert_eq!(fails, 1);
        assert_eq!(recs, 1);
        // Every tenant reports the outage-attainment metric under faults.
        for t in &r.tenants {
            assert!(t.slo_attainment_outage.is_some(), "{}", t.name);
        }
        // Deterministic, faults and all.
        let mut sink2 = TraceSink::enabled();
        let r2 =
            simulate_fleet_multi_tenant_traced(&cfg, &fleet, &specs, &w, &plans, &ccfg, &mut sink2);
        assert_eq!(r.to_json().to_string_pretty(), r2.to_json().to_string_pretty());
    }

    #[test]
    fn permanent_board_loss_drains_to_the_survivor() {
        // No recovery: the fleet finishes the run on board 0 alone and the
        // downtime bills to the end of the run.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 8);
        ccfg.tenants = specs.clone();
        ccfg.faults = Some(FaultScript {
            events: vec![FaultEvent::BoardDown {
                board: 1,
                at_ms: 0.2,
                recover_ms: None,
            }],
        });
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        assert_eq!(r.completed, 88, "survivor absorbs everything");
        let f = r.faults.as_ref().unwrap();
        assert_eq!(f.board_failures, 1);
        assert_eq!(f.board_recoveries, 0);
        let fail_at = (0.2 * cfg.platform.freq_mhz * 1e3).round() as u64;
        assert_eq!(f.downtime_cycles, r.makespan_cycles - fail_at);
        // Board 1 serves nothing after the failure: its items stay below
        // the even split.
        assert!(r.per_board[1].items < r.per_board[0].items);
    }

    #[test]
    fn clock_derate_stretches_the_run_until_restored() {
        // Both boards at half clock from t = 0: the burst takes roughly
        // twice as long as the healthy run. A restoring factor-1.0 event
        // counts as a derate too (the summary tallies applications).
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        // Both tenants burst at t = 0 so the makespan is service-bound —
        // a Poisson stream would hide the derate behind arrival gaps.
        let specs = two_tenant_specs(f64::INFINITY, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let healthy = mt_cfg(2, 8);
        let mut derated = mt_cfg(2, 8);
        derated.tenants = specs.clone();
        derated.faults = Some(FaultScript {
            events: vec![
                FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: 0.0 },
                FaultEvent::ClockDerate { board: 1, factor: 0.5, at_ms: 0.0 },
                FaultEvent::ClockDerate { board: 0, factor: 1.0, at_ms: 50.0 },
                FaultEvent::ClockDerate { board: 1, factor: 1.0, at_ms: 50.0 },
            ],
        });
        let rh = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &healthy);
        let rd = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &derated);
        assert_eq!(rd.completed, rh.completed);
        assert!(
            rd.makespan_cycles as f64 > 1.5 * rh.makespan_cycles as f64,
            "half clock must stretch the run: {} vs {}",
            rd.makespan_cycles,
            rh.makespan_cycles
        );
        assert_eq!(rd.faults.as_ref().unwrap().clock_derates, 4);
        assert!(rh.faults.is_none());
    }

    #[test]
    fn link_flaps_within_one_window_slow_a_pipelined_chain() {
        // Back-to-back degrade windows (a flap) on the stage-0 egress link
        // of a pipelined tenant: transfers overlapping the windows bill at
        // the degraded rate, so the faulted run is strictly slower than the
        // healthy one on a link-bound chain — and byte-deterministic.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let tiny = tiny_vgg();
        let w_piped = Weights::random(&tiny, 2);
        let unfused = FusionPlan::unfused(7);
        let specs = vec![TenantSpec {
            name: "piped".to_string(),
            network: tiny.clone(),
            weights_seed: 2,
            arrival_rps: f64::INFINITY,
            requests: 40,
            load_steps: vec![],
            mode: ShardMode::Pipelined,
            replicas: None,
            slo: SloPolicy { p99_ms: 5000.0, priority: 1, weight: 1.0, overload: None },
        }];
        let workloads = [TenantWorkload {
            name: "piped",
            net: &tiny,
            weights: &w_piped,
            plan: &unfused,
            mode: ShardMode::Pipelined,
            priority: 1,
            replicas: None,
        }];
        let plans = place_tenants(&fleet, &workloads).unwrap();
        assert_eq!(plans[0].used_boards(), 2);
        let src = plans[0].shards[0].board;
        let w = vec![w_piped];
        let mut healthy = mt_cfg(2, 4);
        healthy.link_bytes_per_cycle = 1.0; // starved wire → link-bound
        healthy.link_latency_cycles = 0;
        let mut flapped = healthy.clone();
        flapped.tenants = specs.clone();
        flapped.faults = Some(FaultScript {
            events: vec![
                FaultEvent::LinkDegrade { link: src, factor: 0.5, at_ms: 0.0, until_ms: 5.0 },
                FaultEvent::LinkDegrade { link: src, factor: 0.25, at_ms: 5.0, until_ms: 50.0 },
            ],
        });
        let rh = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &healthy);
        let rf = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &flapped);
        assert_eq!(rf.completed, 40);
        assert_eq!(rf.link_bytes_total, rh.link_bytes_total, "bytes conserve");
        assert!(
            rf.makespan_cycles > rh.makespan_cycles,
            "degraded link must slow a link-bound chain: {} vs {}",
            rf.makespan_cycles,
            rh.makespan_cycles
        );
        assert_eq!(rf.faults.as_ref().unwrap().link_degrades, 2);
        let a = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &flapped)
            .to_json()
            .to_string_pretty();
        assert_eq!(rf.to_json().to_string_pretty(), a, "faulted runs stay deterministic");
    }

    // ---- single-network fault semantics (satellite: FaultScript on the
    // static/dynamic simulators) ----

    #[test]
    fn static_sim_board_down_blocks_new_batches_until_recovery() {
        // Board 0 is dark from t = 0 until past the healthy makespan: its
        // round-robin share only starts after recovery, so the faulted run
        // ends strictly later and at least at the recovery instant. The
        // single-network semantics never abort in-flight work, so nothing
        // requeues.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let healthy = burst_cfg(2, ShardMode::Replicated);
        let rh = simulate_fleet(&cfg, &shard, &healthy);
        assert!(rh.faults.is_none(), "no script → no summary");
        let ref_freq = cfg.platform.freq_mhz;
        let recover_ms = rh.makespan_cycles as f64 / (ref_freq * 1e3) * 1.5;
        let mut faulted = healthy.clone();
        faulted.faults = Some(FaultScript {
            events: vec![FaultEvent::BoardDown {
                board: 0,
                at_ms: 0.0,
                recover_ms: Some(recover_ms),
            }],
        });
        let rf = simulate_fleet(&cfg, &shard, &faulted);
        assert_eq!(rf.completed, 96, "every request still completes");
        let rec = (recover_ms * ref_freq * 1e3).round() as u64;
        assert!(rf.makespan_cycles > rh.makespan_cycles);
        assert!(
            rf.makespan_cycles >= rec,
            "board 0's share cannot finish before the board returns"
        );
        let f = rf.faults.as_ref().expect("script armed → summary present");
        assert_eq!(f.board_failures, 1);
        assert_eq!(f.board_recoveries, 1);
        assert_eq!(f.downtime_cycles, rec);
        assert_eq!(f.items_requeued, 0, "single-network outages never abort in-flight work");
        assert_eq!(f.emergency_reshards, 0);
        // Deterministic under faults.
        let rf2 = simulate_fleet(&cfg, &shard, &faulted);
        assert_eq!(rf.to_json().to_string_pretty(), rf2.to_json().to_string_pretty());
    }

    #[test]
    fn dynamic_sim_clock_derate_stretches_the_run() {
        // Both boards at half clock from t = 0 under the dynamic greedy
        // dispatcher: every batch bills at 2x, so the makespan roughly
        // doubles and the summary tallies both derate applications.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated_fleet(&fleet, &net, &w, &plan);
        let mut healthy = burst_cfg(2, ShardMode::Replicated);
        healthy.requests = 64;
        healthy.max_batch = 4;
        let mut derated = healthy.clone();
        derated.faults = Some(FaultScript {
            events: vec![
                FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: 0.0 },
                FaultEvent::ClockDerate { board: 1, factor: 0.5, at_ms: 0.0 },
            ],
        });
        let rh = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard.clone(), &healthy);
        let rd = simulate_fleet_dynamic(&cfg, &fleet, &net, &w, shard, &derated);
        assert_eq!(rd.completed, 64);
        assert!(
            rd.makespan_cycles as f64 > 1.5 * rh.makespan_cycles as f64,
            "half clock must stretch the dynamic run: {} vs {}",
            rd.makespan_cycles,
            rh.makespan_cycles
        );
        let f = rd.faults.as_ref().unwrap();
        assert_eq!(f.clock_derates, 2);
        assert_eq!(f.board_failures, 0);
        assert_eq!(f.compute_degrades, 0);
        assert!(f.recovery_time_ms.is_none(), "no controller window → no RTO here");
        assert!(rh.faults.is_none());
    }

    #[test]
    #[should_panic(expected = "board_down needs recover_ms")]
    fn static_sim_rejects_permanent_board_loss() {
        // The batcher-driven loops cannot re-route a board's round-robin
        // share; a permanent outage would strand it forever.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut ccfg = burst_cfg(2, ShardMode::Replicated);
        ccfg.faults = Some(FaultScript {
            events: vec![FaultEvent::BoardDown { board: 0, at_ms: 0.1, recover_ms: None }],
        });
        let _ = simulate_fleet(&cfg, &shard, &ccfg);
    }

    #[test]
    #[should_panic(expected = "board_down and clock_derate only")]
    fn static_sim_rejects_unsupported_fault_kinds() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::fully_fused(7);
        let shard = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let mut ccfg = burst_cfg(2, ShardMode::Replicated);
        ccfg.faults = Some(FaultScript {
            events: vec![FaultEvent::ComputeDegrade {
                board: 0,
                capacity_fraction: 0.5,
                at_ms: 0.1,
                recover_ms: Some(1.0),
            }],
        });
        let _ = simulate_fleet(&cfg, &shard, &ccfg);
    }

    // ---- clock-derate stacking edges (satellite: overlap, same-instant
    // restore, mid-batch onset) ----

    #[test]
    fn overlapping_derates_last_one_wins() {
        // Two derates overlap on the only board: 0.5 from t = 0, then 0.25
        // landing mid-run. Steps REPLACE the factor (they do not multiply):
        // the stacked run is slower than pure-0.5 (its tail runs at 4x) but
        // faster than pure-0.25 (its head ran at only 2x). A multiplicative
        // bug (0.5 * 0.25 = 0.125 tail) would push it past the pure-0.25
        // run.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone()];
        let specs = two_tenant_specs(f64::INFINITY, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let healthy = mt_cfg(1, 8);
        let rh = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &healthy);
        let script = |events| {
            let mut c = mt_cfg(1, 8);
            c.tenants = specs.clone();
            c.faults = Some(FaultScript { events });
            c
        };
        let half = script(vec![FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: 0.0 }]);
        let rs = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &half);
        let mid_ms = rs.makespan_cycles as f64 / (cfg.platform.freq_mhz * 1e3) * 0.5;
        let stacked = script(vec![
            FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: 0.0 },
            FaultEvent::ClockDerate { board: 0, factor: 0.25, at_ms: mid_ms },
        ]);
        let rk = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &stacked);
        let quarter =
            script(vec![FaultEvent::ClockDerate { board: 0, factor: 0.25, at_ms: 0.0 }]);
        let rq = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &quarter);
        assert_eq!(rk.completed, 88);
        assert!(rh.makespan_cycles < rs.makespan_cycles);
        assert!(
            rs.makespan_cycles < rk.makespan_cycles,
            "deepening the derate mid-run must slow the tail: {} vs {}",
            rs.makespan_cycles,
            rk.makespan_cycles
        );
        assert!(
            rk.makespan_cycles < rq.makespan_cycles,
            "overlapping derates replace, not multiply: stacked {} vs pure-quarter {}",
            rk.makespan_cycles,
            rq.makespan_cycles
        );
        assert_eq!(rs.faults.as_ref().unwrap().clock_derates, 1);
        assert_eq!(rk.faults.as_ref().unwrap().clock_derates, 2);
        assert_eq!(rq.faults.as_ref().unwrap().clock_derates, 1);
    }

    #[test]
    fn restore_racing_a_same_instant_dispatch_is_clean() {
        // A factor-1.0 restore scheduled at the very same instant as the
        // derate it undoes: the engine folds every event at an instant in
        // before pricing any dispatch, so the board never serves a cycle at
        // the derated clock and the run matches the healthy one exactly —
        // while the summary still tallies both applications.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(f64::INFINITY, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let healthy = mt_cfg(2, 8);
        let mut raced = mt_cfg(2, 8);
        raced.tenants = specs.clone();
        raced.faults = Some(FaultScript {
            events: vec![
                FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: 0.1 },
                FaultEvent::ClockDerate { board: 0, factor: 1.0, at_ms: 0.1 },
            ],
        });
        let rh = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &healthy);
        let rr = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &raced);
        assert_eq!(rr.completed, rh.completed);
        assert_eq!(
            rr.makespan_cycles, rh.makespan_cycles,
            "a same-instant derate/restore pair must not perturb the run"
        );
        assert_eq!(rr.p99_ms.to_bits(), rh.p99_ms.to_bits());
        assert_eq!(rr.faults.as_ref().unwrap().clock_derates, 2);
    }

    #[test]
    fn derate_landing_mid_batch_spares_inflight_work() {
        // One board, 8 burst requests, max_batch 4 → exactly two batches.
        // A half-clock derate landing halfway through the first batch must
        // not re-price it (in-flight work keeps its dispatch-time cost):
        // the run takes ~1 healthy batch + 1 derated batch = ~3 batch
        // services, strictly between the healthy 2 and the derate-from-
        // dispatch 4.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone()];
        // One full batch alone measures the healthy batch service D.
        let probe = vec![TenantSpec {
            name: "solo".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: f64::INFINITY,
            requests: 4,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy { p99_ms: 1e9, priority: 1, weight: 1.0, overload: None },
        }];
        let (wp, pp) = place_two(&fleet, &probe);
        let d = simulate_fleet_multi_tenant(&cfg, &fleet, &probe, &wp, &pp, &mt_cfg(1, 4))
            .makespan_cycles;
        let mut specs = probe.clone();
        specs[0].requests = 8;
        let (w, plans) = place_two(&fleet, &specs);
        let healthy = mt_cfg(1, 4);
        let rh = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &healthy);
        let mid_ms = d as f64 * 0.5 / (cfg.platform.freq_mhz * 1e3);
        let mut derated = mt_cfg(1, 4);
        derated.tenants = specs.clone();
        derated.faults = Some(FaultScript {
            events: vec![FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: mid_ms }],
        });
        let rd = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &derated);
        assert_eq!(rd.completed, 8);
        assert!(rd.makespan_cycles > rh.makespan_cycles);
        let (lo, hi) = ((2.6 * d as f64) as u64, (3.4 * d as f64) as u64);
        assert!(
            rd.makespan_cycles > lo && rd.makespan_cycles < hi,
            "mid-batch derate must spare the in-flight batch (~3 services, D = {d}): got {}",
            rd.makespan_cycles
        );
    }

    // ---- overload shedding & partial-capacity faults ----

    use crate::config::{OverloadPolicy, RetryPolicy};

    #[test]
    fn overload_shedding_conserves_requests_and_spares_the_quiet_tenant() {
        // A best-effort flooder bursts 200 requests into a 4-deep admission
        // queue while a policy-less interactive tenant streams alongside.
        // The flooder sheds and retries; the quiet tenant is never touched
        // by the overload machinery. Offered == completed + abandoned on
        // both sides, the fleet rollups match the per-tenant sums, and the
        // trace carries exactly the counted events.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let mut specs = two_tenant_specs(2000.0, 24, 200);
        specs[1].slo.overload = Some(OverloadPolicy {
            deadline_ms: 50.0,
            max_queue: 4,
            retry: RetryPolicy { max_attempts: 3, backoff_base_ms: 0.05, jitter: 0.5 },
        });
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 4);
        ccfg.tenants = specs.clone();
        let mut sink = TraceSink::enabled();
        let r =
            simulate_fleet_multi_tenant_traced(&cfg, &fleet, &specs, &w, &plans, &ccfg, &mut sink);
        let (hi, lo) = (&r.tenants[0], &r.tenants[1]);
        // The policy-less tenant never sheds, retries, or abandons.
        assert_eq!(hi.completed, 24);
        assert_eq!(hi.shed, Some(0));
        assert_eq!(hi.retried, Some(0));
        assert_eq!(hi.abandoned, Some(0));
        // The flooder sheds (burst ≫ max_queue) and its clients retry.
        assert!(lo.shed.unwrap() > 0, "a 200-burst into a 4-deep queue must shed");
        assert!(lo.retried.unwrap() > 0, "shed requests must come back");
        assert_eq!(
            lo.completed as u64 + lo.abandoned.unwrap(),
            200,
            "offered == completed + abandoned"
        );
        // Fleet rollups are the per-tenant sums; goodput counts completions
        // only and can never exceed the offered-based throughput.
        assert_eq!(r.shed_total.unwrap(), hi.shed.unwrap() + lo.shed.unwrap());
        assert_eq!(r.retried_total.unwrap(), lo.retried.unwrap());
        assert_eq!(r.abandoned_total.unwrap(), lo.abandoned.unwrap());
        assert_eq!(r.completed as u64, 24 + lo.completed as u64);
        assert!(r.goodput_rps.unwrap() > 0.0);
        assert!(lo.goodput_rps.unwrap() <= lo.throughput_rps);
        // Trace ↔ counter consistency.
        let count = |k: &str| sink.events.iter().filter(|e| e.kind() == k).count() as u64;
        assert_eq!(count("shed"), r.shed_total.unwrap());
        assert_eq!(count("retry"), r.retried_total.unwrap());
        assert_eq!(count("abandon"), r.abandoned_total.unwrap());
        // Deterministic, retry jitter and all — two plain runs agree to the
        // byte, and the armed sink never perturbs the shed outcome.
        let r2 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let r3 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        assert_eq!(r2.to_json().to_string_pretty(), r3.to_json().to_string_pretty());
        assert_eq!(r2.tenants[1].shed, lo.shed);
        assert_eq!(r2.tenants[1].retried, lo.retried);
        assert_eq!(r2.tenants[1].abandoned, lo.abandoned);
        assert_eq!(r2.makespan_cycles, r.makespan_cycles);
    }

    #[test]
    fn zero_retry_budget_abandons_on_first_shed() {
        // max_attempts = 0: every shed abandons on the spot. With a 64-req
        // burst into a 2-deep queue the math is exact — 2 admitted, 62
        // shed-and-abandoned, no retries ever scheduled.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = vec![TenantSpec {
            name: "impatient".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: f64::INFINITY,
            requests: 64,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 1e9,
                priority: 1,
                weight: 1.0,
                overload: Some(OverloadPolicy {
                    deadline_ms: 50.0,
                    max_queue: 2,
                    retry: RetryPolicy { max_attempts: 0, backoff_base_ms: 1.0, jitter: 0.0 },
                }),
            },
        }];
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 8);
        ccfg.tenants = specs.clone();
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        let t = &r.tenants[0];
        assert_eq!(t.completed, 2, "only the queue's worth gets served");
        assert_eq!(t.shed, Some(62));
        assert_eq!(t.abandoned, Some(62), "no retry budget → every shed abandons");
        assert_eq!(t.retried, Some(0));
        assert_eq!(r.completed, 2);
        assert_eq!(r.abandoned_total, Some(62));
        // Latency population is completions-only: a p99 over 2 served
        // requests is near one batch service, not poisoned by zeros from
        // the 62 that never ran.
        assert!(t.p99_ms > 0.0);
    }

    #[test]
    fn compute_degrade_prices_through_the_cost_model_and_recovers() {
        // A brownout (25% capacity) on board 0: service stretches while it
        // holds, so a permanent degrade is slower than one that recovers
        // mid-run, and both are slower than healthy. The summary counts the
        // degrade and the trace carries the event.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(f64::INFINITY, 24, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let healthy = mt_cfg(2, 8);
        let rh = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &healthy);
        let script = |recover_ms| {
            let mut c = mt_cfg(2, 8);
            c.tenants = specs.clone();
            c.faults = Some(FaultScript {
                events: vec![FaultEvent::ComputeDegrade {
                    board: 0,
                    capacity_fraction: 0.25,
                    at_ms: 0.0,
                    recover_ms,
                }],
            });
            c
        };
        let perm = script(None);
        let rec_ms = rh.makespan_cycles as f64 / (cfg.platform.freq_mhz * 1e3) * 0.5;
        let rec = script(Some(rec_ms));
        let rp = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &perm);
        let mut sink = TraceSink::enabled();
        let rr =
            simulate_fleet_multi_tenant_traced(&cfg, &fleet, &specs, &w, &plans, &rec, &mut sink);
        assert_eq!(rp.completed, 88, "a brownout sheds capacity, not requests");
        assert_eq!(rr.completed, 88);
        assert!(
            rp.makespan_cycles > rh.makespan_cycles,
            "quarter capacity must stretch the run: {} vs {}",
            rp.makespan_cycles,
            rh.makespan_cycles
        );
        assert!(
            rr.makespan_cycles < rp.makespan_cycles,
            "recovering mid-run must beat a permanent brownout: {} vs {}",
            rr.makespan_cycles,
            rp.makespan_cycles
        );
        assert_eq!(rp.faults.as_ref().unwrap().compute_degrades, 1);
        assert_eq!(rr.faults.as_ref().unwrap().compute_degrades, 1);
        assert_eq!(rp.faults.as_ref().unwrap().board_failures, 0);
        let degr = sink.events.iter().filter(|e| e.kind() == "compute_degrade").count();
        assert_eq!(degr, 1);
        // Deterministic under brownouts: two plain runs agree to the byte
        // (the traced run differs by exactly the `telemetry` key).
        let rr2 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &rec);
        let rr3 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &rec);
        assert_eq!(rr2.to_json().to_string_pretty(), rr3.to_json().to_string_pretty());
        assert_eq!(rr2.makespan_cycles, rr.makespan_cycles);
    }

    #[test]
    fn recovery_time_objective_stamped_after_a_mid_run_fault() {
        // Controller armed + scripted derate window: once the fault clears,
        // the first controller window whose fleet-wide p99 falls back
        // within 1.25x the pre-fault baseline stamps the recovery instant,
        // and the summary reports it as milliseconds since fault onset.
        let cfg = AccelConfig::paper_default();
        let fleet = vec![cfg.clone(), cfg.clone()];
        let specs = two_tenant_specs(2000.0, 400, 64);
        let (w, plans) = place_two(&fleet, &specs);
        let mut ccfg = mt_cfg(2, 8);
        ccfg.tenants = specs.clone();
        ccfg.reshard = Some(ReshardPolicy {
            window: 16,
            util_skew: 0.9,
            p99_ms: 50.0,
            cooldown_windows: 1,
            migration_factor: 0.0,
        });
        ccfg.faults = Some(FaultScript {
            events: vec![
                FaultEvent::ClockDerate { board: 0, factor: 0.5, at_ms: 5.0 },
                FaultEvent::ClockDerate { board: 0, factor: 1.0, at_ms: 10.0 },
            ],
        });
        let r = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        assert_eq!(r.completed, 464);
        let f = r.faults.as_ref().unwrap();
        assert_eq!(f.clock_derates, 2);
        let rto = f
            .recovery_time_ms
            .expect("windows keep rolling long after the fault → recovery must be stamped");
        assert!(rto > 0.0, "recovery cannot predate the fault");
        let makespan_ms = r.makespan_cycles as f64 / (cfg.platform.freq_mhz * 1e3);
        assert!(rto <= makespan_ms, "RTO {rto} must fit inside the run {makespan_ms}");
        // Bit-deterministic, RTO included.
        let r2 = simulate_fleet_multi_tenant(&cfg, &fleet, &specs, &w, &plans, &ccfg);
        assert_eq!(
            r2.faults.as_ref().unwrap().recovery_time_ms.unwrap().to_bits(),
            rto.to_bits()
        );
    }

    #[test]
    fn carry_link_state_preserves_surviving_pairs_only() {
        // Old chain 0→1→2→3 with traffic on every boundary; the re-plan
        // keeps the 0→1 cut but rewires the tail to 1→3→2. The physical
        // wire between boards 0 and 1 must keep its odometer and its
        // in-flight occupancy; the new pairs start fresh.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(net.layers.len());
        let old_plan = ShardPlan::pipelined(&cfg, &net, &w, &plan, 4);
        let mut old_links: Vec<LinkChannel> = (0..3)
            .map(|_| LinkChannel::new(InterBoardLink::new(16.0, 10)))
            .collect();
        let ends: Vec<u64> = old_links
            .iter_mut()
            .enumerate()
            .map(|(i, ch)| ch.transfer(160 * (i as u64 + 1), 0))
            .collect();

        let mut new_plan = old_plan.clone();
        new_plan.shards[2].board = 3;
        new_plan.shards[3].board = 2;
        let mut new_links: Vec<LinkChannel> = (0..3)
            .map(|_| LinkChannel::new(InterBoardLink::new(16.0, 10)))
            .collect();
        carry_link_state(&old_plan, &old_links, &new_plan, &mut new_links);

        // Pair (0, 1) survived: bytes + occupancy carried.
        assert_eq!(new_links[0].bytes_moved, 160);
        assert_eq!(new_links[0].busy_until(), ends[0]);
        // Pairs (1, 3) and (3, 2) are new wires: fresh state.
        for ch in &new_links[1..] {
            assert_eq!(ch.bytes_moved, 0);
            assert_eq!(ch.busy_until(), 0);
        }

        // Re-planning back to the original boards restores every pair —
        // byte conservation across a round trip.
        let mut back: Vec<LinkChannel> = (0..3)
            .map(|_| LinkChannel::new(InterBoardLink::new(16.0, 10)))
            .collect();
        carry_link_state(&old_plan, &old_links, &old_plan, &mut back);
        let total: u64 = back.iter().map(|c| c.bytes_moved).sum();
        assert_eq!(total, 160 + 320 + 480);
        for (ch, &e) in back.iter().zip(&ends) {
            assert_eq!(ch.busy_until(), e);
        }
    }

    #[test]
    fn fabric_sim_reports_segments_and_no_residue_without_one() {
        // Same static pipelined scene with and without a fabric whose one
        // rack holds the whole chain: traffic totals agree (the topology
        // adds a section, not different physics on the intra wire), the
        // armed report carries the per-segment section, and the flat
        // report has no trace of it.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(net.layers.len());
        let shard = ShardPlan::pipelined(&cfg, &net, &w, &plan, 2);
        let mut ccfg = burst_cfg(2, ShardMode::Pipelined);
        ccfg.link_bytes_per_cycle = 16.0;
        ccfg.link_latency_cycles = 100;
        ccfg.requests = 24;
        let flat = simulate_fleet(&cfg, &shard, &ccfg);
        assert!(flat.fabric.is_none());
        let s = flat.to_json().to_string_compact();
        assert!(!s.contains("\"fabric\""), "no residue without a fabric");

        ccfg.fabric = Some(crate::config::FabricSpec {
            intra_bytes_per_cycle: 16.0,
            intra_latency_cycles: 100,
            ..crate::config::FabricSpec::leaf_spine(2)
        });
        let armed = simulate_fleet(&cfg, &shard, &ccfg);
        let fs = armed.fabric.as_ref().expect("fabric section");
        assert_eq!(fs.racks, 1);
        // Single rack → the chain's boundary bytes all ride the backplane;
        // the rack's (idle) spine uplink is still reported.
        assert_eq!(fs.segments.len(), 2);
        assert_eq!(fs.segments[0].bytes_moved, armed.link_bytes_total);
        assert_eq!(fs.segments[1].kind, "uplink");
        assert_eq!(fs.segments[1].bytes_moved, 0);
        assert_eq!(armed.link_bytes_total, flat.link_bytes_total);
        assert_eq!(armed.completed, flat.completed);
        let sj = armed.to_json().to_string_compact();
        assert!(sj.contains("\"fabric\"") && sj.contains("\"segments\""));
    }
}
