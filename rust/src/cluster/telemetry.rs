//! Fleet telemetry: deterministic event tracing, windowed time-series, and
//! online quantile sketches for the cluster simulators.
//!
//! The per-layer `accel/trace.rs` timeline proved the idiom at the
//! single-accelerator level (the Fig 5 staircase); this module lifts it to
//! the whole fleet and control plane. Three pieces:
//!
//! * [`TraceSink`] — a zero-cost-when-disabled event recorder threaded
//!   through all three simulators. Every record method takes a closure so a
//!   disabled sink never even constructs the event; `TraceSink::disabled()`
//!   is the default for every existing entry point, which is what keeps the
//!   committed `FleetReport` fixtures byte-identical.
//! * [`TraceEvent`] — the typed, byte-deterministic event vocabulary:
//!   admission (with the DRR deficit at decision time), per-board batch
//!   dispatch and flush, preemption (mode, victim, refunded deficit),
//!   reshard trigger/stall/wake with per-tenant migration billing, and
//!   window rollups. [`WindowSample`] carries the windowed time-series
//!   (per-board busy fraction, per-tenant queue depth and window p99)
//!   sampled at the existing reshard-window boundaries.
//! * [`QuantileSketch`] — a fixed-bin log-scale histogram (mergeable,
//!   ≤ 0.5 % relative error by construction, validated against
//!   `percentile_sorted` to ≤ 1 %) so per-tenant tail latency stays
//!   computable for 1e6-request traces without retaining every sample.
//!
//! Aggregates recomputed from the trace (`flushed_items_per_tenant`,
//! `last_flush_per_tenant`, `preemptions_per_tenant`) are asserted equal to
//! `FleetReport`'s in `tests/integration_telemetry.rs`.

use crate::util::json::Json;
use crate::util::math::ln_det;

/// Number of log-scale bins in a [`QuantileSketch`]. With `SKETCH_EPS`
/// = 0.005 the bins cover `[1e-9, ~6e8]` ms — far beyond any simulated
/// latency — before overflow clamping kicks in.
pub const SKETCH_BINS: usize = 4096;
/// Lower edge of bin 0 (ms). Everything at or below lands in the underflow
/// bin; a one-cycle latency at 120 MHz is ~8.3e-6 ms, so nothing real does.
pub const SKETCH_MIN: f64 = 1e-9;
/// Per-sample relative-error budget. γ = (1+ε)/(1−ε) makes the midpoint
/// estimate `2lγ/(γ+1)` of bin `(l, lγ]` exact to ±ε.
pub const SKETCH_EPS: f64 = 0.005;

fn sketch_gamma() -> f64 {
    (1.0 + SKETCH_EPS) / (1.0 - SKETCH_EPS)
}

/// Online log-scale histogram with deterministic binning (`ln_det`, not
/// platform libm) and linear-interpolated quantiles that mimic
/// `percentile_sorted`'s rank convention, so the two agree to within the
/// per-sample error budget on any sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    total: u64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    pub fn new() -> QuantileSketch {
        QuantileSketch {
            counts: vec![0; SKETCH_BINS],
            underflow: 0,
            overflow: 0,
            total: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Reset to the empty state, keeping the bin allocation. Window-scoped
    /// consumers (the controller's per-window recovery check) reuse one
    /// sketch across thousands of windows instead of reallocating 4096
    /// bins each time.
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.underflow = 0;
        self.overflow = 0;
        self.total = 0;
        self.min = f64::INFINITY;
        self.max = f64::NEG_INFINITY;
        self.sum = 0.0;
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Record one observation (ms). Non-finite values are a caller bug.
    pub fn record(&mut self, v: f64) {
        assert!(v.is_finite(), "QuantileSketch::record({v})");
        self.total += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        if v <= SKETCH_MIN {
            self.underflow += 1;
            return;
        }
        let i = (ln_det(v / SKETCH_MIN) / ln_det(sketch_gamma())).floor() as i64;
        if i < 0 {
            self.underflow += 1;
        } else if i as usize >= SKETCH_BINS {
            self.overflow += 1;
        } else {
            self.counts[i as usize] += 1;
        }
    }

    /// Merge another sketch into this one (bin-exact: counts add).
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.total += other.total;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Estimated value of the sample at ascending rank `k` (0-indexed).
    /// Bin `(l, lγ]` is estimated at `2lγ/(γ+1)`, clamped to the observed
    /// `[min, max]` so the extremes are exact.
    fn value_at_rank(&self, k: u64) -> f64 {
        debug_assert!(k < self.total);
        let clamp = |v: f64| v.max(self.min).min(self.max);
        if k < self.underflow {
            return clamp(SKETCH_MIN);
        }
        let g = sketch_gamma();
        let mut cum = self.underflow;
        let mut l = SKETCH_MIN;
        for &c in &self.counts {
            if k < cum + c {
                return clamp(2.0 * l * g / (g + 1.0));
            }
            cum += c;
            l *= g;
        }
        clamp(self.max) // overflow tail
    }

    /// Linear-interpolated quantile, same rank convention as
    /// `percentile_sorted`: rank = pct/100·(n−1), interpolate floor/ceil.
    pub fn quantile(&self, pct: f64) -> f64 {
        assert!(self.total > 0, "QuantileSketch::quantile on empty sketch");
        assert!((0.0..=100.0).contains(&pct));
        // The extremes are tracked exactly — match `percentile_sorted`
        // bit-for-bit there instead of estimating.
        if self.total == 1 || pct == 0.0 {
            return self.min;
        }
        if pct == 100.0 {
            return self.max;
        }
        let rank = pct / 100.0 * (self.total - 1) as f64;
        let lo = rank.floor() as u64;
        let hi = rank.ceil() as u64;
        let frac = rank - lo as f64;
        let vlo = self.value_at_rank(lo);
        let vhi = self.value_at_rank(hi);
        vlo + (vhi - vlo) * frac
    }

    /// Compact JSON: only non-empty bins, plus exact min/max/sum/total and
    /// the headline estimated percentiles.
    pub fn to_json(&self) -> Json {
        let mut bins = Json::Arr(vec![]);
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                bins = bins.push(Json::Arr(vec![Json::from(i as u64), Json::from(c)]));
            }
        }
        let mut j = Json::obj()
            .set("total", self.total)
            .set("underflow", self.underflow)
            .set("overflow", self.overflow)
            .set("bins", bins);
        if self.total > 0 {
            j = j
                .set("min_ms", self.min)
                .set("max_ms", self.max)
                .set("mean_ms", self.sum / self.total as f64)
                .set("p50_ms", self.quantile(50.0))
                .set("p99_ms", self.quantile(99.0));
        }
        j
    }
}

/// One typed simulator event. `at` (and `done`) are reference-clock cycle
/// instants — the same timeline `FleetReport` reports in.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A tenant won admission for a batch (multi-tenant only). `deficit` is
    /// the tenant's DRR billed-cycle counter at decision time.
    Admit { at: u64, tenant: usize, board: usize, items: usize, deficit: u64 },
    /// A batch (or one pipelined stage of a chain) started service.
    Dispatch { at: u64, tenant: usize, board: usize, items: usize, done: u64 },
    /// Completed items left a board — the per-tenant completion instant.
    /// Per-tenant sums/maxima over flushes reproduce `FleetReport` exactly.
    Flush { at: u64, tenant: usize, board: usize, items: usize },
    /// A running batch was preempted. `refunded_cycles` is the DRR deficit
    /// handed back to the victim for undelivered service.
    Preempt {
        at: u64,
        board: usize,
        victim: usize,
        by: usize,
        mode: &'static str,
        refunded_cycles: u64,
    },
    /// The window controller decided to re-shard.
    ReshardTrigger { at: u64, reason: String },
    /// Migration billing for one tenant (or the whole fleet when `tenant`
    /// is `None`, as in the single-tenant dynamic controller).
    ReshardStall { at: u64, tenant: Option<usize>, bytes: u64, stall_cycles: u64 },
    /// The fleet resumed after a re-shard stall.
    ReshardWake { at: u64 },
    /// A stats window closed (with or without a re-shard).
    WindowRollup { at: u64, requests: u64 },
    /// A scripted board failure fired. `requeued` counts the in-flight
    /// items of the batch the board was serving that went back to the head
    /// of their tenant's queue (the finished prefix completed in place).
    BoardFail { at: u64, board: usize, requeued: usize },
    /// A failed board came back and rejoined the candidate set.
    BoardRecover { at: u64, board: usize },
    /// A scripted link-degrade window opened on `board`'s egress link
    /// (`factor` × nominal bandwidth until cycle `until`).
    LinkDegrade { at: u64, board: usize, factor: f64, until: u64 },
    /// A board death severed a tenant placement (pipelined chain stage or
    /// last replica) and the control plane re-planned `tenants` tenants
    /// onto the surviving boards outside the normal window cadence.
    EmergencyReshard { at: u64, board: usize, tenants: usize },
    /// Admission shed a request (overload policy armed): its predicted
    /// wait broke the tenant's deadline or the queue hit `max_queue`.
    /// `attempt` is 0 for the first presentation, k for the k-th retry.
    Shed { at: u64, tenant: usize, attempt: u32, queue_depth: usize },
    /// A previously shed request re-arrived after its backoff.
    Retry { at: u64, tenant: usize, attempt: u32 },
    /// A shed request exhausted its retry budget and left the system
    /// unserved (counted toward `TenantStats::abandoned`).
    Abandon { at: u64, tenant: usize, attempts: u32 },
    /// A scripted partial-capacity brownout began on `board`: it serves
    /// with `fraction` × nominal compute throughput until cycle `until`
    /// (`None` = permanent).
    ComputeDegrade { at: u64, board: usize, fraction: f64, until: Option<u64> },
    /// Traffic billed over a routed fabric (fabric-armed runs only): a
    /// pipeline boundary hand-off, a re-shard migration, or a dead board's
    /// drain to a surviving peer, serialized hop-by-hop over `hops` shared
    /// segments. `at` is the completion instant of the last hop; `class` is
    /// `"boundary"`, `"migration"`, or `"drain"`.
    RouteTransfer { at: u64, src: usize, dst: usize, bytes: u64, hops: usize, class: &'static str },
}

impl TraceEvent {
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::Dispatch { .. } => "dispatch",
            TraceEvent::Flush { .. } => "flush",
            TraceEvent::Preempt { .. } => "preempt",
            TraceEvent::ReshardTrigger { .. } => "reshard_trigger",
            TraceEvent::ReshardStall { .. } => "reshard_stall",
            TraceEvent::ReshardWake { .. } => "reshard_wake",
            TraceEvent::WindowRollup { .. } => "window",
            TraceEvent::BoardFail { .. } => "board_fail",
            TraceEvent::BoardRecover { .. } => "board_recover",
            TraceEvent::LinkDegrade { .. } => "link_degrade",
            TraceEvent::EmergencyReshard { .. } => "emergency_reshard",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::Abandon { .. } => "abandon",
            TraceEvent::ComputeDegrade { .. } => "compute_degrade",
            TraceEvent::RouteTransfer { .. } => "route_transfer",
        }
    }

    pub fn at(&self) -> u64 {
        match *self {
            TraceEvent::Admit { at, .. }
            | TraceEvent::Dispatch { at, .. }
            | TraceEvent::Flush { at, .. }
            | TraceEvent::Preempt { at, .. }
            | TraceEvent::ReshardTrigger { at, .. }
            | TraceEvent::ReshardStall { at, .. }
            | TraceEvent::ReshardWake { at }
            | TraceEvent::WindowRollup { at, .. }
            | TraceEvent::BoardFail { at, .. }
            | TraceEvent::BoardRecover { at, .. }
            | TraceEvent::LinkDegrade { at, .. }
            | TraceEvent::EmergencyReshard { at, .. }
            | TraceEvent::Shed { at, .. }
            | TraceEvent::Retry { at, .. }
            | TraceEvent::Abandon { at, .. }
            | TraceEvent::ComputeDegrade { at, .. }
            | TraceEvent::RouteTransfer { at, .. } => at,
        }
    }

    pub fn to_json(&self) -> Json {
        let j = Json::obj().set("kind", self.kind()).set("at", self.at());
        match self {
            TraceEvent::Admit { tenant, board, items, deficit, .. } => j
                .set("tenant", *tenant as u64)
                .set("board", *board as u64)
                .set("items", *items as u64)
                .set("deficit", *deficit),
            TraceEvent::Dispatch { tenant, board, items, done, .. } => j
                .set("tenant", *tenant as u64)
                .set("board", *board as u64)
                .set("items", *items as u64)
                .set("done", *done),
            TraceEvent::Flush { tenant, board, items, .. } => j
                .set("tenant", *tenant as u64)
                .set("board", *board as u64)
                .set("items", *items as u64),
            TraceEvent::Preempt { board, victim, by, mode, refunded_cycles, .. } => j
                .set("board", *board as u64)
                .set("victim", *victim as u64)
                .set("by", *by as u64)
                .set("mode", *mode)
                .set("refunded_cycles", *refunded_cycles),
            TraceEvent::ReshardTrigger { reason, .. } => j.set("reason", reason.as_str()),
            TraceEvent::ReshardStall { tenant, bytes, stall_cycles, .. } => {
                let j = match tenant {
                    Some(t) => j.set("tenant", *t as u64),
                    None => j,
                };
                j.set("bytes", *bytes).set("stall_cycles", *stall_cycles)
            }
            TraceEvent::ReshardWake { .. } => j,
            TraceEvent::WindowRollup { requests, .. } => j.set("requests", *requests),
            TraceEvent::BoardFail { board, requeued, .. } => j
                .set("board", *board as u64)
                .set("requeued", *requeued as u64),
            TraceEvent::BoardRecover { board, .. } => j.set("board", *board as u64),
            TraceEvent::LinkDegrade { board, factor, until, .. } => j
                .set("board", *board as u64)
                .set("factor", *factor)
                .set("until", *until),
            TraceEvent::EmergencyReshard { board, tenants, .. } => j
                .set("board", *board as u64)
                .set("tenants", *tenants as u64),
            TraceEvent::Shed { tenant, attempt, queue_depth, .. } => j
                .set("tenant", *tenant as u64)
                .set("attempt", *attempt as u64)
                .set("queue_depth", *queue_depth as u64),
            TraceEvent::Retry { tenant, attempt, .. } => j
                .set("tenant", *tenant as u64)
                .set("attempt", *attempt as u64),
            TraceEvent::Abandon { tenant, attempts, .. } => j
                .set("tenant", *tenant as u64)
                .set("attempts", *attempts as u64),
            TraceEvent::ComputeDegrade { board, fraction, until, .. } => {
                let j = j
                    .set("board", *board as u64)
                    .set("fraction", *fraction);
                match until {
                    Some(u) => j.set("until", *u),
                    None => j,
                }
            }
            TraceEvent::RouteTransfer { src, dst, bytes, hops, class, .. } => j
                .set("src", *src as u64)
                .set("dst", *dst as u64)
                .set("bytes", *bytes)
                .set("hops", *hops as u64)
                .set("class", *class),
        }
    }
}

/// One windowed time-series sample, taken when a stats window closes at the
/// existing reshard-window boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowSample {
    /// Window-close instant (reference cycles).
    pub at: u64,
    /// Per-board busy fraction over the window just closed.
    pub busy_frac: Vec<f64>,
    /// Per-tenant pending queue depth at the boundary.
    pub queue_depth: Vec<usize>,
    /// Per-tenant p99 (ms) over the window's completions; NaN (JSON null)
    /// when a tenant completed nothing in the window.
    pub window_p99_ms: Vec<f64>,
}

impl WindowSample {
    pub fn to_json(&self) -> Json {
        let mut busy = Json::Arr(vec![]);
        for &b in &self.busy_frac {
            busy = busy.push(Json::from(b));
        }
        let mut depth = Json::Arr(vec![]);
        for &q in &self.queue_depth {
            depth = depth.push(Json::from(q as u64));
        }
        let mut p99 = Json::Arr(vec![]);
        for &p in &self.window_p99_ms {
            p99 = p99.push(Json::from(p));
        }
        Json::obj()
            .set("at", self.at)
            .set("busy_frac", busy)
            .set("queue_depth", depth)
            .set("window_p99_ms", p99)
    }
}

/// Aggregated telemetry carried on `FleetReport` when tracing is enabled
/// (the field is absent — not null — when disabled, so committed fixtures
/// stay byte-identical).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySummary {
    pub events_total: u64,
    pub admits: u64,
    pub dispatches: u64,
    pub flushes: u64,
    pub preemptions: u64,
    pub reshard_triggers: u64,
    pub reshard_stalls: u64,
    pub reshard_wakes: u64,
    pub windows: u64,
    /// Fault-injection counters (all zero on a healthy run).
    pub board_failures: u64,
    pub board_recoveries: u64,
    pub link_degrades: u64,
    pub emergency_reshards: u64,
    pub compute_degrades: u64,
    /// Overload counters (all zero without an `OverloadPolicy`).
    pub sheds: u64,
    pub retries: u64,
    pub abandons: u64,
    /// Fabric route-billing counters. `None` (keys absent in JSON) when no
    /// traffic ever crossed a routed fabric — which is every run with
    /// `fabric: None`, so existing telemetry consumers see no new keys.
    pub route_transfers: Option<u64>,
    pub route_bytes: Option<u64>,
    pub route_hops_max: Option<u64>,
    /// Simulator heap events processed (drives `sim_events_per_sec`).
    pub sim_events: u64,
    pub heap_depth_max: u64,
    pub heap_depth_mean: f64,
    /// Per-tenant sketch-estimated p99 (ms); NaN when a tenant has no
    /// completions.
    pub tenant_p99_ms: Vec<f64>,
}

impl TelemetrySummary {
    pub fn to_json(&self) -> Json {
        let mut p99 = Json::Arr(vec![]);
        for &p in &self.tenant_p99_ms {
            p99 = p99.push(Json::from(p));
        }
        let mut j = Json::obj()
            .set("events_total", self.events_total)
            .set("admits", self.admits)
            .set("dispatches", self.dispatches)
            .set("flushes", self.flushes)
            .set("preemptions", self.preemptions)
            .set("reshard_triggers", self.reshard_triggers)
            .set("reshard_stalls", self.reshard_stalls)
            .set("reshard_wakes", self.reshard_wakes)
            .set("windows", self.windows)
            .set("board_failures", self.board_failures)
            .set("board_recoveries", self.board_recoveries)
            .set("link_degrades", self.link_degrades)
            .set("emergency_reshards", self.emergency_reshards)
            .set("compute_degrades", self.compute_degrades)
            .set("sheds", self.sheds)
            .set("retries", self.retries)
            .set("abandons", self.abandons);
        if let Some(rt) = self.route_transfers {
            j = j.set("route_transfers", rt);
        }
        if let Some(rb) = self.route_bytes {
            j = j.set("route_bytes", rb);
        }
        if let Some(rh) = self.route_hops_max {
            j = j.set("route_hops_max", rh);
        }
        j.set("sim_events", self.sim_events)
            .set("heap_depth_max", self.heap_depth_max)
            .set("heap_depth_mean", self.heap_depth_mean)
            .set("tenant_p99_ms", p99)
    }
}

/// The recorder the simulators thread through their hot loops. Disabled is
/// the default everywhere; every record method is `#[inline]` and takes a
/// closure, so a disabled sink costs one branch and never constructs the
/// event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSink {
    enabled: bool,
    pub events: Vec<TraceEvent>,
    pub windows: Vec<WindowSample>,
    /// One latency sketch per tenant (index 0 for the single-tenant sims).
    pub sketches: Vec<QuantileSketch>,
    pub sim_events: u64,
    pub heap_depth_max: u64,
    heap_depth_sum: u64,
    heap_depth_samples: u64,
}

impl TraceSink {
    pub fn disabled() -> TraceSink {
        TraceSink {
            enabled: false,
            events: Vec::new(),
            windows: Vec::new(),
            sketches: Vec::new(),
            sim_events: 0,
            heap_depth_max: 0,
            heap_depth_sum: 0,
            heap_depth_samples: 0,
        }
    }

    pub fn enabled() -> TraceSink {
        TraceSink { enabled: true, ..TraceSink::disabled() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn record(&mut self, ev: impl FnOnce() -> TraceEvent) {
        if self.enabled {
            self.events.push(ev());
        }
    }

    #[inline]
    pub fn sample_window(&mut self, w: impl FnOnce() -> WindowSample) {
        if self.enabled {
            self.windows.push(w());
        }
    }

    /// Feed one completion latency into the tenant's quantile sketch.
    #[inline]
    pub fn observe_latency_ms(&mut self, tenant: usize, ms: f64) {
        if self.enabled {
            if self.sketches.len() <= tenant {
                self.sketches.resize_with(tenant + 1, QuantileSketch::new);
            }
            self.sketches[tenant].record(ms);
        }
    }

    /// Count one simulator heap event and sample the heap depth at the time
    /// it was processed (self-instrumentation for `sim_events_per_sec`).
    #[inline]
    pub fn note_sim_event(&mut self, heap_depth: usize) {
        if self.enabled {
            self.sim_events += 1;
            let d = heap_depth as u64;
            if d > self.heap_depth_max {
                self.heap_depth_max = d;
            }
            self.heap_depth_sum += d;
            self.heap_depth_samples += 1;
        }
    }

    pub fn heap_depth_mean(&self) -> f64 {
        if self.heap_depth_samples == 0 {
            0.0
        } else {
            self.heap_depth_sum as f64 / self.heap_depth_samples as f64
        }
    }

    /// `None` when disabled — which is what keeps `FleetReport::to_json`
    /// byte-identical for every committed fixture.
    pub fn summary(&self) -> Option<TelemetrySummary> {
        if !self.enabled {
            return None;
        }
        let mut s = TelemetrySummary {
            events_total: self.events.len() as u64,
            admits: 0,
            dispatches: 0,
            flushes: 0,
            preemptions: 0,
            reshard_triggers: 0,
            reshard_stalls: 0,
            reshard_wakes: 0,
            windows: self.windows.len() as u64,
            board_failures: 0,
            board_recoveries: 0,
            link_degrades: 0,
            emergency_reshards: 0,
            compute_degrades: 0,
            sheds: 0,
            retries: 0,
            abandons: 0,
            route_transfers: None,
            route_bytes: None,
            route_hops_max: None,
            sim_events: self.sim_events,
            heap_depth_max: self.heap_depth_max,
            heap_depth_mean: self.heap_depth_mean(),
            tenant_p99_ms: self
                .sketches
                .iter()
                .map(|q| if q.total() > 0 { q.quantile(99.0) } else { f64::NAN })
                .collect(),
        };
        for ev in &self.events {
            match ev {
                TraceEvent::Admit { .. } => s.admits += 1,
                TraceEvent::Dispatch { .. } => s.dispatches += 1,
                TraceEvent::Flush { .. } => s.flushes += 1,
                TraceEvent::Preempt { .. } => s.preemptions += 1,
                TraceEvent::ReshardTrigger { .. } => s.reshard_triggers += 1,
                TraceEvent::ReshardStall { .. } => s.reshard_stalls += 1,
                TraceEvent::ReshardWake { .. } => s.reshard_wakes += 1,
                // Window rollups are counted via the samples vector above.
                TraceEvent::WindowRollup { .. } => {}
                TraceEvent::BoardFail { .. } => s.board_failures += 1,
                TraceEvent::BoardRecover { .. } => s.board_recoveries += 1,
                TraceEvent::LinkDegrade { .. } => s.link_degrades += 1,
                TraceEvent::EmergencyReshard { .. } => s.emergency_reshards += 1,
                TraceEvent::Shed { .. } => s.sheds += 1,
                TraceEvent::Retry { .. } => s.retries += 1,
                TraceEvent::Abandon { .. } => s.abandons += 1,
                TraceEvent::ComputeDegrade { .. } => s.compute_degrades += 1,
                TraceEvent::RouteTransfer { bytes, hops, .. } => {
                    s.route_transfers = Some(s.route_transfers.unwrap_or(0) + 1);
                    s.route_bytes = Some(s.route_bytes.unwrap_or(0) + *bytes);
                    s.route_hops_max =
                        Some(s.route_hops_max.unwrap_or(0).max(*hops as u64));
                }
            }
        }
        Some(s)
    }

    /// Full trace export (the `--trace` payload body).
    pub fn to_json(&self) -> Json {
        let mut events = Json::Arr(vec![]);
        for ev in &self.events {
            events = events.push(ev.to_json());
        }
        let mut windows = Json::Arr(vec![]);
        for w in &self.windows {
            windows = windows.push(w.to_json());
        }
        let mut sketches = Json::Arr(vec![]);
        for q in &self.sketches {
            sketches = sketches.push(q.to_json());
        }
        Json::obj()
            .set("events", events)
            .set("windows", windows)
            .set("sketches", sketches)
            .set("sim_events", self.sim_events)
            .set("heap_depth_max", self.heap_depth_max)
            .set("heap_depth_mean", self.heap_depth_mean())
    }
}

/// Sum of flushed items per tenant — equals `TenantStats.requests` served.
pub fn flushed_items_per_tenant(events: &[TraceEvent], tenants: usize) -> Vec<u64> {
    let mut out = vec![0u64; tenants];
    for ev in events {
        if let TraceEvent::Flush { tenant, items, .. } = ev {
            out[*tenant] += *items as u64;
        }
    }
    out
}

/// Latest flush instant per tenant — equals the span `FleetReport` divides
/// by for per-tenant throughput. Zero for tenants that never flushed.
pub fn last_flush_per_tenant(events: &[TraceEvent], tenants: usize) -> Vec<u64> {
    let mut out = vec![0u64; tenants];
    for ev in events {
        if let TraceEvent::Flush { tenant, at, .. } = ev {
            if *at > out[*tenant] {
                out[*tenant] = *at;
            }
        }
    }
    out
}

/// Preemption count per victim tenant — equals `TenantStats.preemptions`.
pub fn preemptions_per_tenant(events: &[TraceEvent], tenants: usize) -> Vec<u64> {
    let mut out = vec![0u64; tenants];
    for ev in events {
        if let TraceEvent::Preempt { victim, .. } = ev {
            out[*victim] += 1;
        }
    }
    out
}

/// ASCII fleet dashboard: one occupancy lane per board (shaded by busy
/// fraction per column, from `Dispatch` spans), `P` markers where a batch
/// was preempted on that board, and a top `reshard` lane with `R` markers
/// at trigger instants — the `ascii_gantt` idiom lifted to the fleet.
pub fn fleet_dashboard(sink: &TraceSink, boards: usize, makespan: u64, width: usize) -> String {
    let width = width.max(8);
    let total = makespan.max(1) as f64;
    let col_of = |at: u64| (((at as f64 / total) * width as f64) as usize).min(width - 1);
    let mut busy = vec![vec![0.0f64; width]; boards];
    let mut marks: Vec<Vec<char>> = vec![vec![' '; width]; boards];
    let mut reshard = vec![' '; width];
    let col_span = total / width as f64;
    for ev in &sink.events {
        match ev {
            TraceEvent::Dispatch { board, at, done, .. } => {
                if *board >= boards {
                    continue;
                }
                let (a, b) = (*at as f64, (*done).max(*at) as f64);
                let (ca, cb) = (col_of(*at), col_of(*done));
                for col in ca..=cb {
                    let lo = (col as f64) * col_span;
                    let hi = lo + col_span;
                    let overlap = (b.min(hi) - a.max(lo)).max(0.0);
                    busy[*board][col] += overlap;
                }
            }
            TraceEvent::Preempt { board, at, .. } => {
                if *board < boards {
                    marks[*board][col_of(*at)] = 'P';
                }
            }
            TraceEvent::ReshardTrigger { at, .. } => {
                reshard[col_of(*at)] = 'R';
            }
            _ => {}
        }
    }
    let name_w = "reshard".len().max(format!("board {}", boards.saturating_sub(1)).len());
    let mut out = String::new();
    out.push_str(&format!(
        "{:name_w$} |{}|\n",
        "reshard",
        reshard.iter().collect::<String>(),
        name_w = name_w
    ));
    for b in 0..boards {
        let mut lane = String::new();
        let mut busy_cycles = 0.0;
        for col in 0..width {
            let frac = (busy[b][col] / col_span).min(1.0);
            busy_cycles += busy[b][col];
            lane.push(if marks[b][col] != ' ' {
                marks[b][col]
            } else if frac >= 0.95 {
                '█'
            } else if frac >= 0.66 {
                '▓'
            } else if frac >= 0.33 {
                '▒'
            } else if frac > 0.0 {
                '░'
            } else {
                ' '
            });
        }
        out.push_str(&format!(
            "{:name_w$} |{}| busy {:3.0}%\n",
            format!("board {b}"),
            lane,
            100.0 * (busy_cycles / total).min(1.0),
            name_w = name_w
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::stats::percentile_sorted;

    fn log_uniform_samples(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                // 1e-3 .. 1e3 ms, log-uniform: the full simulated range.
                let u = rng.next_f64();
                let exponent = u * 6.0 - 3.0;
                (std::f64::consts::LN_10 * exponent).exp()
            })
            .collect()
    }

    #[test]
    fn sketch_matches_percentile_sorted_within_one_percent() {
        for seed in [1u64, 7, 42] {
            let xs = log_uniform_samples(seed, 10_000);
            let mut sketch = QuantileSketch::new();
            for &x in &xs {
                sketch.record(x);
            }
            let mut sorted = xs.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for pct in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                let exact = percentile_sorted(&sorted, pct);
                let est = sketch.quantile(pct);
                let rel = (est - exact).abs() / exact.abs().max(1e-30);
                assert!(
                    rel <= 0.01,
                    "seed {seed} pct {pct}: exact {exact} est {est} rel {rel}"
                );
            }
        }
    }

    #[test]
    fn sketch_extremes_are_exact() {
        let mut s = QuantileSketch::new();
        for v in [0.25, 3.5, 17.0, 0.003] {
            s.record(v);
        }
        assert_eq!(s.quantile(0.0), 0.003);
        assert_eq!(s.quantile(100.0), 17.0);
        assert_eq!(s.total(), 4);
    }

    #[test]
    fn sketch_single_sample_is_exact() {
        let mut s = QuantileSketch::new();
        s.record(0.42);
        for pct in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.quantile(pct), 0.42);
        }
    }

    #[test]
    fn sketch_merge_equals_single_pass() {
        let xs = log_uniform_samples(9, 4_000);
        let mut whole = QuantileSketch::new();
        for &x in &xs {
            whole.record(x);
        }
        let mut merged = QuantileSketch::new();
        for chunk in xs.chunks(1_000) {
            let mut part = QuantileSketch::new();
            for &x in chunk {
                part.record(x);
            }
            merged.merge(&part);
        }
        assert_eq!(whole, merged);
        assert_eq!(whole.quantile(99.0), merged.quantile(99.0));
    }

    #[test]
    fn sketch_reset_restores_pristine_state() {
        let mut s = QuantileSketch::new();
        for &x in &log_uniform_samples(3, 500) {
            s.record(x);
        }
        s.record(0.0); // underflow bin
        assert!(s.total() > 0);
        s.reset();
        assert_eq!(s, QuantileSketch::new());
        // A reset sketch records like a fresh one.
        s.record(0.42);
        assert_eq!(s.quantile(99.0), 0.42);
    }

    #[test]
    fn sketch_underflow_bin_catches_tiny_values() {
        let mut s = QuantileSketch::new();
        s.record(0.0);
        s.record(1e-12);
        s.record(1.0);
        assert_eq!(s.total(), 3);
        // Extremes clamp to observed min/max.
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(100.0), 1.0);
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let mut sink = TraceSink::disabled();
        sink.record(|| panic!("event constructed on a disabled sink"));
        sink.sample_window(|| panic!("window sampled on a disabled sink"));
        sink.observe_latency_ms(0, 1.0);
        sink.note_sim_event(3);
        assert!(sink.events.is_empty());
        assert!(sink.windows.is_empty());
        assert!(sink.sketches.is_empty());
        assert_eq!(sink.sim_events, 0);
        assert!(sink.summary().is_none());
    }

    #[test]
    fn enabled_sink_counts_by_kind() {
        let mut sink = TraceSink::enabled();
        sink.record(|| TraceEvent::Admit { at: 1, tenant: 0, board: 0, items: 2, deficit: 7 });
        sink.record(|| TraceEvent::Dispatch { at: 1, tenant: 0, board: 0, items: 2, done: 9 });
        sink.record(|| TraceEvent::Flush { at: 9, tenant: 0, board: 0, items: 2 });
        sink.record(|| TraceEvent::Preempt {
            at: 5,
            board: 0,
            victim: 1,
            by: 0,
            mode: "resume",
            refunded_cycles: 4,
        });
        sink.record(|| TraceEvent::ReshardTrigger { at: 6, reason: "p99".into() });
        sink.record(|| TraceEvent::ReshardStall {
            at: 6,
            tenant: Some(1),
            bytes: 64,
            stall_cycles: 8,
        });
        sink.record(|| TraceEvent::ReshardWake { at: 14 });
        sink.record(|| TraceEvent::WindowRollup { at: 14, requests: 2 });
        sink.record(|| TraceEvent::BoardFail { at: 20, board: 2, requeued: 3 });
        sink.record(|| TraceEvent::LinkDegrade { at: 21, board: 0, factor: 0.5, until: 40 });
        sink.record(|| TraceEvent::EmergencyReshard { at: 22, board: 2, tenants: 1 });
        sink.record(|| TraceEvent::BoardRecover { at: 44, board: 2 });
        sink.record(|| TraceEvent::Shed { at: 50, tenant: 1, attempt: 0, queue_depth: 9 });
        sink.record(|| TraceEvent::Retry { at: 55, tenant: 1, attempt: 1 });
        sink.record(|| TraceEvent::Abandon { at: 60, tenant: 1, attempts: 3 });
        sink.record(|| TraceEvent::ComputeDegrade {
            at: 61,
            board: 1,
            fraction: 0.5,
            until: Some(99),
        });
        sink.record(|| TraceEvent::RouteTransfer {
            at: 70,
            src: 0,
            dst: 3,
            bytes: 4096,
            hops: 4,
            class: "boundary",
        });
        sink.record(|| TraceEvent::RouteTransfer {
            at: 80,
            src: 3,
            dst: 0,
            bytes: 1024,
            hops: 2,
            class: "migration",
        });
        sink.observe_latency_ms(0, 0.5);
        sink.note_sim_event(4);
        sink.note_sim_event(2);
        let s = sink.summary().unwrap();
        assert_eq!(s.events_total, 18);
        assert_eq!(s.admits, 1);
        assert_eq!(s.dispatches, 1);
        assert_eq!(s.flushes, 1);
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.reshard_triggers, 1);
        assert_eq!(s.reshard_stalls, 1);
        assert_eq!(s.reshard_wakes, 1);
        assert_eq!(s.board_failures, 1);
        assert_eq!(s.board_recoveries, 1);
        assert_eq!(s.link_degrades, 1);
        assert_eq!(s.emergency_reshards, 1);
        assert_eq!(s.sheds, 1);
        assert_eq!(s.retries, 1);
        assert_eq!(s.abandons, 1);
        assert_eq!(s.compute_degrades, 1);
        assert_eq!(s.route_transfers, Some(2));
        assert_eq!(s.route_bytes, Some(5120));
        assert_eq!(s.route_hops_max, Some(4));
        assert_eq!(s.sim_events, 2);
        assert_eq!(s.heap_depth_max, 4);
        assert_eq!(s.heap_depth_mean, 3.0);
        assert_eq!(s.tenant_p99_ms, vec![0.5]);
    }

    #[test]
    fn recompute_helpers_aggregate_flushes_and_preemptions() {
        let events = vec![
            TraceEvent::Flush { at: 10, tenant: 0, board: 0, items: 3 },
            TraceEvent::Flush { at: 25, tenant: 0, board: 1, items: 2 },
            TraceEvent::Flush { at: 12, tenant: 1, board: 0, items: 4 },
            TraceEvent::Preempt {
                at: 8,
                board: 0,
                victim: 1,
                by: 0,
                mode: "restart",
                refunded_cycles: 9,
            },
            TraceEvent::Preempt {
                at: 9,
                board: 1,
                victim: 1,
                by: 0,
                mode: "restart",
                refunded_cycles: 9,
            },
        ];
        assert_eq!(flushed_items_per_tenant(&events, 2), vec![5, 4]);
        assert_eq!(last_flush_per_tenant(&events, 2), vec![25, 12]);
        assert_eq!(preemptions_per_tenant(&events, 2), vec![0, 2]);
    }

    #[test]
    fn event_json_is_deterministic_and_typed() {
        let ev = TraceEvent::ReshardStall { at: 3, tenant: None, bytes: 10, stall_cycles: 2 };
        let j = ev.to_json().to_string_compact();
        assert!(j.contains("reshard_stall"));
        assert!(!j.contains("tenant")); // None ⇒ key omitted, like ReshardEvent
        let ev2 = TraceEvent::ReshardStall { at: 3, tenant: Some(4), bytes: 10, stall_cycles: 2 };
        assert!(ev2.to_json().to_string_compact().contains("tenant"));
        // A permanent brownout omits `until`, like ReshardStall's tenant.
        let ev3 = TraceEvent::ComputeDegrade { at: 5, board: 1, fraction: 0.5, until: None };
        let j3 = ev3.to_json().to_string_compact();
        assert!(j3.contains("compute_degrade") && !j3.contains("until"));
        let ev4 = TraceEvent::ComputeDegrade { at: 5, board: 1, fraction: 0.5, until: Some(9) };
        assert!(ev4.to_json().to_string_compact().contains("until"));
        let shed = TraceEvent::Shed { at: 2, tenant: 0, attempt: 1, queue_depth: 4 };
        assert_eq!(shed.kind(), "shed");
        assert_eq!(shed.at(), 2);
        let rt = TraceEvent::RouteTransfer {
            at: 9,
            src: 1,
            dst: 6,
            bytes: 256,
            hops: 4,
            class: "drain",
        };
        assert_eq!(rt.kind(), "route_transfer");
        assert_eq!(rt.at(), 9);
        let jr = rt.to_json().to_string_compact();
        assert!(jr.contains("\"class\":\"drain\"") && jr.contains("\"hops\":4"));
    }

    #[test]
    fn summary_without_route_traffic_has_no_route_keys() {
        // The fabric counters are strictly opt-in: a trace that never saw a
        // RouteTransfer must not grow new summary keys (the fabric: None
        // no-residue contract, extended to telemetry).
        let mut sink = TraceSink::enabled();
        sink.record(|| TraceEvent::Flush { at: 1, tenant: 0, board: 0, items: 1 });
        let s = sink.summary().unwrap();
        assert_eq!(s.route_transfers, None);
        let j = s.to_json().to_string_compact();
        assert!(!j.contains("route_transfers"));
        assert!(!j.contains("route_bytes"));
        assert!(!j.contains("route_hops_max"));
    }

    #[test]
    fn dashboard_renders_lanes_and_markers() {
        let mut sink = TraceSink::enabled();
        sink.record(|| TraceEvent::Dispatch { at: 0, tenant: 0, board: 0, items: 4, done: 500 });
        sink.record(|| TraceEvent::Dispatch { at: 500, tenant: 0, board: 1, items: 4, done: 1000 });
        sink.record(|| TraceEvent::Preempt {
            at: 250,
            board: 1,
            victim: 0,
            by: 1,
            mode: "restart",
            refunded_cycles: 0,
        });
        sink.record(|| TraceEvent::ReshardTrigger { at: 750, reason: "skew".into() });
        let dash = fleet_dashboard(&sink, 2, 1000, 32);
        let lines: Vec<&str> = dash.lines().collect();
        assert_eq!(lines.len(), 3); // reshard lane + 2 boards
        assert!(lines[0].contains('R'));
        assert!(lines[2].contains('P'));
        assert!(lines[1].contains('█') || lines[1].contains('▓'));
        assert!(lines[1].contains("busy"));
    }

    #[test]
    fn dashboard_handles_empty_trace() {
        let sink = TraceSink::enabled();
        let dash = fleet_dashboard(&sink, 1, 0, 16);
        assert_eq!(dash.lines().count(), 2);
    }
}
