//! Event-queue machinery for the fleet simulators.
//!
//! The simulators used to walk linear structures on every arrival: the
//! static scheduler re-checked batcher deadlines queue by queue, and the
//! dynamic dispatcher re-scanned every board to find the earliest start —
//! O(n·boards) over a sweep. This module replaces both inner loops with
//! index-aware heaps, making a 16-board × 100k-arrival sweep O(n log boards):
//!
//! * [`DeadlineQueue`] — a min-heap of pending batch-flush deadlines
//!   (arrival/flush events), drained in time order. Events are **coalesced
//!   per id**: the heap holds one entry per id (keyed by that id's earliest
//!   pending instant) and the full per-id schedule lives in a flat sorted
//!   run, so heap depth scales with *boards + tenants*, not with in-flight
//!   items. Drain order is provably identical to the plain
//!   `BinaryHeap<(at, id)>` it replaced: the heap root is the minimum over
//!   per-id heads, each head is its id's minimum, and equal-instant ties
//!   still break on the lower id — the property suite below replays
//!   randomized traces against a sorted-vector oracle to pin this.
//! * [`BoardPool`] — a busy/idle heap pair answering "which board can start
//!   soonest" with the *exact* tie-breaks of the linear scan it replaced
//!   (earliest start, then faster clock, then lower index); the property
//!   suite replays randomized traces against a brute-force scan oracle,
//!   and the golden fixtures under `tests/fixtures/` pin the resulting
//!   reports. [`BoardPool::rebuild`] re-seeds the pool in place (plan
//!   swaps happen mid-run; the old path allocated three fresh buffers per
//!   swap).
//!
//! Link-free state needs no heap: a pipelined batch walks its stage chain in
//! order and each cut's [`crate::cluster::LinkChannel`] already carries its
//! own occupancy timeline.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// `pos` sentinel: the id currently has no pending events (no heap entry).
const ABSENT: usize = usize::MAX;

/// Min-heap of `(cycle, id)` flush deadlines with per-id coalescing.
/// Entries may go stale (a size-bound flush emptied the queue first);
/// consumers validate against the batcher's live deadline before firing.
///
/// Layout: `heap` is a manual binary min-heap holding **one** `(head, id)`
/// entry per id with pending events, where `head` is that id's earliest
/// instant; `pending[id]` is the id's full schedule sorted *descending*
/// (pop the earliest from the back in O(1)); `pos[id]` tracks the id's
/// heap slot so `schedule` can decrease-key instead of pushing duplicates.
#[derive(Debug, Default)]
pub struct DeadlineQueue {
    heap: Vec<(u64, usize)>,
    pos: Vec<usize>,
    pending: Vec<Vec<u64>>,
    /// Total scheduled-but-unpopped events (uncoalesced count).
    events: usize,
}

impl DeadlineQueue {
    pub fn new() -> DeadlineQueue {
        DeadlineQueue::default()
    }

    /// Pre-size the id-indexed tables (ids may still grow past `ids` —
    /// the multi-tenant retry table appends ids mid-run).
    pub fn with_capacity(ids: usize) -> DeadlineQueue {
        DeadlineQueue {
            heap: Vec::with_capacity(ids),
            pos: vec![ABSENT; ids],
            pending: vec![Vec::new(); ids],
            events: 0,
        }
    }

    pub fn schedule(&mut self, at: u64, queue: usize) {
        if queue >= self.pending.len() {
            self.pending.resize_with(queue + 1, Vec::new);
            self.pos.resize(queue + 1, ABSENT);
        }
        let run = &mut self.pending[queue];
        // Descending run: everything > `at` stays in front, the earliest
        // instant sits at the back.
        let i = run.partition_point(|&x| x > at);
        run.insert(i, at);
        self.events += 1;
        let head = *run.last().expect("just inserted");
        let slot = self.pos[queue];
        if slot == ABSENT {
            self.pos[queue] = self.heap.len();
            self.heap.push((head, queue));
            self.sift_up(self.heap.len() - 1);
        } else if self.heap[slot].0 != head {
            // The new event became the id's head — a decrease-key.
            self.heap[slot].0 = head;
            self.sift_up(slot);
        }
    }

    /// Pop the earliest event not after `t`, if any.
    pub fn next_at_or_before(&mut self, t: u64) -> Option<(u64, usize)> {
        match self.heap.first() {
            Some(&(at, _)) if at <= t => self.pop(),
            _ => None,
        }
    }

    /// Pop the earliest event unconditionally (drain phase). Coalescing
    /// never drops duplicates: every scheduled instant comes back out as
    /// its own pop, in the exact `(cycle, id)` order of the plain heap
    /// this replaced.
    pub fn pop(&mut self) -> Option<(u64, usize)> {
        let &(at, id) = self.heap.first()?;
        let run = &mut self.pending[id];
        let popped = run.pop().expect("heap entry with empty run");
        debug_assert_eq!(popped, at);
        self.events -= 1;
        if let Some(&next) = run.last() {
            // Re-key the root at the id's next instant and restore order.
            self.heap[0].0 = next;
        } else {
            self.pos[id] = ABSENT;
            let last = self.heap.len() - 1;
            self.heap.swap(0, last);
            self.heap.pop();
            if self.heap.is_empty() {
                return Some((at, id));
            }
            self.pos[self.heap[0].1] = 0;
        }
        self.sift_down(0);
        Some((at, id))
    }

    /// Earliest pending `(cycle, queue)` without popping it.
    pub fn peek(&self) -> Option<(u64, usize)> {
        self.heap.first().copied()
    }

    /// **Coalesced** entry count: the number of ids with pending events,
    /// i.e. the live heap depth (this is what the telemetry heap-depth
    /// rows sample — O(boards + tenants) regardless of in-flight items).
    /// Stale entries are included; consumers validate at fire time.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending — the simulators' drain invariant.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total scheduled-but-unpopped events, duplicates included (the
    /// pre-coalescing `len`).
    pub fn pending_events(&self) -> usize {
        self.events
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i] >= self.heap[parent] {
                break;
            }
            self.heap.swap(i, parent);
            self.pos[self.heap[i].1] = i;
            self.pos[self.heap[parent].1] = parent;
            i = parent;
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = l + 1;
            let mut m = i;
            if l < n && self.heap[l] < self.heap[m] {
                m = l;
            }
            if r < n && self.heap[r] < self.heap[m] {
                m = r;
            }
            if m == i {
                return;
            }
            self.heap.swap(i, m);
            self.pos[self.heap[i].1] = i;
            self.pos[self.heap[m].1] = m;
            i = m;
        }
    }
}

/// Busy-board min-heap key: earliest `free_at` first; ties go to the faster
/// clock (max `freq_bits`), then the lower slot index. Wrapped in `Reverse`
/// inside the max-heap.
type BusyKey = (u64, Reverse<u64>, usize);

/// Idle-board max-heap key: fastest clock first, then lowest slot index.
type IdleKey = (u64, Reverse<usize>);

/// Board availability pool for the greedy dispatcher.
///
/// `pick(now)` returns the slot the replaced linear scan would have picked:
/// the lexicographic minimum of `(max(free_at, now), -freq, slot)` over all
/// slots. Boards whose `free_at ≤ now` are *released* into the idle heap
/// (start = `now`, ranked by clock then index); if none is idle the
/// earliest-freeing busy board wins. Positive clocks compare correctly via
/// their IEEE-754 bit patterns.
#[derive(Debug, Default)]
pub struct BoardPool {
    busy: BinaryHeap<Reverse<BusyKey>>,
    idle: BinaryHeap<IdleKey>,
    freq_bits: Vec<u64>,
}

impl BoardPool {
    /// Build from `(freq_mhz, free_at)` slots, one per dispatchable shard.
    pub fn from_slots(slots: impl Iterator<Item = (f64, u64)>) -> BoardPool {
        let mut pool = BoardPool::default();
        pool.rebuild(slots);
        pool
    }

    /// Re-seed the pool in place from fresh slots, reusing the heap and
    /// clock-table allocations. Mid-run plan swaps call this once per
    /// re-shard instead of building a new pool.
    pub fn rebuild(&mut self, slots: impl Iterator<Item = (f64, u64)>) {
        self.busy.clear();
        self.idle.clear();
        self.freq_bits.clear();
        for (slot, (freq_mhz, free_at)) in slots.enumerate() {
            assert!(freq_mhz > 0.0, "board clocks must be positive");
            self.freq_bits.push(freq_mhz.to_bits());
            self.busy.push(Reverse((free_at, Reverse(freq_mhz.to_bits()), slot)));
        }
        assert!(!self.freq_bits.is_empty(), "pool needs at least one slot");
    }

    /// Choose the slot that can start soonest at time `now`; returns
    /// `(slot, start_cycle)`. The caller must hand the slot back with
    /// [`BoardPool::release`] once its completion time is known.
    pub fn pick(&mut self, now: u64) -> (usize, u64) {
        // Release every board that has gone idle by `now`.
        while let Some(Reverse((free_at, _, slot))) = self.busy.peek().copied() {
            if free_at > now {
                break;
            }
            self.busy.pop();
            self.idle.push((self.freq_bits[slot], Reverse(slot)));
        }
        if let Some((_, Reverse(slot))) = self.idle.pop() {
            return (slot, now);
        }
        let Reverse((free_at, _, slot)) = self.busy.pop().expect("pool has a slot");
        (slot, free_at)
    }

    /// Return a picked slot with its next-free cycle.
    pub fn release(&mut self, slot: usize, free_at: u64) {
        self.busy.push(Reverse((free_at, Reverse(self.freq_bits[slot]), slot)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The oracle `BoardPool` must reproduce: the linear scan from the
    /// pre-rewrite dispatcher.
    fn scan_pick(free_at: &[u64], freqs: &[f64], now: u64) -> (usize, u64) {
        let mut pick = 0usize;
        let mut pick_start = u64::MAX;
        let mut pick_freq = f64::MIN;
        for (i, (&f, &fr)) in free_at.iter().zip(freqs).enumerate() {
            let start = f.max(now);
            if start < pick_start || (start == pick_start && fr > pick_freq) {
                pick = i;
                pick_start = start;
                pick_freq = fr;
            }
        }
        (pick, pick_start)
    }

    /// Property-suite size: the event heaps guard every simulator, so they
    /// get a deeper randomized sweep than the default 128 cases.
    const HEAP_PROP_CASES: usize = 256;

    fn heap_prop_cfg() -> prop::PropConfig {
        prop::PropConfig {
            cases: HEAP_PROP_CASES,
            ..prop::PropConfig::default()
        }
    }

    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn deadline_queue_orders_and_bounds() {
        let mut q = DeadlineQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek(), None);
        q.schedule(30, 1);
        q.schedule(10, 2);
        q.schedule(20, 0);
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek(), Some((10, 2)));
        assert_eq!(q.next_at_or_before(5), None);
        assert_eq!(q.next_at_or_before(25), Some((10, 2)));
        assert_eq!(q.next_at_or_before(25), Some((20, 0)));
        assert_eq!(q.next_at_or_before(25), None);
        assert!(!q.is_empty());
        assert_eq!(q.pop(), Some((30, 1)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn deadline_queue_coalesces_per_id() {
        // Five events on one id occupy one heap entry; every instant still
        // pops individually, duplicates included, in nondecreasing order.
        let mut q = DeadlineQueue::with_capacity(2);
        for at in [40, 10, 25, 25, 5] {
            q.schedule(at, 7);
        }
        assert_eq!(q.len(), 1, "one id → one coalesced entry");
        assert_eq!(q.pending_events(), 5);
        assert_eq!(q.peek(), Some((5, 7)));
        // A later-id event at an equal instant still loses the tie.
        q.schedule(5, 9);
        assert_eq!(q.len(), 2);
        let drained: Vec<(u64, usize)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![(5, 7), (5, 9), (10, 7), (25, 7), (25, 7), (40, 7)]);
        assert_eq!(q.pending_events(), 0);
    }

    #[test]
    fn deadline_queue_decrease_key_reorders_head() {
        // Scheduling an earlier instant on an id whose head is already in
        // the heap must re-rank that id (the decrease-key path).
        let mut q = DeadlineQueue::new();
        q.schedule(10, 0);
        q.schedule(7, 1);
        assert_eq!(q.peek(), Some((7, 1)));
        q.schedule(5, 0);
        assert_eq!(q.peek(), Some((5, 0)));
        assert_eq!(q.pop(), Some((5, 0)));
        assert_eq!(q.pop(), Some((7, 1)));
        assert_eq!(q.pop(), Some((10, 0)));
        assert_eq!(q.pop(), None);
    }

    /// One randomized operation against the queue: schedule an event, pop
    /// bounded at a horizon, or drain one unconditionally.
    #[derive(Debug, Clone, Copy)]
    enum QueueOp {
        Schedule(u64, usize),
        PopAtOrBefore(u64),
        Pop,
    }

    #[test]
    fn deadline_queue_drains_in_nondecreasing_time_order_on_random_traces() {
        // Oracle: a sorted vector popped from the front. The queue must
        // agree with it op-for-op, which implies (a) pops come out in
        // nondecreasing (time, queue) order between intervening schedules,
        // (b) `next_at_or_before(t)` never yields an event after `t` and
        // never withholds one at or before `t`, and (c) nothing is lost.
        // The tight id range (0..=4) makes per-id coalescing constant.
        prop::check(
            "deadline-queue-vs-sorted-oracle",
            heap_prop_cfg(),
            |r: &mut Rng| {
                let n = r.range_usize(1, 60);
                (0..n)
                    .map(|_| match r.below(3) {
                        0 | 1 => QueueOp::Schedule(r.below(100), r.range_usize(0, 4)),
                        _ => {
                            if r.chance(0.5) {
                                QueueOp::PopAtOrBefore(r.below(120))
                            } else {
                                QueueOp::Pop
                            }
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut q = DeadlineQueue::new();
                let mut oracle: Vec<(u64, usize)> = Vec::new();
                let mut last_popped: Option<(u64, usize)> = None;
                for &op in ops {
                    match op {
                        QueueOp::Schedule(at, queue) => {
                            q.schedule(at, queue);
                            let i = oracle.partition_point(|&e| e <= (at, queue));
                            oracle.insert(i, (at, queue));
                            // A fresh earlier event may legitimately pop
                            // before the last one we saw.
                            if Some((at, queue)) < last_popped {
                                last_popped = None;
                            }
                        }
                        QueueOp::PopAtOrBefore(t) => {
                            let want = match oracle.first() {
                                Some(&e) if e.0 <= t => Some(oracle.remove(0)),
                                _ => None,
                            };
                            let got = q.next_at_or_before(t);
                            if got != want {
                                return Err(format!(
                                    "next_at_or_before({t}): {got:?} vs oracle {want:?}"
                                ));
                            }
                            if let Some(e) = got {
                                if let Some(prev) = last_popped {
                                    if e < prev {
                                        return Err(format!(
                                            "pops went back in time: {prev:?} then {e:?}"
                                        ));
                                    }
                                }
                                last_popped = Some(e);
                            }
                        }
                        QueueOp::Pop => {
                            let want = if oracle.is_empty() {
                                None
                            } else {
                                Some(oracle.remove(0))
                            };
                            let got = q.pop();
                            if got != want {
                                return Err(format!("pop: {got:?} vs oracle {want:?}"));
                            }
                            if let Some(e) = got {
                                if let Some(prev) = last_popped {
                                    if e < prev {
                                        return Err(format!(
                                            "pops went back in time: {prev:?} then {e:?}"
                                        ));
                                    }
                                }
                                last_popped = Some(e);
                            }
                        }
                    }
                    // Coalescing invariant: heap depth counts ids, never
                    // in-flight events; events are conserved.
                    let distinct = {
                        let mut ids: Vec<usize> = oracle.iter().map(|&(_, id)| id).collect();
                        ids.sort_unstable();
                        ids.dedup();
                        ids.len()
                    };
                    if q.len() != distinct {
                        return Err(format!(
                            "coalesced len {} vs {} distinct pending ids",
                            q.len(),
                            distinct
                        ));
                    }
                    if q.pending_events() != oracle.len() {
                        return Err(format!(
                            "pending_events {} vs oracle {}",
                            q.pending_events(),
                            oracle.len()
                        ));
                    }
                }
                // Full drain at the end comes out exactly sorted.
                while let Some(e) = q.pop() {
                    let want = oracle.remove(0);
                    if e != want {
                        return Err(format!("drain: {e:?} vs oracle {want:?}"));
                    }
                }
                if !oracle.is_empty() {
                    return Err(format!("queue lost events: {oracle:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_matches_linear_scan_on_random_traces() {
        prop::check(
            "board-pool-vs-scan",
            heap_prop_cfg(),
            |r: &mut Rng| {
                let n = r.range_usize(1, 6);
                let freqs: Vec<f64> =
                    (0..n).map(|_| [60.0, 100.0, 120.0][r.below(3) as usize]).collect();
                let ops: Vec<(u64, u64)> =
                    (0..r.range_usize(1, 40)).map(|_| (r.below(50), 1 + r.below(30))).collect();
                (freqs, ops)
            },
            |(freqs, ops)| {
                let mut scan_free = vec![0u64; freqs.len()];
                let mut pool =
                    BoardPool::from_slots(freqs.iter().map(|&f| (f, 0u64)));
                let mut now = 0u64;
                for &(advance, svc) in ops {
                    now += advance;
                    let want = scan_pick(&scan_free, freqs, now);
                    let got = pool.pick(now);
                    if got != want {
                        return Err(format!("at t={now}: pool {got:?} vs scan {want:?}"));
                    }
                    let done = got.1 + svc;
                    scan_free[got.0] = done;
                    pool.release(got.0, done);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_matches_scan_from_staggered_initial_state() {
        // Same oracle, but slots start with nonzero, distinct `free_at`
        // values — the state every plan swap rebuilds the pool from.
        // `rebuild` (the in-place swap path) must behave exactly like a
        // fresh `from_slots`, including after prior use left the heaps
        // populated.
        prop::check(
            "board-pool-vs-scan-staggered",
            heap_prop_cfg(),
            |r: &mut Rng| {
                let n = r.range_usize(1, 6);
                let slots: Vec<(f64, u64)> = (0..n)
                    .map(|_| ([60.0, 100.0, 120.0][r.below(3) as usize], r.below(80)))
                    .collect();
                let ops: Vec<(u64, u64)> =
                    (0..r.range_usize(1, 30)).map(|_| (r.below(40), 1 + r.below(25))).collect();
                (slots, ops)
            },
            |(slots, ops)| {
                let freqs: Vec<f64> = slots.iter().map(|&(f, _)| f).collect();
                let mut scan_free: Vec<u64> = slots.iter().map(|&(_, at)| at).collect();
                // Seed with garbage state, then rebuild — the mid-run swap
                // path must fully supersede whatever came before.
                let mut pool = BoardPool::from_slots([(1.0, 999)].into_iter());
                pool.pick(0);
                pool.rebuild(slots.iter().copied());
                let mut now = 0u64;
                for &(advance, svc) in ops {
                    now += advance;
                    let want = scan_pick(&scan_free, &freqs, now);
                    let got = pool.pick(now);
                    if got != want {
                        return Err(format!("at t={now}: pool {got:?} vs scan {want:?}"));
                    }
                    let done = got.1 + svc;
                    scan_free[got.0] = done;
                    pool.release(got.0, done);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_tie_breaks_prefer_fast_then_low_index() {
        // Three idle boards at t=0: the 120 MHz one wins; among equal
        // clocks, the lower index.
        let mut pool = BoardPool::from_slots([(60.0, 0), (120.0, 0), (120.0, 0)].into_iter());
        assert_eq!(pool.pick(0), (1, 0));
        pool.release(1, 100);
        assert_eq!(pool.pick(0), (2, 0));
        pool.release(2, 100);
        assert_eq!(pool.pick(0), (0, 0));
        pool.release(0, 90);
        // All busy: earliest free_at wins regardless of clock.
        assert_eq!(pool.pick(10), (0, 90));
    }
}
