//! Event-queue machinery for the fleet simulators.
//!
//! The simulators used to walk linear structures on every arrival: the
//! static scheduler re-checked batcher deadlines queue by queue, and the
//! dynamic dispatcher re-scanned every board to find the earliest start —
//! O(n·boards) over a sweep. This module replaces both inner loops with
//! `BinaryHeap`s, making a 16-board × 100k-arrival sweep O(n log boards):
//!
//! * [`DeadlineQueue`] — a min-heap of pending batch-flush deadlines
//!   (arrival/flush events), drained in time order;
//! * [`BoardPool`] — a busy/idle heap pair answering "which board can start
//!   soonest" with the *exact* tie-breaks of the linear scan it replaced
//!   (earliest start, then faster clock, then lower index); the property
//!   suite below replays randomized traces against a brute-force scan
//!   oracle, and the golden fixtures under `tests/fixtures/` pin the
//!   resulting reports.
//!
//! Link-free state needs no heap: a pipelined batch walks its stage chain in
//! order and each cut's [`crate::cluster::LinkChannel`] already carries its
//! own occupancy timeline.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Min-heap of `(cycle, queue)` flush deadlines. Entries may go stale (a
/// size-bound flush emptied the queue first); consumers validate against
/// the batcher's live deadline before firing.
#[derive(Debug, Default)]
pub struct DeadlineQueue {
    heap: BinaryHeap<Reverse<(u64, usize)>>,
}

impl DeadlineQueue {
    pub fn new() -> DeadlineQueue {
        DeadlineQueue::default()
    }

    pub fn schedule(&mut self, at: u64, queue: usize) {
        self.heap.push(Reverse((at, queue)));
    }

    /// Pop the earliest event not after `t`, if any.
    pub fn next_at_or_before(&mut self, t: u64) -> Option<(u64, usize)> {
        match self.heap.peek() {
            Some(Reverse((at, _))) if *at <= t => self.heap.pop().map(|Reverse(e)| e),
            _ => None,
        }
    }

    /// Pop the earliest event unconditionally (drain phase).
    pub fn pop(&mut self) -> Option<(u64, usize)> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Earliest pending `(cycle, queue)` without popping it.
    pub fn peek(&self) -> Option<(u64, usize)> {
        self.heap.peek().map(|&Reverse(e)| e)
    }

    /// Pending event count (stale entries included — consumers validate at
    /// fire time).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending — the simulators' drain invariant.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Busy-board min-heap key: earliest `free_at` first; ties go to the faster
/// clock (max `freq_bits`), then the lower slot index. Wrapped in `Reverse`
/// inside the max-heap.
type BusyKey = (u64, Reverse<u64>, usize);

/// Idle-board max-heap key: fastest clock first, then lowest slot index.
type IdleKey = (u64, Reverse<usize>);

/// Board availability pool for the greedy dispatcher.
///
/// `pick(now)` returns the slot the replaced linear scan would have picked:
/// the lexicographic minimum of `(max(free_at, now), -freq, slot)` over all
/// slots. Boards whose `free_at ≤ now` are *released* into the idle heap
/// (start = `now`, ranked by clock then index); if none is idle the
/// earliest-freeing busy board wins. Positive clocks compare correctly via
/// their IEEE-754 bit patterns.
#[derive(Debug, Default)]
pub struct BoardPool {
    busy: BinaryHeap<Reverse<BusyKey>>,
    idle: BinaryHeap<IdleKey>,
    freq_bits: Vec<u64>,
}

impl BoardPool {
    /// Build from `(freq_mhz, free_at)` slots, one per dispatchable shard.
    pub fn from_slots(slots: impl Iterator<Item = (f64, u64)>) -> BoardPool {
        let mut pool = BoardPool::default();
        for (slot, (freq_mhz, free_at)) in slots.enumerate() {
            assert!(freq_mhz > 0.0, "board clocks must be positive");
            pool.freq_bits.push(freq_mhz.to_bits());
            pool.busy.push(Reverse((free_at, Reverse(freq_mhz.to_bits()), slot)));
        }
        assert!(!pool.freq_bits.is_empty(), "pool needs at least one slot");
        pool
    }

    /// Choose the slot that can start soonest at time `now`; returns
    /// `(slot, start_cycle)`. The caller must hand the slot back with
    /// [`BoardPool::release`] once its completion time is known.
    pub fn pick(&mut self, now: u64) -> (usize, u64) {
        // Release every board that has gone idle by `now`.
        while let Some(Reverse((free_at, _, slot))) = self.busy.peek().copied() {
            if free_at > now {
                break;
            }
            self.busy.pop();
            self.idle.push((self.freq_bits[slot], Reverse(slot)));
        }
        if let Some((_, Reverse(slot))) = self.idle.pop() {
            return (slot, now);
        }
        let Reverse((free_at, _, slot)) = self.busy.pop().expect("pool has a slot");
        (slot, free_at)
    }

    /// Return a picked slot with its next-free cycle.
    pub fn release(&mut self, slot: usize, free_at: u64) {
        self.busy.push(Reverse((free_at, Reverse(self.freq_bits[slot]), slot)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The oracle `BoardPool` must reproduce: the linear scan from the
    /// pre-rewrite dispatcher.
    fn scan_pick(free_at: &[u64], freqs: &[f64], now: u64) -> (usize, u64) {
        let mut pick = 0usize;
        let mut pick_start = u64::MAX;
        let mut pick_freq = f64::MIN;
        for (i, (&f, &fr)) in free_at.iter().zip(freqs).enumerate() {
            let start = f.max(now);
            if start < pick_start || (start == pick_start && fr > pick_freq) {
                pick = i;
                pick_start = start;
                pick_freq = fr;
            }
        }
        (pick, pick_start)
    }

    /// Property-suite size: the event heaps guard every simulator, so they
    /// get a deeper randomized sweep than the default 128 cases.
    const HEAP_PROP_CASES: usize = 256;

    fn heap_prop_cfg() -> prop::PropConfig {
        prop::PropConfig {
            cases: HEAP_PROP_CASES,
            ..prop::PropConfig::default()
        }
    }

    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn deadline_queue_orders_and_bounds() {
        let mut q = DeadlineQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek(), None);
        q.schedule(30, 1);
        q.schedule(10, 2);
        q.schedule(20, 0);
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek(), Some((10, 2)));
        assert_eq!(q.next_at_or_before(5), None);
        assert_eq!(q.next_at_or_before(25), Some((10, 2)));
        assert_eq!(q.next_at_or_before(25), Some((20, 0)));
        assert_eq!(q.next_at_or_before(25), None);
        assert!(!q.is_empty());
        assert_eq!(q.pop(), Some((30, 1)));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    /// One randomized operation against the queue: schedule an event, pop
    /// bounded at a horizon, or drain one unconditionally.
    #[derive(Debug, Clone, Copy)]
    enum QueueOp {
        Schedule(u64, usize),
        PopAtOrBefore(u64),
        Pop,
    }

    #[test]
    fn deadline_queue_drains_in_nondecreasing_time_order_on_random_traces() {
        // Oracle: a sorted vector popped from the front. The queue must
        // agree with it op-for-op, which implies (a) pops come out in
        // nondecreasing (time, queue) order between intervening schedules,
        // (b) `next_at_or_before(t)` never yields an event after `t` and
        // never withholds one at or before `t`, and (c) nothing is lost.
        prop::check(
            "deadline-queue-vs-sorted-oracle",
            heap_prop_cfg(),
            |r: &mut Rng| {
                let n = r.range_usize(1, 60);
                (0..n)
                    .map(|_| match r.below(3) {
                        0 | 1 => QueueOp::Schedule(r.below(100), r.range_usize(0, 4)),
                        _ => {
                            if r.chance(0.5) {
                                QueueOp::PopAtOrBefore(r.below(120))
                            } else {
                                QueueOp::Pop
                            }
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |ops| {
                let mut q = DeadlineQueue::new();
                let mut oracle: Vec<(u64, usize)> = Vec::new();
                let mut last_popped: Option<(u64, usize)> = None;
                for &op in ops {
                    match op {
                        QueueOp::Schedule(at, queue) => {
                            q.schedule(at, queue);
                            let i = oracle.partition_point(|&e| e <= (at, queue));
                            oracle.insert(i, (at, queue));
                            // A fresh earlier event may legitimately pop
                            // before the last one we saw.
                            if Some((at, queue)) < last_popped {
                                last_popped = None;
                            }
                        }
                        QueueOp::PopAtOrBefore(t) => {
                            let want = match oracle.first() {
                                Some(&e) if e.0 <= t => Some(oracle.remove(0)),
                                _ => None,
                            };
                            let got = q.next_at_or_before(t);
                            if got != want {
                                return Err(format!(
                                    "next_at_or_before({t}): {got:?} vs oracle {want:?}"
                                ));
                            }
                            if let Some(e) = got {
                                if let Some(prev) = last_popped {
                                    if e < prev {
                                        return Err(format!(
                                            "pops went back in time: {prev:?} then {e:?}"
                                        ));
                                    }
                                }
                                last_popped = Some(e);
                            }
                        }
                        QueueOp::Pop => {
                            let want = if oracle.is_empty() {
                                None
                            } else {
                                Some(oracle.remove(0))
                            };
                            let got = q.pop();
                            if got != want {
                                return Err(format!("pop: {got:?} vs oracle {want:?}"));
                            }
                            if let Some(e) = got {
                                if let Some(prev) = last_popped {
                                    if e < prev {
                                        return Err(format!(
                                            "pops went back in time: {prev:?} then {e:?}"
                                        ));
                                    }
                                }
                                last_popped = Some(e);
                            }
                        }
                    }
                }
                // Full drain at the end comes out exactly sorted.
                while let Some(e) = q.pop() {
                    let want = oracle.remove(0);
                    if e != want {
                        return Err(format!("drain: {e:?} vs oracle {want:?}"));
                    }
                }
                if !oracle.is_empty() {
                    return Err(format!("queue lost events: {oracle:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_matches_linear_scan_on_random_traces() {
        prop::check(
            "board-pool-vs-scan",
            heap_prop_cfg(),
            |r: &mut Rng| {
                let n = r.range_usize(1, 6);
                let freqs: Vec<f64> =
                    (0..n).map(|_| [60.0, 100.0, 120.0][r.below(3) as usize]).collect();
                let ops: Vec<(u64, u64)> =
                    (0..r.range_usize(1, 40)).map(|_| (r.below(50), 1 + r.below(30))).collect();
                (freqs, ops)
            },
            |(freqs, ops)| {
                let mut scan_free = vec![0u64; freqs.len()];
                let mut pool =
                    BoardPool::from_slots(freqs.iter().map(|&f| (f, 0u64)));
                let mut now = 0u64;
                for &(advance, svc) in ops {
                    now += advance;
                    let want = scan_pick(&scan_free, freqs, now);
                    let got = pool.pick(now);
                    if got != want {
                        return Err(format!("at t={now}: pool {got:?} vs scan {want:?}"));
                    }
                    let done = got.1 + svc;
                    scan_free[got.0] = done;
                    pool.release(got.0, done);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_matches_scan_from_staggered_initial_state() {
        // Same oracle, but slots start with nonzero, distinct `free_at`
        // values — the state every plan swap rebuilds the pool from.
        prop::check(
            "board-pool-vs-scan-staggered",
            heap_prop_cfg(),
            |r: &mut Rng| {
                let n = r.range_usize(1, 6);
                let slots: Vec<(f64, u64)> = (0..n)
                    .map(|_| ([60.0, 100.0, 120.0][r.below(3) as usize], r.below(80)))
                    .collect();
                let ops: Vec<(u64, u64)> =
                    (0..r.range_usize(1, 30)).map(|_| (r.below(40), 1 + r.below(25))).collect();
                (slots, ops)
            },
            |(slots, ops)| {
                let freqs: Vec<f64> = slots.iter().map(|&(f, _)| f).collect();
                let mut scan_free: Vec<u64> = slots.iter().map(|&(_, at)| at).collect();
                let mut pool = BoardPool::from_slots(slots.iter().copied());
                let mut now = 0u64;
                for &(advance, svc) in ops {
                    now += advance;
                    let want = scan_pick(&scan_free, &freqs, now);
                    let got = pool.pick(now);
                    if got != want {
                        return Err(format!("at t={now}: pool {got:?} vs scan {want:?}"));
                    }
                    let done = got.1 + svc;
                    scan_free[got.0] = done;
                    pool.release(got.0, done);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pool_tie_breaks_prefer_fast_then_low_index() {
        // Three idle boards at t=0: the 120 MHz one wins; among equal
        // clocks, the lower index.
        let mut pool = BoardPool::from_slots([(60.0, 0), (120.0, 0), (120.0, 0)].into_iter());
        assert_eq!(pool.pick(0), (1, 0));
        pool.release(1, 100);
        assert_eq!(pool.pick(0), (2, 0));
        pool.release(2, 100);
        assert_eq!(pool.pick(0), (0, 0));
        pool.release(0, 90);
        // All busy: earliest free_at wins regardless of clock.
        assert_eq!(pool.pick(10), (0, 90));
    }
}
