//! The pre-event-queue fleet simulators, kept verbatim as a differential
//! oracle.
//!
//! [`crate::cluster::sim`] rewrote the inner loops around heaps
//! ([`crate::cluster::events`]); the contract of that rewrite is *byte
//! identical* [`FleetReport`]s. This module preserves the original
//! per-arrival linear walks — queue-by-queue deadline checks in
//! [`simulate_fleet`], the O(boards) earliest-start scan in
//! [`simulate_fleet_dynamic`] — so equivalence tests
//! (`tests/integration_cluster.rs`, `sim::tests`) can diff the two paths on
//! every scenario class, and `benches/compute_kernels.rs` can report the
//! naive-vs-event-queue events/s ratio. Not wired into any serving path;
//! new features land in `sim` only.

use std::time::{Duration, Instant};

use crate::accel::engine::Weights;
use crate::config::{AccelConfig, ClusterConfig, Network, ReshardPolicy, ShardMode};
use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher};
use crate::fpga::ddr::SharedDdr;
use crate::util::stats::percentile_sorted;

use super::link::{InterBoardLink, LinkChannel};
use super::shard::ShardPlan;
use super::sim::{
    arrivals_with_steps, fleet_demand, migration_bytes, BoardStats, FleetReport, ReshardEvent,
};

/// Drive round-robin arrivals through per-queue [`DynamicBatcher`]s — the
/// original lazy form: a queue's elapsed flush deadline fires only when its
/// own next arrival lands (or at the final drain), not in global time order.
fn drive_batchers(
    batchers: &mut [DynamicBatcher<usize>],
    arrivals: &[u64],
    to_instant: &impl Fn(u64) -> Instant,
    to_cycles: &impl Fn(Instant) -> u64,
    mut serve: impl FnMut(usize, Vec<usize>, u64),
) {
    for (i, &a) in arrivals.iter().enumerate() {
        let b = i % batchers.len();
        // Fire any batching deadline that elapsed before this arrival.
        while let Some(dl) = batchers[b].next_deadline() {
            if to_cycles(dl) > a {
                break;
            }
            match batchers[b].poll(dl) {
                Some(batch) => serve(b, batch, to_cycles(dl)),
                None => break,
            }
        }
        if let Some(batch) = batchers[b].push(i, to_instant(a)) {
            serve(b, batch, a);
        }
    }
    // Remaining queues flush when their wait deadline fires.
    for (b, batcher) in batchers.iter_mut().enumerate() {
        if let Some(dl) = batcher.next_deadline() {
            let ready = to_cycles(dl);
            let batch = match batcher.poll(dl) {
                Some(batch) => batch,
                None => batcher.flush(),
            };
            serve(b, batch, ready);
        }
    }
}

/// Pre-rewrite [`crate::cluster::sim::simulate_fleet`].
pub fn simulate_fleet(cfg: &AccelConfig, shard: &ShardPlan, ccfg: &ClusterConfig) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    let ref_freq = cfg.platform.freq_mhz;
    let n = ccfg.requests;
    let arrivals = arrivals_with_steps(n, ccfg.arrival_rps, &ccfg.load_steps, ref_freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let demand = fleet_demand(shard, ref_freq);

    let t0 = Instant::now();
    let ns_per_cycle = 1e3 / ref_freq;
    let to_instant = |c: u64| t0 + Duration::from_nanos((c as f64 * ns_per_cycle).round() as u64);
    let to_cycles =
        |i: Instant| (i.duration_since(t0).as_nanos() as f64 / ns_per_cycle).round() as u64;
    let policy = BatchPolicy {
        max_batch: ccfg.max_batch,
        max_wait: Duration::from_nanos((ccfg.max_wait_us * 1e3).round() as u64),
    };

    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;

    let service =
        |s: &super::shard::BoardShard, bsz: u64| s.service_cycles(bsz, ref_freq, &shared, demand);

    let (busy, batch_counts, item_counts) = match shard.mode {
        ShardMode::Replicated => {
            let nb = shard.used_boards();
            let mut batchers: Vec<DynamicBatcher<usize>> =
                (0..nb).map(|_| DynamicBatcher::new(policy)).collect();
            let mut free_at = vec![0u64; nb];
            let mut busy = vec![0u64; nb];
            drive_batchers(
                &mut batchers,
                &arrivals,
                &to_instant,
                &to_cycles,
                |b, batch, ready| {
                    let bsz = batch.len() as u64;
                    let svc = service(&shard.shards[b], bsz);
                    let start = ready.max(free_at[b]);
                    let done = start + svc;
                    free_at[b] = done;
                    busy[b] += svc;
                    for req in batch {
                        complete[req] = done;
                    }
                },
            );
            let batches: Vec<u64> = batchers.iter().map(|b| b.batches_emitted).collect();
            let items: Vec<u64> = batchers.iter().map(|b| b.items_processed).collect();
            (busy, batches, items)
        }
        ShardMode::Pipelined => {
            let stages = shard.used_boards();
            let mut entry = vec![DynamicBatcher::<usize>::new(policy)];
            let mut free_at = vec![0u64; stages];
            let mut busy = vec![0u64; stages];
            let mut links: Vec<LinkChannel> = (0..stages.saturating_sub(1))
                .map(|_| LinkChannel::new(link))
                .collect();
            drive_batchers(
                &mut entry,
                &arrivals,
                &to_instant,
                &to_cycles,
                |_, batch, ready| {
                    let bsz = batch.len() as u64;
                    let mut t = ready;
                    for (s, bs) in shard.shards.iter().enumerate() {
                        let svc = service(bs, bsz);
                        let start = t.max(free_at[s]);
                        let done = start + svc;
                        free_at[s] = done;
                        busy[s] += svc;
                        t = done;
                        if s + 1 < stages {
                            let bytes = bs.egress_bytes * bsz;
                            link_bytes_total += bytes;
                            t = links[s].transfer(bytes, t);
                        }
                    }
                    for req in batch {
                        complete[req] = t;
                    }
                },
            );
            let batches = vec![entry[0].batches_emitted; stages];
            let items = vec![entry[0].items_processed; stages];
            (busy, batches, items)
        }
    };

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| (c.saturating_sub(a)) as f64 * ns_per_cycle / 1e6)
        .collect();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..shard.used_boards())
        .map(|b| BoardStats {
            board: b,
            items: item_counts[b],
            batches: batch_counts[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: shard.shards[b].freq_mhz,
        })
        .collect();

    FleetReport {
        mode: shard.mode,
        boards: shard.boards,
        used_boards: shard.used_boards(),
        idle_boards: shard.idle_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events: Vec::new(),
    }
}

/// Pre-rewrite [`crate::cluster::sim::simulate_fleet_dynamic`]: the
/// replicated arm re-scans every shard per batch.
pub fn simulate_fleet_dynamic(
    cfg: &AccelConfig,
    fleet: &[AccelConfig],
    net: &Network,
    weights: &Weights,
    initial: ShardPlan,
    ccfg: &ClusterConfig,
) -> FleetReport {
    ccfg.validate().expect("invalid cluster config");
    assert!(!fleet.is_empty());
    assert!(
        initial.used_boards() <= fleet.len(),
        "initial plan uses more boards than the fleet has"
    );
    let ref_freq = cfg.platform.freq_mhz;
    let ns_per_cycle = 1e3 / ref_freq;
    let n = ccfg.requests;
    let arrivals = arrivals_with_steps(n, ccfg.arrival_rps, &ccfg.load_steps, ref_freq, ccfg.seed);
    let shared = SharedDdr::new(
        cfg.platform.ddr_bytes_per_cycle,
        ccfg.aggregate_ddr_bytes_per_cycle,
    );
    let link = InterBoardLink::new(ccfg.link_bytes_per_cycle, ccfg.link_latency_cycles);
    let nb = fleet.len();
    let word_bytes = cfg.platform.word_bytes;
    let n_layers = net.layers.len();

    let mut plan = initial;
    let mut links: Vec<LinkChannel> = (0..plan.used_boards().saturating_sub(1))
        .map(|_| LinkChannel::new(link))
        .collect();
    let mut demand = fleet_demand(&plan, ref_freq);

    let mut free_at = vec![0u64; nb];
    let mut busy = vec![0u64; nb];
    let mut items = vec![0u64; nb];
    let mut batches = vec![0u64; nb];
    let mut complete = vec![0u64; n];
    let mut link_bytes_total = 0u64;
    let mut events: Vec<ReshardEvent> = Vec::new();

    let policy: Option<ReshardPolicy> = ccfg.reshard.clone();
    let mut win_lat_ms: Vec<f64> = Vec::new();
    let mut win_start = 0u64;
    let mut win_busy0 = busy.clone();
    let mut cooldown = 0usize;
    let mut sim_now = 0u64;

    let mut i = 0usize;
    while i < n {
        // ---- dispatch one batch, greedy and work-conserving ----
        let (batch_done, batch_len) = match plan.mode {
            ShardMode::Replicated => {
                let a = arrivals[i];
                // The original linear scan: every shard examined per batch.
                let mut pick = 0usize;
                let mut pick_start = u64::MAX;
                let mut pick_freq = f64::MIN;
                for (si, s) in plan.shards.iter().enumerate() {
                    let start = free_at[s.board].max(a);
                    if start < pick_start || (start == pick_start && s.freq_mhz > pick_freq) {
                        pick = si;
                        pick_start = start;
                        pick_freq = s.freq_mhz;
                    }
                }
                let s = &plan.shards[pick];
                let start = pick_start;
                let mut k = 1usize;
                while i + k < n && k < ccfg.max_batch && arrivals[i + k] <= start {
                    k += 1;
                }
                let bsz = k as u64;
                let svc = s.service_cycles(bsz, ref_freq, &shared, demand);
                let done = start + svc;
                free_at[s.board] = done;
                busy[s.board] += svc;
                items[s.board] += bsz;
                batches[s.board] += 1;
                for c in complete.iter_mut().skip(i).take(k) {
                    *c = done;
                }
                (done, k)
            }
            ShardMode::Pipelined => {
                let a = arrivals[i];
                let first = plan.shards[0].board;
                let start0 = free_at[first].max(a);
                let mut k = 1usize;
                while i + k < n && k < ccfg.max_batch && arrivals[i + k] <= start0 {
                    k += 1;
                }
                let bsz = k as u64;
                let stages = plan.used_boards();
                let mut t = start0;
                for (si, s) in plan.shards.iter().enumerate() {
                    let svc = s.service_cycles(bsz, ref_freq, &shared, demand);
                    let start = t.max(free_at[s.board]);
                    let done = start + svc;
                    free_at[s.board] = done;
                    busy[s.board] += svc;
                    items[s.board] += bsz;
                    batches[s.board] += 1;
                    t = done;
                    if si + 1 < stages {
                        let bytes = s.egress_bytes * bsz;
                        link_bytes_total += bytes;
                        t = links[si].transfer(bytes, t);
                    }
                }
                for c in complete.iter_mut().skip(i).take(k) {
                    *c = t;
                }
                (t, k)
            }
        };

        for j in i..i + batch_len {
            win_lat_ms
                .push(complete[j].saturating_sub(arrivals[j]) as f64 * ns_per_cycle / 1e6);
        }
        i += batch_len;
        sim_now = sim_now.max(batch_done);

        // ---- controller: evaluate the window ----
        let Some(pol) = &policy else { continue };
        if win_lat_ms.len() < pol.window {
            continue;
        }
        let now = sim_now;
        let span = now.saturating_sub(win_start);
        let mut sorted = win_lat_ms.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let p99 = percentile_sorted(&sorted, 99.0);
        let mut skew = 0.0f64;
        if span > 0 {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for s in &plan.shards {
                let u = busy[s.board].saturating_sub(win_busy0[s.board]) as f64 / span as f64;
                lo = lo.min(u);
                hi = hi.max(u);
            }
            skew = hi - lo;
        }
        if cooldown > 0 {
            cooldown -= 1;
        } else if p99 > pol.p99_ms || skew > pol.util_skew {
            let reason = if p99 > pol.p99_ms {
                format!("window p99 {p99:.1} ms > {:.1} ms", pol.p99_ms)
            } else {
                format!("utilization skew {skew:.2} > {:.2}", pol.util_skew)
            };
            let mut best: Option<(f64, ShardPlan)> = None;
            for cand in [
                ShardPlan::replicated_fleet(fleet, net, weights, &plan.plan),
                ShardPlan::pipelined_fleet(fleet, net, weights, &plan.plan),
            ] {
                if !cand.fits() {
                    continue;
                }
                let cap = cand.capacity_rps(ccfg.max_batch, &link, ref_freq);
                let better = match &best {
                    None => true,
                    Some((b, _)) => cap > *b,
                };
                if better {
                    best = Some((cap, cand));
                }
            }
            if let Some((_, new_plan)) = best {
                if new_plan.label() != plan.label() {
                    let raw = migration_bytes(&plan, &new_plan, weights, word_bytes, n_layers, nb);
                    let bill = (raw as f64 * pol.migration_factor).round() as u64;
                    let stall = link.transfer_cycles(bill);
                    let sync = free_at.iter().copied().max().unwrap_or(now).max(now);
                    for f in &mut free_at {
                        *f = sync + stall;
                    }
                    events.push(ReshardEvent {
                        at_cycle: sync,
                        from: plan.label(),
                        to: new_plan.label(),
                        reason,
                        migration_bytes: bill,
                        stall_cycles: stall,
                    });
                    links = (0..new_plan.used_boards().saturating_sub(1))
                        .map(|_| LinkChannel::new(link))
                        .collect();
                    plan = new_plan;
                    demand = fleet_demand(&plan, ref_freq);
                    cooldown = pol.cooldown_windows;
                }
            }
        }
        win_lat_ms.clear();
        win_start = now;
        win_busy0.copy_from_slice(&busy);
    }

    let makespan_cycles = complete.iter().copied().max().unwrap_or(0);
    let makespan_s = makespan_cycles as f64 * ns_per_cycle / 1e9;
    let mut lat_ms: Vec<f64> = complete
        .iter()
        .zip(&arrivals)
        .map(|(&c, &a)| c.saturating_sub(a) as f64 * ns_per_cycle / 1e6)
        .collect();
    lat_ms.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let mean_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;

    let per_board: Vec<BoardStats> = (0..nb)
        .map(|b| BoardStats {
            board: b,
            items: items[b],
            batches: batches[b],
            busy_cycles: busy[b],
            utilization: if makespan_cycles == 0 {
                0.0
            } else {
                busy[b] as f64 / makespan_cycles as f64
            },
            freq_mhz: fleet[b].platform.freq_mhz,
        })
        .collect();

    FleetReport {
        mode: plan.mode,
        boards: nb,
        used_boards: plan.used_boards(),
        idle_boards: nb - plan.used_boards(),
        requests: n,
        completed: n,
        makespan_cycles,
        throughput_rps: n as f64 / makespan_s,
        mean_ms,
        p50_ms: percentile_sorted(&lat_ms, 50.0),
        p99_ms: percentile_sorted(&lat_ms, 99.0),
        per_board,
        link_bytes_total,
        ddr_slowdown: shared.slowdown_of(demand),
        reshard_events: events,
    }
}
