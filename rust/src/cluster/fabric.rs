//! Rack-scale routed interconnect fabric.
//!
//! The paper's core argument is that inter-layer fusion wins by keeping
//! boundary traffic off the expensive shared channel (external DDR); at
//! fleet scale the analogous shared channel is the rack interconnect. The
//! point-to-point [`LinkChannel`]s the simulators grew up with give every
//! pipelined chain a private wire per stage boundary, so co-tenant
//! transfers, migration bills and fault drains can never contend with each
//! other. A [`Fabric`] replaces those private wires with a routed topology:
//!
//! * boards map to racks in contiguous chunks
//!   ([`FabricSpec::boards_per_rack`]), mirroring the rack order
//!   `board_specs` already uses;
//! * every rack owns one **intra-rack backplane segment**, and racks are
//!   joined by **uplink segments** per the [`FabricTopology`] — one
//!   rack-to-spine uplink each on a leaf-spine, one wire per adjacent rack
//!   pair on a ring;
//! * [`Fabric::route`] returns the segment path a `src → dst` transfer
//!   crosses, and [`Fabric::transfer`] bills the bytes over *every* hop on
//!   the **shared** serializing timeline of each segment (the same
//!   occupancy model as [`LinkChannel`], which each segment wraps).
//!
//! Because segments are shared, a saturated uplink is a producible
//! bottleneck: two pipelined chains placed across the same rack boundary
//! queue behind each other on that rack's uplink, which is exactly the
//! contention the topology-aware placement in [`crate::cluster::shard`]
//! exists to avoid. The fabric is *physical* state — it persists across
//! re-shards (plans change, wires do not), so its byte odometers conserve
//! across mid-run plan switches by construction.
//!
//! Everything here is strictly opt-in: with [`ClusterConfig::fabric`]
//! `None` the simulators never construct a `Fabric` and keep the original
//! point-to-point arithmetic byte-for-byte.
//!
//! [`ClusterConfig::fabric`]: crate::config::ClusterConfig::fabric

use crate::cluster::link::{InterBoardLink, LinkChannel};
use crate::config::{FabricSpec, FabricTopology};
use crate::util::json::Json;

/// What a fabric segment physically is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentKind {
    /// A rack's internal backplane: every transfer entering or leaving a
    /// board of that rack crosses it.
    Intra,
    /// A leaf-spine rack uplink: all of one rack's cross-rack traffic, in
    /// both directions, serializes here.
    Uplink,
    /// A ring wire joining two adjacent racks, shared by both directions.
    Ring,
}

impl SegmentKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SegmentKind::Intra => "intra",
            SegmentKind::Uplink => "uplink",
            SegmentKind::Ring => "ring",
        }
    }
}

/// One shared serializing wire of the fabric: a [`LinkChannel`] occupancy
/// timeline plus the contention counters the utilization report needs.
#[derive(Debug, Clone)]
pub struct Segment {
    pub kind: SegmentKind,
    /// Owning rack (intra/uplink) or lower-numbered endpoint rack (ring).
    pub rack: usize,
    pub channel: LinkChannel,
    /// Transfers billed over this segment (zero-byte transfers are free
    /// and uncounted, matching [`LinkChannel::transfer`]).
    pub transfers: u64,
    /// Cycles the wire spent occupied (queueing excluded: a transfer's
    /// wait behind an earlier one bills the earlier transfer's span, not
    /// this one twice).
    pub busy_cycles: u64,
}

impl Segment {
    fn name(&self) -> String {
        match self.kind {
            SegmentKind::Intra => format!("rack{}", self.rack),
            SegmentKind::Uplink => format!("uplink{}", self.rack),
            SegmentKind::Ring => format!("ring{}", self.rack),
        }
    }
}

/// The routed rack fabric: segment timelines plus the topology's routing
/// function. Construct once per simulation from the validated spec; bill
/// every inter-board byte through [`Fabric::transfer`].
#[derive(Debug, Clone)]
pub struct Fabric {
    spec: FabricSpec,
    n_racks: usize,
    pub segments: Vec<Segment>,
}

impl Fabric {
    pub fn new(spec: &FabricSpec, boards: usize) -> Fabric {
        assert!(boards >= 1, "fabric needs at least one board");
        let n_racks = spec.n_racks(boards);
        let intra = InterBoardLink::new(spec.intra_bytes_per_cycle, spec.intra_latency_cycles);
        let up = InterBoardLink::new(spec.uplink_bytes_per_cycle, spec.uplink_latency_cycles);
        let mut segments: Vec<Segment> = (0..n_racks)
            .map(|r| Segment {
                kind: SegmentKind::Intra,
                rack: r,
                channel: LinkChannel::new(intra),
                transfers: 0,
                busy_cycles: 0,
            })
            .collect();
        match spec.topology {
            FabricTopology::LeafSpine => {
                for r in 0..n_racks {
                    segments.push(Segment {
                        kind: SegmentKind::Uplink,
                        rack: r,
                        channel: LinkChannel::new(up),
                        transfers: 0,
                        busy_cycles: 0,
                    });
                }
            }
            FabricTopology::RackRing => {
                // A 2-rack ring degenerates to a single shared wire; a
                // 1-rack ring has none.
                let wires = match n_racks {
                    0 | 1 => 0,
                    2 => 1,
                    r => r,
                };
                for w in 0..wires {
                    segments.push(Segment {
                        kind: SegmentKind::Ring,
                        rack: w,
                        channel: LinkChannel::new(up),
                        transfers: 0,
                        busy_cycles: 0,
                    });
                }
            }
        }
        Fabric {
            spec: spec.clone(),
            n_racks,
            segments,
        }
    }

    pub fn n_racks(&self) -> usize {
        self.n_racks
    }

    pub fn rack_of(&self, board: usize) -> usize {
        self.spec.rack_of(board)
    }

    pub fn spec(&self) -> &FabricSpec {
        &self.spec
    }

    /// Segment id of rack `r`'s intra backplane.
    fn intra(&self, r: usize) -> usize {
        r
    }

    /// Segment id of cross-rack wire `w` (uplink `w` on a leaf-spine,
    /// ring wire `w` on a ring).
    fn cross(&self, w: usize) -> usize {
        self.n_racks + w
    }

    /// The segment path a `src → dst` transfer crosses, in billing order.
    /// Same board: empty (a board talking to itself never touches the
    /// fabric). Same rack: the backplane. Cross-rack: source backplane,
    /// then the topology's uplink hops, then the destination backplane.
    pub fn route(&self, src: usize, dst: usize) -> Vec<usize> {
        if src == dst {
            return Vec::new();
        }
        let (sr, dr) = (self.rack_of(src), self.rack_of(dst));
        if sr == dr {
            return vec![self.intra(sr)];
        }
        let mut path = vec![self.intra(sr)];
        match self.spec.topology {
            FabricTopology::LeafSpine => {
                path.push(self.cross(sr));
                path.push(self.cross(dr));
            }
            FabricTopology::RackRing => {
                let r = self.n_racks;
                if r == 2 {
                    path.push(self.cross(0));
                } else {
                    // Shorter arc, ties clockwise. Wire w joins racks w
                    // and (w + 1) % r and is shared by both directions.
                    let cw = (dr + r - sr) % r;
                    let ccw = (sr + r - dr) % r;
                    if cw <= ccw {
                        for k in 0..cw {
                            path.push(self.cross((sr + k) % r));
                        }
                    } else {
                        for k in 0..ccw {
                            path.push(self.cross((sr + r - 1 - k) % r));
                        }
                    }
                }
            }
        }
        path.push(self.intra(dr));
        path
    }

    /// Bill `bytes` over the route from `src` to `dst` starting no earlier
    /// than `earliest`; returns the completion cycle. Hops serialize: the
    /// transfer occupies each segment in route order, queueing behind
    /// whatever that segment is already carrying — which is how a shared
    /// uplink becomes the bottleneck of two otherwise-independent chains.
    /// Zero-byte transfers are free, same-board transfers cross nothing.
    pub fn transfer(&mut self, src: usize, dst: usize, bytes: u64, earliest: u64) -> u64 {
        let route = self.route(src, dst);
        self.transfer_route(&route, bytes, earliest)
    }

    /// [`Fabric::transfer`] over a precomputed route.
    pub fn transfer_route(&mut self, route: &[usize], bytes: u64, earliest: u64) -> u64 {
        if bytes == 0 {
            return earliest;
        }
        let mut t = earliest;
        for &s in route {
            let seg = &mut self.segments[s];
            let start = t.max(seg.channel.busy_until());
            let end = seg.channel.transfer(bytes, t);
            seg.transfers += 1;
            seg.busy_cycles += end - start;
            t = end;
        }
        t
    }

    /// Total bytes billed over all segments (each transfer counts once per
    /// hop — the conservation invariant the property suite checks is per
    /// segment, not fleet-total).
    pub fn bytes_moved(&self) -> u64 {
        self.segments.iter().map(|s| s.channel.bytes_moved).sum()
    }

    /// Arm [`LinkChannel`] degrade windows on the backplane of `board`'s
    /// rack — the fabric-mode reading of a
    /// [`crate::config::FaultEvent::LinkDegrade`] on that board's egress:
    /// the first hop of every route leaving the board runs slow (and,
    /// being shared media, so does its rack-mates' traffic — a degraded
    /// backplane is a rack-wide event).
    pub fn set_board_degrades(&mut self, board: usize, windows: Vec<(u64, u64, f64)>) {
        let r = self.rack_of(board);
        let id = self.intra(r);
        self.segments[id].channel.set_degrades(windows);
    }

    /// Per-segment utilization snapshot against a run's makespan.
    pub fn summary(&self, makespan_cycles: u64) -> FabricSummary {
        FabricSummary {
            topology: self.spec.topology.as_str().to_string(),
            racks: self.n_racks,
            boards_per_rack: self.spec.boards_per_rack,
            segments: self
                .segments
                .iter()
                .map(|s| SegmentSummary {
                    name: s.name(),
                    kind: s.kind.as_str().to_string(),
                    bytes_moved: s.channel.bytes_moved,
                    transfers: s.transfers,
                    busy_cycles: s.busy_cycles,
                    utilization: if makespan_cycles == 0 {
                        0.0
                    } else {
                        s.busy_cycles as f64 / makespan_cycles as f64
                    },
                })
                .collect(),
        }
    }
}

/// The per-segment report section a fabric-armed run attaches to
/// [`crate::cluster::FleetReport`] (key absent with `fabric: None` — the
/// byte-compat contract).
#[derive(Debug, Clone, PartialEq)]
pub struct FabricSummary {
    pub topology: String,
    pub racks: usize,
    pub boards_per_rack: usize,
    pub segments: Vec<SegmentSummary>,
}

/// One segment's lifetime counters.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentSummary {
    pub name: String,
    pub kind: String,
    pub bytes_moved: u64,
    pub transfers: u64,
    pub busy_cycles: u64,
    /// `busy_cycles / makespan` — the number the provisioning question
    /// ("is the uplink the bottleneck?") reads directly.
    pub utilization: f64,
}

impl FabricSummary {
    pub fn to_json(&self) -> Json {
        let mut segs = Json::Arr(vec![]);
        for s in &self.segments {
            segs = segs.push(
                Json::obj()
                    .set("name", s.name.as_str())
                    .set("kind", s.kind.as_str())
                    .set("bytes_moved", s.bytes_moved)
                    .set("transfers", s.transfers)
                    .set("busy_cycles", s.busy_cycles)
                    .set("utilization", s.utilization),
            );
        }
        Json::obj()
            .set("topology", self.topology.as_str())
            .set("racks", self.racks)
            .set("boards_per_rack", self.boards_per_rack)
            .set("segments", segs)
    }

    pub fn from_json(j: &Json) -> Result<FabricSummary, String> {
        let segments = j
            .get("segments")
            .as_arr()
            .ok_or("fabric summary: missing 'segments'")?
            .iter()
            .map(|s| {
                Ok(SegmentSummary {
                    name: s
                        .get("name")
                        .as_str()
                        .ok_or("fabric segment: missing 'name'")?
                        .to_string(),
                    kind: s
                        .get("kind")
                        .as_str()
                        .ok_or("fabric segment: missing 'kind'")?
                        .to_string(),
                    bytes_moved: s
                        .get("bytes_moved")
                        .as_u64()
                        .ok_or("fabric segment: missing 'bytes_moved'")?,
                    transfers: s
                        .get("transfers")
                        .as_u64()
                        .ok_or("fabric segment: missing 'transfers'")?,
                    busy_cycles: s
                        .get("busy_cycles")
                        .as_u64()
                        .ok_or("fabric segment: missing 'busy_cycles'")?,
                    utilization: s
                        .get("utilization")
                        .as_f64()
                        .ok_or("fabric segment: missing 'utilization'")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(FabricSummary {
            topology: j
                .get("topology")
                .as_str()
                .ok_or("fabric summary: missing 'topology'")?
                .to_string(),
            racks: j
                .get("racks")
                .as_usize()
                .ok_or("fabric summary: missing 'racks'")?,
            boards_per_rack: j
                .get("boards_per_rack")
                .as_usize()
                .ok_or("fabric summary: missing 'boards_per_rack'")?,
            segments,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(topology: FabricTopology, bpr: usize) -> FabricSpec {
        FabricSpec {
            topology,
            boards_per_rack: bpr,
            intra_bytes_per_cycle: 16.0,
            intra_latency_cycles: 10,
            uplink_bytes_per_cycle: 4.0,
            uplink_latency_cycles: 40,
        }
    }

    #[test]
    fn same_board_routes_nowhere_and_same_rack_crosses_the_backplane() {
        let f = Fabric::new(&spec(FabricTopology::LeafSpine, 4), 8);
        assert!(f.route(2, 2).is_empty());
        assert_eq!(f.route(0, 3), vec![0], "rack 0's backplane");
        assert_eq!(f.route(5, 4), vec![1], "rack 1's backplane");
    }

    #[test]
    fn leaf_spine_cross_rack_route_is_four_hops() {
        let f = Fabric::new(&spec(FabricTopology::LeafSpine, 4), 8);
        // rack0 backplane, rack0 uplink, rack1 uplink, rack1 backplane.
        assert_eq!(f.route(1, 6), vec![0, 2, 3, 1]);
        // The reverse direction shares the same two uplinks.
        assert_eq!(f.route(6, 1), vec![1, 3, 2, 0]);
    }

    #[test]
    fn ring_takes_the_shorter_arc_ties_clockwise() {
        // 4 racks of 1 board: wires 0↔1 (id 4), 1↔2 (5), 2↔3 (6), 3↔0 (7).
        let f = Fabric::new(&spec(FabricTopology::RackRing, 1), 4);
        assert_eq!(f.route(0, 1), vec![0, 4, 1], "one hop clockwise");
        assert_eq!(f.route(0, 3), vec![0, 7, 3], "one hop counter-clockwise");
        // Distance 2 either way: the tie goes clockwise through rack 1.
        assert_eq!(f.route(0, 2), vec![0, 4, 5, 2]);
        // Two racks degenerate to a single shared wire.
        let f2 = Fabric::new(&spec(FabricTopology::RackRing, 2), 4);
        assert_eq!(f2.route(0, 2), vec![0, 2, 1]);
        assert_eq!(f2.route(3, 1), vec![1, 2, 0]);
    }

    #[test]
    fn shared_uplink_serializes_two_chains() {
        // Two transfers from different boards of rack 0 to rack 1 at the
        // same instant: both queue on rack 0's backplane and uplink. The
        // second finishes no earlier than the serialized lower bound.
        let mut f = Fabric::new(&spec(FabricTopology::LeafSpine, 2), 4);
        let bytes = 4000u64;
        let e1 = f.transfer(0, 2, bytes, 0);
        let e2 = f.transfer(1, 3, bytes, 0);
        // Uplink drain alone: 40 + 4000/4 = 1040 cycles per transfer; two
        // transfers over the same uplink cannot beat 2× the drain.
        assert!(e1 >= 1040);
        assert!(
            e2 >= e1 + 1000,
            "second chain must queue behind the first on the shared uplink: {e2} vs {e1}"
        );
        // Per-segment conservation: every segment carried exactly what was
        // routed over it.
        let up0 = &f.segments[2];
        assert_eq!(up0.kind, SegmentKind::Uplink);
        assert_eq!(up0.channel.bytes_moved, 2 * bytes);
        assert_eq!(up0.transfers, 2);
    }

    #[test]
    fn zero_bytes_and_same_board_are_free() {
        let mut f = Fabric::new(&spec(FabricTopology::LeafSpine, 2), 4);
        assert_eq!(f.transfer(0, 3, 0, 99), 99);
        assert_eq!(f.transfer(1, 1, 1 << 20, 7), 7);
        assert_eq!(f.bytes_moved(), 0);
        assert!(f.segments.iter().all(|s| s.transfers == 0));
    }

    #[test]
    fn busy_cycles_exclude_queueing() {
        let mut f = Fabric::new(&spec(FabricTopology::LeafSpine, 2), 2);
        // Same-rack transfers: backplane only. 160 B at 16 B/c + 10 lat.
        let e1 = f.transfer(0, 1, 160, 0);
        assert_eq!(e1, 20);
        let e2 = f.transfer(1, 0, 160, 0); // queues behind the first
        assert_eq!(e2, 40);
        let seg = &f.segments[0];
        assert_eq!(seg.busy_cycles, 40, "wire time, not wire + wait");
        let s = f.summary(80);
        assert_eq!(s.segments[0].utilization, 0.5);
    }

    #[test]
    fn summary_roundtrips_through_json() {
        let mut f = Fabric::new(&spec(FabricTopology::RackRing, 2), 6);
        f.transfer(0, 5, 1 << 16, 0);
        f.transfer(4, 1, 1 << 12, 100);
        let s = f.summary(1 << 20);
        let back = FabricSummary::from_json(&s.to_json()).unwrap();
        assert_eq!(s, back);
    }
}
