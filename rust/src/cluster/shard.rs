//! Shard planner: partition a network's fusion groups across N boards.
//!
//! Two strategies, mirroring the two classic scale-out shapes:
//!
//! * **Replicated** (data parallel): every board hosts the whole fusion
//!   plan; the fleet load-balances requests. Capacity scales with boards,
//!   per-request latency does not improve.
//! * **Pipelined** (model parallel): each board hosts a contiguous range of
//!   fusion groups; activation volumes cross inter-board links at the cuts.
//!   Throughput is set by the slowest stage, so the planner balances stages
//!   with a min-max DP over per-item group costs.
//!
//! Costing reuses the closed-form models the single-board planner already
//! trusts: [`group_cost_estimate`] for cycles, [`group_traffic_bytes`] for
//! local DDR traffic, [`group_resources`] (max over resident groups — units
//! are reused across serialized groups, paper §V) for per-board feasibility.

use std::ops::Range;

use crate::accel::engine::Weights;
use crate::accel::fusion::FusionPlan;
use crate::accel::latency::{group_cost_estimate, GroupCost};
use crate::config::{AccelConfig, Network, ShardMode, VolShape};
use crate::resources::{group_resources, Resources};

/// One board's slice of the work, fully costed.
#[derive(Debug, Clone)]
pub struct BoardShard {
    pub board: usize,
    /// Indices into `plan.groups()` hosted by this board.
    pub groups: Range<usize>,
    /// Layer range covered (groups are contiguous, so this is too).
    pub layers: Range<usize>,
    /// Per-batch overhead cycles: Σ fill+drain of resident groups.
    pub overhead_cycles: u64,
    /// Per-item steady-state cycles: Σ steady of resident groups.
    pub steady_cycles: u64,
    /// Per-inference local DDR traffic (bytes) of the resident groups.
    pub traffic_bytes: u64,
    /// Peak resources over resident groups (units reused across groups).
    pub resources: Resources,
    pub fits: bool,
    /// Bytes this board forwards to the next stage per inference
    /// (0 for the last stage and for replicated shards).
    pub egress_bytes: u64,
}

impl BoardShard {
    /// Cycles this board spends on a batch of `batch` inferences
    /// (excluding contention stall, which depends on fleet state).
    pub fn batch_cycles(&self, batch: u64) -> u64 {
        self.overhead_cycles + self.steady_cycles.saturating_mul(batch)
    }

    /// Single-inference cycles on this board.
    pub fn item_cycles(&self) -> u64 {
        self.batch_cycles(1)
    }
}

/// A fusion plan distributed across a fleet.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub mode: ShardMode,
    /// Boards provisioned (pipelined mode may use fewer than provisioned
    /// when the plan has fewer groups).
    pub boards: usize,
    pub plan: FusionPlan,
    /// One entry per *used* board.
    pub shards: Vec<BoardShard>,
}

impl ShardPlan {
    /// Data-parallel sharding: the whole plan on every board.
    pub fn replicated(
        cfg: &AccelConfig,
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
        boards: usize,
    ) -> ShardPlan {
        assert!(boards >= 1);
        let ctx = PlanCtx::new(cfg, net, weights, plan);
        let proto = ctx.cost_range(0..plan.n_groups(), 0);
        let shards = (0..boards)
            .map(|b| BoardShard {
                board: b,
                ..proto.clone()
            })
            .collect();
        ShardPlan {
            mode: ShardMode::Replicated,
            boards,
            plan: plan.clone(),
            shards,
        }
    }

    /// Model-parallel sharding: balance contiguous group ranges over at most
    /// `boards` stages, minimizing the slowest stage's per-item cycles.
    pub fn pipelined(
        cfg: &AccelConfig,
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
        boards: usize,
    ) -> ShardPlan {
        assert!(boards >= 1);
        let ctx = PlanCtx::new(cfg, net, weights, plan);
        let totals: Vec<u64> = ctx.costs.iter().map(|c| c.total()).collect();
        let cuts = balance_min_max(&totals, boards.min(totals.len()));
        let shards: Vec<BoardShard> = cuts
            .windows(2)
            .enumerate()
            .map(|(b, w)| ctx.cost_range(w[0]..w[1], b))
            .collect();
        ShardPlan {
            mode: ShardMode::Pipelined,
            boards,
            plan: plan.clone(),
            shards,
        }
    }

    /// Boards actually hosting work.
    pub fn used_boards(&self) -> usize {
        self.shards.len()
    }

    /// Bytes one inference moves across inter-board links (Σ egress of all
    /// non-final stages). 0 in replicated mode.
    pub fn link_bytes_per_item(&self) -> u64 {
        self.shards.iter().map(|s| s.egress_bytes).sum()
    }

    /// Every used board fits its platform budget.
    pub fn fits(&self) -> bool {
        self.shards.iter().all(|s| s.fits)
    }

    /// Per-item cycles of the slowest stage (pipeline bottleneck). For
    /// replicated shards this is simply one board's per-item cycles.
    pub fn bottleneck_cycles(&self) -> u64 {
        self.shards.iter().map(|s| s.item_cycles()).max().unwrap_or(0)
    }
}

/// Per-plan costing context: shapes and group costs computed once, shared by
/// every shard the planner carves out of the plan.
struct PlanCtx<'a> {
    cfg: &'a AccelConfig,
    net: &'a Network,
    weights: &'a Weights,
    groups: Vec<Range<usize>>,
    shapes: Vec<VolShape>,
    costs: Vec<GroupCost>,
}

impl<'a> PlanCtx<'a> {
    fn new(
        cfg: &'a AccelConfig,
        net: &'a Network,
        weights: &'a Weights,
        plan: &FusionPlan,
    ) -> PlanCtx<'a> {
        let groups = plan.groups();
        let costs = groups
            .iter()
            .map(|g| group_cost_estimate(cfg, net, g.clone()))
            .collect();
        PlanCtx {
            cfg,
            net,
            weights,
            groups,
            shapes: net.shapes(),
            costs,
        }
    }

    /// Cost one contiguous range of fusion groups as a board shard.
    fn cost_range(&self, group_range: Range<usize>, board: usize) -> BoardShard {
        assert!(!group_range.is_empty());
        let wb = self.cfg.platform.word_bytes;
        let layer_lo = self.groups[group_range.start].start;
        let layer_hi = self.groups[group_range.end - 1].end;
        let mut overhead = 0u64;
        let mut steady = 0u64;
        let mut traffic = 0u64;
        let mut res = Resources::default();
        for (g, c) in self.groups[group_range.clone()]
            .iter()
            .zip(&self.costs[group_range.clone()])
        {
            overhead += c.fill + c.drain;
            steady += c.steady;
            traffic += (self.shapes[g.start].elems() * wb) as u64
                + (self.shapes[g.end].elems() * wb) as u64
                + self.weights.bytes_for_layers(g.clone(), wb);
            res = res.max(group_resources(self.cfg, self.net, g.clone()));
        }
        // Egress: the output volume of the shard's last group, unless it is
        // the network's final output (which returns to the client, not a
        // peer board).
        let egress_bytes = if layer_hi == self.net.layers.len() {
            0
        } else {
            (self.shapes[layer_hi].elems() * wb) as u64
        };
        let fits = res.fits(self.cfg);
        BoardShard {
            board,
            groups: group_range,
            layers: layer_lo..layer_hi,
            overhead_cycles: overhead,
            steady_cycles: steady,
            traffic_bytes: traffic,
            resources: res,
            fits,
            egress_bytes,
        }
    }
}

/// Partition `costs` into at most `k` contiguous non-empty segments
/// minimizing the maximum segment sum, using the *fewest* segments that
/// achieve the optimum (extra pipeline stages add link hops without raising
/// throughput). Returns the cut points `[0, …, costs.len()]`. Classic
/// O(k·n²) DP — n is the number of fusion groups (≤ 20), k the board count.
fn balance_min_max(costs: &[u64], k: usize) -> Vec<usize> {
    let n = costs.len();
    assert!(n >= 1 && (1..=n).contains(&k));
    // prefix[i] = Σ costs[..i]
    let mut prefix = vec![0u64; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + costs[i];
    }
    let seg = |j: usize, i: usize| prefix[i] - prefix[j];
    // dp[s][i]: best max-segment-sum splitting costs[..i] into s segments.
    let mut dp = vec![vec![u64::MAX; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    for i in 1..=n {
        dp[1][i] = seg(0, i);
    }
    for s in 2..=k {
        for i in s..=n {
            for j in (s - 1)..i {
                let v = dp[s - 1][j].max(seg(j, i));
                if v < dp[s][i] {
                    dp[s][i] = v;
                    cut[s][i] = j;
                }
            }
        }
    }
    let best = (1..=k).map(|s| dp[s][n]).min().unwrap();
    let stages = (1..=k).find(|&s| dp[s][n] == best).unwrap();
    let mut bounds = vec![n];
    let mut i = n;
    for s in (2..=stages).rev() {
        i = cut[s][i];
        bounds.push(i);
    }
    bounds.push(0);
    bounds.reverse();
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{tiny_vgg, vgg16_prefix};

    fn setup() -> (AccelConfig, Network, Weights) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        (AccelConfig::paper_default(), net, w)
    }

    #[test]
    fn balance_min_max_basic() {
        assert_eq!(balance_min_max(&[5, 5, 5, 5], 2), vec![0, 2, 4]);
        assert_eq!(balance_min_max(&[9, 1, 1, 1], 2), vec![0, 1, 4]);
        assert_eq!(balance_min_max(&[1, 1, 1], 3), vec![0, 1, 2, 3]);
        assert_eq!(balance_min_max(&[7], 1), vec![0, 1]);
    }

    #[test]
    fn balance_uses_fewest_stages_for_the_optimum() {
        // A third stage cannot beat max=10, so the planner must stop at two
        // (extra stages would add link hops for nothing).
        assert_eq!(balance_min_max(&[10, 1, 1], 3), vec![0, 1, 3]);
        // One dominant group: even with k=4 the optimum is one cut per
        // remaining improvement only.
        let cuts = balance_min_max(&[100, 1, 1, 1], 4);
        assert_eq!(cuts.first(), Some(&0));
        assert_eq!(cuts.last(), Some(&4));
        assert!(cuts.len() <= 3, "no more stages than help: {cuts:?}");
    }

    #[test]
    fn balance_is_monotone_in_stage_count() {
        let costs = [13u64, 2, 8, 41, 5, 5, 19];
        let bottleneck = |k: usize| {
            let cuts = balance_min_max(&costs, k);
            cuts.windows(2)
                .map(|w| costs[w[0]..w[1]].iter().sum::<u64>())
                .max()
                .unwrap()
        };
        let mut last = u64::MAX;
        for k in 1..=costs.len() {
            let b = bottleneck(k);
            assert!(b <= last, "k={k}: {b} > {last}");
            last = b;
        }
        assert_eq!(bottleneck(costs.len()), 41, "fully split → max element");
    }

    #[test]
    fn replicated_shards_are_identical_whole_plans() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let sp = ShardPlan::replicated(&cfg, &net, &w, &plan, 4);
        assert_eq!(sp.used_boards(), 4);
        assert_eq!(sp.link_bytes_per_item(), 0);
        for s in &sp.shards {
            assert_eq!(s.layers, 0..7);
            assert_eq!(s.egress_bytes, 0);
            assert!(s.fits);
        }
        // Per-item cycles decompose the classic plan estimate.
        let est = crate::accel::latency::plan_cycles_estimate(&cfg, &net, &plan);
        assert_eq!(sp.shards[0].item_cycles(), est);
        // Traffic matches the plan accounting.
        let t = crate::accel::latency::plan_traffic_bytes(&cfg, &net, &w, &plan);
        assert_eq!(sp.shards[0].traffic_bytes, t);
    }

    #[test]
    fn pipelined_covers_every_layer_exactly_once() {
        let (cfg, net, w) = setup();
        for plan in [
            FusionPlan::unfused(7),
            FusionPlan::from_group_sizes(7, &[2, 3, 2]).unwrap(),
        ] {
            for boards in 1..=8 {
                let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
                assert!(sp.used_boards() <= boards);
                assert!(sp.used_boards() <= plan.n_groups());
                let mut covered = Vec::new();
                for s in &sp.shards {
                    covered.extend(s.layers.clone());
                }
                assert_eq!(covered, (0..7).collect::<Vec<_>>());
                // Interior stages egress, the final stage does not.
                for (i, s) in sp.shards.iter().enumerate() {
                    if i + 1 == sp.used_boards() {
                        assert_eq!(s.egress_bytes, 0);
                    } else {
                        assert!(s.egress_bytes > 0);
                    }
                }
            }
        }
    }

    #[test]
    fn pipelined_bottleneck_non_increasing_in_boards() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let mut last = u64::MAX;
        for boards in 1..=8 {
            let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
            let b = sp.bottleneck_cycles();
            assert!(b <= last, "boards={boards}: bottleneck rose {b} > {last}");
            last = b;
        }
    }

    #[test]
    fn pipelined_single_board_equals_replicated_single() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::from_group_sizes(7, &[3, 2, 2]).unwrap();
        let p1 = ShardPlan::pipelined(&cfg, &net, &w, &plan, 1);
        let r1 = ShardPlan::replicated(&cfg, &net, &w, &plan, 1);
        assert_eq!(p1.shards[0].item_cycles(), r1.shards[0].item_cycles());
        assert_eq!(p1.shards[0].traffic_bytes, r1.shards[0].traffic_bytes);
        assert_eq!(p1.link_bytes_per_item(), 0);
    }

    #[test]
    fn link_bytes_equal_boundary_volumes() {
        // The conservation law: bytes crossing links = volumes at the board
        // cuts, straight from shape inference — nothing lost or duplicated.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let shapes = net.shapes();
        let wb = cfg.platform.word_bytes;
        let expected: u64 = sp.shards[..sp.used_boards() - 1]
            .iter()
            .map(|s| (shapes[s.layers.end].elems() * wb) as u64)
            .sum();
        assert!(expected > 0);
        assert_eq!(sp.link_bytes_per_item(), expected);
    }

    #[test]
    fn tiny_net_more_boards_than_groups() {
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 2);
        let plan = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, 16);
        assert_eq!(sp.used_boards(), 2, "only 2 groups to host");
        assert_eq!(sp.boards, 16);
    }
}
