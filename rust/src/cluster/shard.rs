//! Shard planner: partition a network's fusion groups across a fleet.
//!
//! Two strategies, mirroring the two classic scale-out shapes:
//!
//! * **Replicated** (data parallel): every board hosts the whole fusion
//!   plan; the fleet load-balances requests. Capacity scales with boards,
//!   per-request latency does not improve.
//! * **Pipelined** (model parallel): each board hosts a contiguous range of
//!   fusion groups; activation volumes cross inter-board links at the cuts.
//!   Throughput is set by the slowest stage, so the planner balances stages
//!   with a min-max DP over per-item group costs.
//!
//! Fleets may be **heterogeneous**: each board carries its own
//! [`AccelConfig`] (resource envelope, clock, DDR share), and the pipelined
//! DP balances stage *time* — cycles at that board's clock — while checking
//! feasibility against that board's own budget. A cut that would overflow a
//! small board is simply not a candidate.
//!
//! Costing reuses the closed-form models the single-board planner already
//! trusts: [`group_cost_estimate`] for cycles,
//! [`crate::accel::latency::group_traffic_bytes`] for local DDR traffic,
//! [`group_resources`] (max over resident groups — units are reused across
//! serialized groups, paper §V) for per-board feasibility.

use std::ops::Range;

use crate::accel::engine::Weights;
use crate::accel::fusion::FusionPlan;
use crate::accel::latency::{group_cost_estimate, GroupCost};
use crate::config::{AccelConfig, FabricSpec, Network, ShardMode, VolShape};
use crate::fpga::ddr::SharedDdr;
use crate::resources::{group_resources, Resources};

use super::link::InterBoardLink;

/// One board's slice of the work, fully costed against *that board's*
/// configuration.
#[derive(Debug, Clone)]
pub struct BoardShard {
    pub board: usize,
    /// Indices into `plan.groups()` hosted by this board.
    pub groups: Range<usize>,
    /// Layer range covered (groups are contiguous, so this is too).
    pub layers: Range<usize>,
    /// Per-batch overhead cycles: Σ fill+drain of resident groups.
    pub overhead_cycles: u64,
    /// Per-item steady-state cycles: Σ steady of resident groups.
    pub steady_cycles: u64,
    /// Per-inference local DDR traffic (bytes) of the resident groups.
    pub traffic_bytes: u64,
    /// Peak resources over resident groups (units reused across groups).
    pub resources: Resources,
    /// Fits *this board's* platform budget.
    pub fits: bool,
    /// Bytes this board forwards to the next stage per inference
    /// (0 for the last stage and for replicated shards).
    pub egress_bytes: u64,
    /// This board's clock in MHz. Cycle counts are only comparable across a
    /// heterogeneous fleet after dividing by this.
    pub freq_mhz: f64,
    /// This board's provisioned off-chip draw, in bytes per *its own* cycle.
    pub ddr_bytes_per_cycle: f64,
}

impl BoardShard {
    /// Cycles this board spends on a batch of `batch` inferences
    /// (excluding contention stall, which depends on fleet state). Measured
    /// in this board's own clock domain.
    pub fn batch_cycles(&self, batch: u64) -> u64 {
        self.overhead_cycles + self.steady_cycles.saturating_mul(batch)
    }

    /// Single-inference cycles on this board (own clock domain).
    pub fn item_cycles(&self) -> u64 {
        self.batch_cycles(1)
    }

    /// Batch service time converted to cycles of a reference clock, so a
    /// heterogeneous fleet can share one simulation timeline.
    pub fn ref_cycles(&self, batch: u64, ref_freq_mhz: f64) -> u64 {
        (self.batch_cycles(batch) as f64 * ref_freq_mhz / self.freq_mhz).round() as u64
    }

    /// Single-inference service time in microseconds at this board's clock.
    pub fn item_us(&self) -> f64 {
        self.item_cycles() as f64 / self.freq_mhz
    }

    /// Full batch service time on the shared reference timeline: compute at
    /// this board's clock plus the contention stall of its off-chip phases
    /// under the fleet's aggregate `demand` (bytes per reference cycle).
    /// Both simulators price service through this one method so the static
    /// baseline and the re-shard controller can never disagree on it.
    pub fn service_cycles(
        &self,
        batch: u64,
        ref_freq_mhz: f64,
        shared: &SharedDdr,
        demand: f64,
    ) -> u64 {
        self.ref_cycles(batch, ref_freq_mhz)
            + shared.stall_cycles_of(
                self.traffic_bytes * batch,
                self.ddr_bytes_per_cycle * self.freq_mhz / ref_freq_mhz,
                demand,
            )
    }

    /// [`Self::service_cycles`] on a board running at a fraction of its
    /// compute capacity (a `ComputeDegrade` fault: lost columns / DSP
    /// slices, not a slower clock). Only the compute phase stretches by
    /// `1 / capacity` — the off-chip phase is bandwidth-bound, not
    /// column-bound, so its stall keeps the healthy arithmetic. This is
    /// what distinguishes a brownout from a `ClockDerate`, which stretches
    /// both phases. `capacity == 1.0` is bit-exactly
    /// [`Self::service_cycles`].
    pub fn service_cycles_capped(
        &self,
        batch: u64,
        ref_freq_mhz: f64,
        shared: &SharedDdr,
        demand: f64,
        capacity: f64,
    ) -> u64 {
        let compute = self.ref_cycles(batch, ref_freq_mhz);
        let compute = if capacity == 1.0 {
            compute
        } else {
            (compute as f64 / capacity).ceil() as u64
        };
        compute
            + shared.stall_cycles_of(
                self.traffic_bytes * batch,
                self.ddr_bytes_per_cycle * self.freq_mhz / ref_freq_mhz,
                demand,
            )
    }
}

/// A fusion plan distributed across a fleet.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub mode: ShardMode,
    /// Boards provisioned (pipelined mode may use fewer than provisioned
    /// when the plan has fewer groups).
    pub boards: usize,
    pub plan: FusionPlan,
    /// One entry per *used* board, in **stage order**. Single-tenant plans
    /// use a board prefix (`shards[i].board == i`); multi-tenant placements
    /// ([`place_tenants`]) may skip boards another tenant filled *and* may
    /// permute pipelined stages off rack order entirely
    /// ([`place_tenants_biased`] maps stage *s* to the *s*-th
    /// emptiest/coolest board), so consumers must index boards through
    /// `BoardShard::board`, not the shard position.
    pub shards: Vec<BoardShard>,
}

impl ShardPlan {
    /// Data-parallel sharding: the whole plan on every board of a
    /// homogeneous fleet.
    pub fn replicated(
        cfg: &AccelConfig,
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
        boards: usize,
    ) -> ShardPlan {
        assert!(boards >= 1);
        ShardPlan::replicated_fleet(&vec![cfg.clone(); boards], net, weights, plan)
    }

    /// Data-parallel sharding over an explicit (possibly heterogeneous)
    /// fleet: the whole plan on every board, costed per board.
    pub fn replicated_fleet(
        fleet: &[AccelConfig],
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
    ) -> ShardPlan {
        assert!(!fleet.is_empty());
        let ctx = FleetCtx::new(fleet, net, weights, plan);
        let shards = (0..fleet.len())
            .map(|b| ctx.cost_range(0..plan.n_groups(), b))
            .collect();
        ShardPlan {
            mode: ShardMode::Replicated,
            boards: fleet.len(),
            plan: plan.clone(),
            shards,
        }
    }

    /// Model-parallel sharding over a homogeneous fleet of `boards` copies
    /// of `cfg`.
    pub fn pipelined(
        cfg: &AccelConfig,
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
        boards: usize,
    ) -> ShardPlan {
        assert!(boards >= 1);
        ShardPlan::pipelined_fleet(&vec![cfg.clone(); boards], net, weights, plan)
    }

    /// Model-parallel sharding over an explicit fleet: balance contiguous
    /// group ranges over at most `fleet.len()` stages (stage *i* runs on
    /// board *i*, fleet order), minimizing the slowest stage's per-item
    /// *time* at that board's clock. Ranges that overflow a board's own
    /// resource budget are not candidates; if no feasible partition exists
    /// at any stage count, the planner falls back to the unconstrained
    /// time-balanced partition so callers can inspect exactly which stage
    /// fails (its `fits` flag is false, and `plan_fleet` surfaces the
    /// error).
    pub fn pipelined_fleet(
        fleet: &[AccelConfig],
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
    ) -> ShardPlan {
        assert!(!fleet.is_empty());
        let ctx = FleetCtx::new(fleet, net, weights, plan);
        let n = plan.n_groups();
        let k = fleet.len().min(n);
        let totals: Vec<Vec<u64>> = ctx
            .costs
            .iter()
            .map(|per_board| per_board.iter().map(|c| c.total()).collect())
            .collect();
        let freqs: Vec<f64> = fleet.iter().map(|c| c.platform.freq_mhz).collect();
        let feasible = |b: usize, r: Range<usize>| ctx.range_resources(b, r).fits(&fleet[b]);
        let always = |_: usize, _: Range<usize>| true;
        let cuts = balance_fleet(&totals, &freqs, &feasible, k)
            .or_else(|| balance_fleet(&totals, &freqs, &always, k))
            .expect("a non-empty partition always exists unconstrained");
        let shards: Vec<BoardShard> = cuts
            .windows(2)
            .enumerate()
            .map(|(b, w)| ctx.cost_range(w[0]..w[1], b))
            .collect();
        ShardPlan {
            mode: ShardMode::Pipelined,
            boards: fleet.len(),
            plan: plan.clone(),
            shards,
        }
    }

    /// Model-parallel sharding with caller-chosen cut points (the
    /// `[0, …, n_groups]` form [`balance_min_max`] returns). Used to cost a
    /// *naive* partition — e.g. cuts balanced under a homogeneous-fleet
    /// assumption — on a heterogeneous fleet, which is exactly the situation
    /// the re-shard controller exists to repair.
    pub fn pipelined_fleet_with_cuts(
        fleet: &[AccelConfig],
        net: &Network,
        weights: &Weights,
        plan: &FusionPlan,
        cuts: &[usize],
    ) -> ShardPlan {
        assert!(!fleet.is_empty());
        assert!(cuts.len() >= 2, "cuts must be [0, …, n_groups]");
        assert_eq!(cuts[0], 0);
        assert_eq!(*cuts.last().unwrap(), plan.n_groups());
        assert!(cuts.windows(2).all(|w| w[0] < w[1]), "cuts must ascend");
        assert!(
            cuts.len() - 1 <= fleet.len(),
            "more stages than boards in the fleet"
        );
        let ctx = FleetCtx::new(fleet, net, weights, plan);
        let shards: Vec<BoardShard> = cuts
            .windows(2)
            .enumerate()
            .map(|(b, w)| ctx.cost_range(w[0]..w[1], b))
            .collect();
        ShardPlan {
            mode: ShardMode::Pipelined,
            boards: fleet.len(),
            plan: plan.clone(),
            shards,
        }
    }

    /// Boards actually hosting work.
    pub fn used_boards(&self) -> usize {
        self.shards.len()
    }

    /// Provisioned boards left without a stage (pipelined plans with fewer
    /// groups than boards). 0 for replicated plans.
    pub fn idle_boards(&self) -> usize {
        self.boards.saturating_sub(self.used_boards())
    }

    /// Bytes one inference moves across inter-board links (Σ egress of all
    /// non-final stages). 0 in replicated mode.
    pub fn link_bytes_per_item(&self) -> u64 {
        self.shards.iter().map(|s| s.egress_bytes).sum()
    }

    /// Every used board fits its own platform budget.
    pub fn fits(&self) -> bool {
        self.shards.iter().all(|s| s.fits)
    }

    /// Per-item cycles of the slowest stage (pipeline bottleneck). Only
    /// meaningful on homogeneous fleets, where all boards share one clock;
    /// heterogeneous callers want [`ShardPlan::bottleneck_us`].
    pub fn bottleneck_cycles(&self) -> u64 {
        self.shards.iter().map(|s| s.item_cycles()).max().unwrap_or(0)
    }

    /// Per-item wall time of the slowest stage in microseconds, comparable
    /// across clock domains.
    pub fn bottleneck_us(&self) -> f64 {
        self.shards.iter().map(|s| s.item_us()).fold(0.0, f64::max)
    }

    /// Short human-readable identity of this shard — mode plus layer cuts —
    /// used by the re-shard controller to detect "the plan actually
    /// changed" and by reports to name plans.
    pub fn label(&self) -> String {
        match self.mode {
            ShardMode::Replicated => format!("replicated:{}", self.used_boards()),
            ShardMode::Pipelined => {
                let cuts: Vec<String> = self
                    .shards
                    .iter()
                    .map(|s| format!("{}..{}", s.layers.start, s.layers.end))
                    .collect();
                format!("pipelined[{}]", cuts.join("|"))
            }
        }
    }

    /// Crude steady-state capacity estimate in items/second at full batch
    /// `max_batch`, used by the re-shard controller to rank candidate plans
    /// (DDR contention excluded — it slows candidates roughly alike).
    /// Replicated: sum of per-board batch rates. Pipelined: the bottleneck
    /// stage, where a stage is either a board's compute or a link
    /// serializing that cut's boundary volume (`ref_freq_mhz` converts link
    /// cycles to time).
    pub fn capacity_rps(
        &self,
        max_batch: usize,
        link: &InterBoardLink,
        ref_freq_mhz: f64,
    ) -> f64 {
        let b = max_batch.max(1) as u64;
        match self.mode {
            ShardMode::Replicated => self
                .shards
                .iter()
                .map(|s| b as f64 / (s.batch_cycles(b) as f64 / (s.freq_mhz * 1e6)))
                .sum(),
            ShardMode::Pipelined => {
                let mut worst_s = 0.0f64;
                for s in &self.shards {
                    worst_s = worst_s.max(s.batch_cycles(b) as f64 / (s.freq_mhz * 1e6));
                }
                for s in &self.shards[..self.used_boards().saturating_sub(1)] {
                    let cyc = link.transfer_cycles(s.egress_bytes * b);
                    worst_s = worst_s.max(cyc as f64 / (ref_freq_mhz * 1e6));
                }
                b as f64 / worst_s
            }
        }
    }
}

/// One tenant's workload, as the fleet-wide placement planner sees it.
#[derive(Debug, Clone, Copy)]
pub struct TenantWorkload<'a> {
    pub name: &'a str,
    pub net: &'a Network,
    pub weights: &'a Weights,
    pub plan: &'a FusionPlan,
    pub mode: ShardMode,
    /// Priority class (larger preempts smaller); also the placement order —
    /// higher-priority tenants pack first and get first pick of the fabric.
    pub priority: u8,
    /// Replicated mode: cap on the number of replicas (None = every board
    /// with room). Ignored for pipelined tenants.
    pub replicas: Option<usize>,
}

/// Pack several tenants' shard plans onto one shared fleet.
///
/// Placement runs in priority order (descending, ties by tenant index): each
/// tenant plans against the fabric *left over* by the tenants placed before
/// it. Feasibility is joint: a board instantiates the fixed shell
/// ([`crate::resources::shell_resources`]: AXI/DDR interfacing, stream
/// routing, control) once, then stacks each resident's incremental fabric
/// (envelope − shell) — so co-residency is possible exactly when the
/// incremental engines fit beside one shared shell.
///
/// * **Replicated** tenants land on up to `replicas` boards with room
///   (emptier boards first, then lower index — spreading before stacking);
///   they need at least one, and may skip boards another tenant filled.
/// * **Pipelined** tenants run the heterogeneity-aware stage DP with the
///   joint-residency feasibility predicate: a stage is only a candidate on
///   a board whose remaining budget covers it. The DP is offered a board
///   *permutation* — emptiest boards first (fewest residents, then lowest
///   index; an explicit load bias first under
///   [`place_tenants_biased`]) — so stage *i* maps to the *i*-th emptiest
///   board instead of being pinned to rack slot *i*: a pipelined tenant now
///   routes around a board prefix an earlier tenant filled instead of
///   failing placement while later boards sit free.
///
/// The returned plans are in the *input* tenant order, with
/// [`BoardShard::board`] indexing the shared fleet (multi-tenant plans may
/// skip boards, so consumers must go through that field). Off-chip
/// co-residency is not a placement constraint — every resident shard keeps
/// its provisioned DDR draw and the simulator bills the aggregate through
/// the [`SharedDdr`] contention model (oversubscription stretches everyone;
/// it never rejects a placement).
pub fn place_tenants(
    fleet: &[AccelConfig],
    tenants: &[TenantWorkload],
) -> Result<Vec<ShardPlan>, String> {
    place_tenants_biased(fleet, tenants, &vec![0u64; fleet.len()])
}

/// [`place_tenants`] with an explicit per-board load bias: boards with a
/// smaller `bias` are preferred (then fewer residents, then lower index)
/// both for spreading replicated tenants and as the stage order offered to
/// the pipelined DP. The unified control plane passes each board's busy
/// cycles over the trigger window, so a mid-run re-placement steers new
/// replicas and stages toward the boards the load actually left cool. A
/// zero bias reduces to the static emptiest-first order.
pub fn place_tenants_biased(
    fleet: &[AccelConfig],
    tenants: &[TenantWorkload],
    bias: &[u64],
) -> Result<Vec<ShardPlan>, String> {
    place_tenants_alive(fleet, tenants, bias, &vec![true; fleet.len()])
}

/// [`place_tenants_biased`] restricted to the boards marked alive — the
/// fault-tolerant placement the chaos control plane re-plans with after a
/// [`crate::config::FaultEvent::BoardDown`]. Dead boards are excluded from
/// the replicated candidate set and from the permutation offered to the
/// pipelined stage DP, so an emergency re-shard routes every tenant onto
/// surviving fabric. With every board alive this is exactly
/// [`place_tenants_biased`] (same candidate order, same plans).
pub fn place_tenants_alive(
    fleet: &[AccelConfig],
    tenants: &[TenantWorkload],
    bias: &[u64],
    alive: &[bool],
) -> Result<Vec<ShardPlan>, String> {
    place_tenants_capacity(fleet, tenants, bias, alive, &vec![1.0; fleet.len()])
}

/// [`place_tenants_alive`] with a per-board effective-capacity fraction —
/// the brownout-aware placement the control plane re-plans with while a
/// [`crate::config::FaultEvent::ComputeDegrade`] is active. A board at
/// `cap[b] < 1.0` is neither healthy nor dead: it stays in the candidate
/// set but ranks *behind* every less-degraded board for replicated
/// spreading, and the pipelined stage DP sees its compute throughput
/// scaled by `cap[b]` — so stage boundaries shift work off the brownout
/// board in proportion to what it lost. With every entry at 1.0 this is
/// exactly [`place_tenants_alive`] (same candidate order, same plans).
pub fn place_tenants_capacity(
    fleet: &[AccelConfig],
    tenants: &[TenantWorkload],
    bias: &[u64],
    alive: &[bool],
    cap: &[f64],
) -> Result<Vec<ShardPlan>, String> {
    place_tenants_capacity_fabric(fleet, tenants, bias, alive, cap, None)
}

/// [`place_tenants_capacity`] made topology-aware: when an interconnect
/// [`FabricSpec`] is armed, placement optimizes for *where boards sit*,
/// not just how full they are.
///
/// * **Pipelined** tenants try each rack's alive boards *alone* first
///   (racks ordered by their coolest member under the usual degradation /
///   bias / residency key) — a chain that fits inside one rack never pays
///   uplink or ring hops on its boundary traffic. Only when no single rack
///   can host the whole chain does the planner fall back to the global
///   cross-rack permutation.
/// * **Replicated** tenants spread replicas across racks as failure
///   domains: candidates are picked greedily by
///   `(degradation, replicas-already-in-rack, bias, residents, index)`, so
///   a correlated [`crate::config::FaultEvent::RackDown`] takes out at most
///   `ceil(replicas / racks)` of them instead of the whole set.
///
/// With `fabric: None` both arms run the exact pre-fabric code path —
/// same candidate order, same plans — which is the byte-compat contract
/// [`place_tenants`] / [`place_tenants_biased`] / [`place_tenants_alive`]
/// inherit by delegation.
pub fn place_tenants_capacity_fabric(
    fleet: &[AccelConfig],
    tenants: &[TenantWorkload],
    bias: &[u64],
    alive: &[bool],
    cap: &[f64],
    fabric: Option<&FabricSpec>,
) -> Result<Vec<ShardPlan>, String> {
    assert!(!fleet.is_empty());
    let nb = fleet.len();
    assert_eq!(bias.len(), nb, "one bias entry per board");
    assert_eq!(alive.len(), nb, "one liveness entry per board");
    assert_eq!(cap.len(), nb, "one capacity entry per board");
    assert!(
        cap.iter().all(|&c| c > 0.0 && c <= 1.0),
        "capacity fractions must be in (0, 1]"
    );
    if !alive.iter().any(|&a| a) {
        return Err("placement: no board is alive".into());
    }
    // Degradation rank ahead of the load bias: healthy boards first, then
    // the least-degraded. Constant (so order-preserving) at all-1.0 — the
    // identity the committed fixtures lean on.
    let degr = |b: usize| (1e6 / cap[b]).round() as u64;
    let shell = crate::resources::shell_resources();
    // Incremental fabric already resident per board, and resident count
    // (for the spread-before-stack ordering).
    let mut used = vec![Resources::default(); nb];
    let mut residents = vec![0usize; nb];
    let joint_fits = |used: &[Resources], extra: Resources, b: usize| {
        let mut joint = shell;
        joint.add(used[b]);
        joint.add(extra.saturating_sub(shell));
        joint.fits(&fleet[b])
    };

    let mut order: Vec<usize> = (0..tenants.len()).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(tenants[i].priority), i));

    let mut plans: Vec<Option<ShardPlan>> = vec![None; tenants.len()];
    for ti in order {
        let t = &tenants[ti];
        let ctx = FleetCtx::new(fleet, t.net, t.weights, t.plan);
        let n = t.plan.n_groups();
        let shards: Vec<BoardShard> = match t.mode {
            ShardMode::Replicated => {
                let mut fitting: Vec<usize> = (0..nb)
                    .filter(|&b| alive[b] && joint_fits(&used, ctx.range_resources(b, 0..n), b))
                    .collect();
                let target = t.replicas.unwrap_or(nb).max(1);
                let mut chosen = match fabric {
                    None => {
                        fitting.sort_by_key(|&b| (degr(b), bias[b], residents[b], b));
                        fitting.truncate(target);
                        fitting
                    }
                    Some(fb) => {
                        // Failure-domain spreading: each pick charges its
                        // rack, so the next equally-cool candidate in a
                        // *different* rack wins — replicas land round-robin
                        // across racks before stacking within one.
                        let mut rack_load = vec![0usize; fb.n_racks(nb)];
                        let mut chosen = Vec::with_capacity(target.min(fitting.len()));
                        while chosen.len() < target && !fitting.is_empty() {
                            let (i, _) = fitting
                                .iter()
                                .enumerate()
                                .min_by_key(|&(_, &b)| {
                                    (degr(b), rack_load[fb.rack_of(b)], bias[b], residents[b], b)
                                })
                                .expect("non-empty");
                            let b = fitting.swap_remove(i);
                            rack_load[fb.rack_of(b)] += 1;
                            chosen.push(b);
                        }
                        chosen
                    }
                };
                chosen.sort_unstable();
                if chosen.is_empty() {
                    return Err(format!(
                        "tenant '{}': no board has room left for a replica",
                        t.name
                    ));
                }
                chosen.into_iter().map(|b| ctx.cost_range(0..n, b)).collect()
            }
            ShardMode::Pipelined => {
                // Free placement: the DP sees boards emptiest-first (bias,
                // residents, index), so stage s runs on perm[s] — an
                // occupied or hot rack prefix no longer blocks the chain.
                // Dead boards never enter the permutation, so an emergency
                // re-plan restores the chain on surviving fabric only.
                let mut perm: Vec<usize> = (0..nb).filter(|&b| alive[b]).collect();
                perm.sort_by_key(|&b| (degr(b), bias[b], residents[b], b));
                let solve = |perm: &[usize]| -> Option<Vec<BoardShard>> {
                    let k = perm.len().min(n);
                    let totals: Vec<Vec<u64>> = perm
                        .iter()
                        .map(|&b| ctx.costs[b].iter().map(|c| c.total()).collect())
                        .collect();
                    // A brownout board looks proportionally slower to the
                    // time-balancing DP (× 1.0 is bit-exact for healthy
                    // boards).
                    let freqs: Vec<f64> = perm
                        .iter()
                        .map(|&b| fleet[b].platform.freq_mhz * cap[b])
                        .collect();
                    let feasible = |s: usize, r: Range<usize>| {
                        joint_fits(&used, ctx.range_resources(perm[s], r), perm[s])
                    };
                    let cuts = balance_fleet(&totals, &freqs, &feasible, k)?;
                    Some(
                        cuts.windows(2)
                            .enumerate()
                            .map(|(s, w)| ctx.cost_range(w[0]..w[1], perm[s]))
                            .collect(),
                    )
                };
                // Locality first: a chain whose stages share a rack pays
                // only that rack's intra segment per boundary. Each rack's
                // alive boards are offered alone (coolest rack first);
                // only when no rack can host the whole chain does the
                // cross-rack permutation run.
                let rack_local = fabric.and_then(|fb| {
                    let mut racks: Vec<Vec<usize>> = vec![Vec::new(); fb.n_racks(nb)];
                    for &b in &perm {
                        racks[fb.rack_of(b)].push(b);
                    }
                    let mut order: Vec<usize> =
                        (0..racks.len()).filter(|&r| !racks[r].is_empty()).collect();
                    order.sort_by_key(|&r| {
                        racks[r]
                            .iter()
                            .map(|&b| (degr(b), bias[b], residents[b], b))
                            .min()
                            .expect("non-empty rack")
                    });
                    order.into_iter().find_map(|r| solve(&racks[r]))
                });
                match rack_local {
                    Some(shards) => shards,
                    None => solve(&perm).ok_or_else(|| {
                        format!(
                            "tenant '{}': no pipelined partition fits the remaining fabric",
                            t.name
                        )
                    })?,
                }
            }
        };
        for s in &shards {
            used[s.board].add(s.resources.saturating_sub(shell));
            residents[s.board] += 1;
        }
        plans[ti] = Some(ShardPlan {
            mode: t.mode,
            boards: nb,
            plan: t.plan.clone(),
            shards,
        });
    }
    Ok(plans.into_iter().map(|p| p.expect("all placed")).collect())
}

/// Per-plan costing context: shapes computed once; group costs and resource
/// envelopes computed per *board* so heterogeneous clocks, DDR shares and
/// budgets each see their own numbers.
struct FleetCtx<'a> {
    boards: &'a [AccelConfig],
    net: &'a Network,
    groups: Vec<Range<usize>>,
    shapes: Vec<VolShape>,
    /// `costs[b][g]`: group `g` costed with board `b`'s config.
    costs: Vec<Vec<GroupCost>>,
    /// `res[b][g]`: group `g`'s resource envelope under board `b`'s config.
    res: Vec<Vec<Resources>>,
    /// `layer_bytes[b][l]`: layer `l`'s weight bytes at board `b`'s word
    /// size — derived once per distinct config instead of re-walking the
    /// filter banks for every costed range.
    layer_bytes: Vec<Vec<u64>>,
}

impl<'a> FleetCtx<'a> {
    fn new(
        boards: &'a [AccelConfig],
        net: &'a Network,
        weights: &'a Weights,
        plan: &FusionPlan,
    ) -> FleetCtx<'a> {
        let groups = plan.groups();
        // Fleets are mostly a few generations repeated many times (often
        // one): cost each distinct config once and share the tables.
        let mut costs: Vec<Vec<GroupCost>> = Vec::with_capacity(boards.len());
        let mut res: Vec<Vec<Resources>> = Vec::with_capacity(boards.len());
        let mut layer_bytes: Vec<Vec<u64>> = Vec::with_capacity(boards.len());
        for (b, cfg) in boards.iter().enumerate() {
            if let Some(r) = boards[..b].iter().position(|c| c == cfg) {
                let (c, e, w) = (costs[r].clone(), res[r].clone(), layer_bytes[r].clone());
                costs.push(c);
                res.push(e);
                layer_bytes.push(w);
            } else {
                costs.push(
                    groups
                        .iter()
                        .map(|g| group_cost_estimate(cfg, net, g.clone()))
                        .collect(),
                );
                res.push(
                    groups
                        .iter()
                        .map(|g| group_resources(cfg, net, g.clone()))
                        .collect(),
                );
                layer_bytes.push(weights.per_layer_bytes(cfg.platform.word_bytes));
            }
        }
        FleetCtx {
            boards,
            net,
            groups,
            shapes: net.shapes(),
            costs,
            res,
            layer_bytes,
        }
    }

    /// Peak resources of a contiguous group range on board `b` (units are
    /// reused across serialized groups, so this is a max, not a sum).
    fn range_resources(&self, b: usize, group_range: Range<usize>) -> Resources {
        self.res[b][group_range]
            .iter()
            .fold(Resources::default(), |acc, r| acc.max(*r))
    }

    /// Cost one contiguous range of fusion groups as a shard on board `b`.
    fn cost_range(&self, group_range: Range<usize>, b: usize) -> BoardShard {
        assert!(!group_range.is_empty());
        let cfg = &self.boards[b];
        let wb = cfg.platform.word_bytes;
        let layer_lo = self.groups[group_range.start].start;
        let layer_hi = self.groups[group_range.end - 1].end;
        let mut overhead = 0u64;
        let mut steady = 0u64;
        let mut traffic = 0u64;
        for (g, c) in self.groups[group_range.clone()]
            .iter()
            .zip(&self.costs[b][group_range.clone()])
        {
            overhead += c.fill + c.drain;
            steady += c.steady;
            let group_weights: u64 = self.layer_bytes[b][g.clone()].iter().sum();
            traffic += (self.shapes[g.start].elems() * wb) as u64
                + (self.shapes[g.end].elems() * wb) as u64
                + group_weights;
        }
        let res = self.range_resources(b, group_range.clone());
        // Egress: the output volume of the shard's last group, unless it is
        // the network's final output (which returns to the client, not a
        // peer board).
        let egress_bytes = if layer_hi == self.net.layers.len() {
            0
        } else {
            (self.shapes[layer_hi].elems() * wb) as u64
        };
        let fits = res.fits(cfg);
        BoardShard {
            board: b,
            groups: group_range,
            layers: layer_lo..layer_hi,
            overhead_cycles: overhead,
            steady_cycles: steady,
            traffic_bytes: traffic,
            resources: res,
            fits,
            egress_bytes,
            freq_mhz: cfg.platform.freq_mhz,
            ddr_bytes_per_cycle: cfg.platform.ddr_bytes_per_cycle,
        }
    }
}

/// Partition `costs` into at most `k` contiguous non-empty segments
/// minimizing the maximum segment sum, using the *fewest* segments that
/// achieve the optimum (extra pipeline stages add link hops without raising
/// throughput). Returns the cut points `[0, …, costs.len()]`. Classic
/// O(k·n²) DP — n is the number of fusion groups (≤ 20), k the board count.
///
/// This is the *homogeneous* form (every stage costs the same everywhere);
/// heterogeneous fleets go through the stage-aware DP inside
/// [`ShardPlan::pipelined_fleet`]. Public so callers can build the "naive
/// cuts" a homogeneity-assuming planner would pick and feed them to
/// [`ShardPlan::pipelined_fleet_with_cuts`].
pub fn balance_min_max(costs: &[u64], k: usize) -> Vec<usize> {
    let n = costs.len();
    assert!(n >= 1 && (1..=n).contains(&k));
    // prefix[i] = Σ costs[..i]
    let mut prefix = vec![0u64; n + 1];
    for i in 0..n {
        prefix[i + 1] = prefix[i] + costs[i];
    }
    let seg = |j: usize, i: usize| prefix[i] - prefix[j];
    // dp[s][i]: best max-segment-sum splitting costs[..i] into s segments.
    let mut dp = vec![vec![u64::MAX; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    for i in 1..=n {
        dp[1][i] = seg(0, i);
    }
    for s in 2..=k {
        for i in s..=n {
            for j in (s - 1)..i {
                let v = dp[s - 1][j].max(seg(j, i));
                if v < dp[s][i] {
                    dp[s][i] = v;
                    cut[s][i] = j;
                }
            }
        }
    }
    let best = (1..=k).map(|s| dp[s][n]).min().unwrap();
    let stages = (1..=k).find(|&s| dp[s][n] == best).unwrap();
    let mut bounds = vec![n];
    let mut i = n;
    for s in (2..=stages).rev() {
        i = cut[s][i];
        bounds.push(i);
    }
    bounds.push(0);
    bounds.reverse();
    bounds
}

/// Heterogeneity-aware min-max partition: split groups `0..n` into at most
/// `k` contiguous non-empty segments where segment `s` runs on board `s`
/// (fleet order), minimizing the maximum segment *time*
/// `Σ cycles(board, group) / freq(board)`. A segment is only a candidate if
/// `feasible(board, range)` holds — that board's own resource check. Uses
/// the fewest stages achieving the optimum. Returns `None` when no feasible
/// partition exists at any stage count.
fn balance_fleet(
    per_board_costs: &[Vec<u64>],
    freqs: &[f64],
    feasible: &dyn Fn(usize, Range<usize>) -> bool,
    k: usize,
) -> Option<Vec<usize>> {
    let n = per_board_costs[0].len();
    assert!(n >= 1 && (1..=n).contains(&k));
    assert!(per_board_costs.len() >= k && freqs.len() >= k);
    // Per-board prefix sums of group cycles.
    let prefix: Vec<Vec<u64>> = per_board_costs
        .iter()
        .map(|costs| {
            let mut p = vec![0u64; n + 1];
            for i in 0..n {
                p[i + 1] = p[i] + costs[i];
            }
            p
        })
        .collect();
    // Stage time in µs: segment cycles on board b at board b's clock.
    let time = |b: usize, j: usize, i: usize| (prefix[b][i] - prefix[b][j]) as f64 / freqs[b];

    let mut dp = vec![vec![f64::INFINITY; n + 1]; k + 1];
    let mut cut = vec![vec![0usize; n + 1]; k + 1];
    for i in 1..=n {
        if feasible(0, 0..i) {
            dp[1][i] = time(0, 0, i);
        }
    }
    for s in 2..=k {
        let b = s - 1; // stage s−1 runs on board s−1
        for i in s..=n {
            for j in (s - 1)..i {
                if dp[s - 1][j].is_finite() && feasible(b, j..i) {
                    let v = dp[s - 1][j].max(time(b, j, i));
                    if v < dp[s][i] {
                        dp[s][i] = v;
                        cut[s][i] = j;
                    }
                }
            }
        }
    }
    let best = (1..=k).map(|s| dp[s][n]).fold(f64::INFINITY, f64::min);
    if !best.is_finite() {
        return None;
    }
    let stages = (1..=k).find(|&s| dp[s][n] == best).unwrap();
    let mut bounds = vec![n];
    let mut i = n;
    for s in (2..=stages).rev() {
        i = cut[s][i];
        bounds.push(i);
    }
    bounds.push(0);
    bounds.reverse();
    Some(bounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{tiny_vgg, vgg16_prefix, Platform};

    fn setup() -> (AccelConfig, Network, Weights) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        (AccelConfig::paper_default(), net, w)
    }

    /// An older, slower board generation: lower clock, thinner DDR.
    fn slow_gen() -> AccelConfig {
        AccelConfig {
            platform: Platform::virtex7_older_gen(),
            ..AccelConfig::paper_default()
        }
    }

    #[test]
    fn balance_min_max_basic() {
        assert_eq!(balance_min_max(&[5, 5, 5, 5], 2), vec![0, 2, 4]);
        assert_eq!(balance_min_max(&[9, 1, 1, 1], 2), vec![0, 1, 4]);
        assert_eq!(balance_min_max(&[1, 1, 1], 3), vec![0, 1, 2, 3]);
        assert_eq!(balance_min_max(&[7], 1), vec![0, 1]);
    }

    #[test]
    fn balance_uses_fewest_stages_for_the_optimum() {
        // A third stage cannot beat max=10, so the planner must stop at two
        // (extra stages would add link hops for nothing).
        assert_eq!(balance_min_max(&[10, 1, 1], 3), vec![0, 1, 3]);
        // One dominant group: even with k=4 the optimum is one cut per
        // remaining improvement only.
        let cuts = balance_min_max(&[100, 1, 1, 1], 4);
        assert_eq!(cuts.first(), Some(&0));
        assert_eq!(cuts.last(), Some(&4));
        assert!(cuts.len() <= 3, "no more stages than help: {cuts:?}");
    }

    #[test]
    fn balance_is_monotone_in_stage_count() {
        let costs = [13u64, 2, 8, 41, 5, 5, 19];
        let bottleneck = |k: usize| {
            let cuts = balance_min_max(&costs, k);
            cuts.windows(2)
                .map(|w| costs[w[0]..w[1]].iter().sum::<u64>())
                .max()
                .unwrap()
        };
        let mut last = u64::MAX;
        for k in 1..=costs.len() {
            let b = bottleneck(k);
            assert!(b <= last, "k={k}: {b} > {last}");
            last = b;
        }
        assert_eq!(bottleneck(costs.len()), 41, "fully split → max element");
    }

    #[test]
    fn balance_fleet_uniform_matches_homogeneous() {
        // Same costs on every board at one clock → the hetero DP must pick
        // the same cuts as the classic min-max partition.
        let costs = vec![13u64, 2, 8, 41, 5, 5, 19];
        for k in 1..=4usize {
            let per_board = vec![costs.clone(); k];
            let freqs = vec![120.0; k];
            let always = |_: usize, _: Range<usize>| true;
            let cuts = balance_fleet(&per_board, &freqs, &always, k).unwrap();
            assert_eq!(cuts, balance_min_max(&costs, k), "k={k}");
        }
    }

    #[test]
    fn balance_fleet_gives_slow_boards_less_work() {
        // Two boards, identical cycle costs, but board 1 runs at half the
        // clock: the cut must shift work onto board 0.
        let costs = vec![vec![10u64, 10, 10, 10], vec![10u64, 10, 10, 10]];
        let freqs = vec![100.0, 50.0];
        let always = |_: usize, _: Range<usize>| true;
        let cuts = balance_fleet(&costs, &freqs, &always, 2).unwrap();
        // Balanced in *time*: 3 groups at 100 MHz (0.3 µs) vs 1 at 50 MHz
        // (0.2 µs) beats 2/2 (0.2 vs 0.4 µs).
        assert_eq!(cuts, vec![0, 3, 4]);
    }

    #[test]
    fn balance_fleet_respects_feasibility() {
        // Board 1 can only host single groups: any wider range is
        // infeasible there, so the DP must cut accordingly even though a
        // 2/2 split would balance better.
        let costs = vec![vec![10u64, 10, 10, 10], vec![10u64, 10, 10, 10]];
        let freqs = vec![100.0, 100.0];
        let feas =
            |b: usize, r: Range<usize>| b != 1 || r.len() == 1;
        let cuts = balance_fleet(&costs, &freqs, &feas, 2).unwrap();
        assert_eq!(cuts, vec![0, 3, 4], "board 1 limited to one group");
        // And when nothing is feasible at all, the DP reports it.
        let never = |_: usize, _: Range<usize>| false;
        assert!(balance_fleet(&costs, &freqs, &never, 2).is_none());
    }

    #[test]
    fn replicated_shards_are_identical_whole_plans() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let sp = ShardPlan::replicated(&cfg, &net, &w, &plan, 4);
        assert_eq!(sp.used_boards(), 4);
        assert_eq!(sp.idle_boards(), 0);
        assert_eq!(sp.link_bytes_per_item(), 0);
        for s in &sp.shards {
            assert_eq!(s.layers, 0..7);
            assert_eq!(s.egress_bytes, 0);
            assert_eq!(s.freq_mhz, cfg.platform.freq_mhz);
            assert!(s.fits);
        }
        // Per-item cycles decompose the classic plan estimate.
        let est = crate::accel::latency::plan_cycles_estimate(&cfg, &net, &plan);
        assert_eq!(sp.shards[0].item_cycles(), est);
        // Traffic matches the plan accounting.
        let t = crate::accel::latency::plan_traffic_bytes(&cfg, &net, &w, &plan);
        assert_eq!(sp.shards[0].traffic_bytes, t);
    }

    #[test]
    fn pipelined_covers_every_layer_exactly_once() {
        let (cfg, net, w) = setup();
        for plan in [
            FusionPlan::unfused(7),
            FusionPlan::from_group_sizes(7, &[2, 3, 2]).unwrap(),
        ] {
            for boards in 1..=8 {
                let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
                assert!(sp.used_boards() <= boards);
                assert!(sp.used_boards() <= plan.n_groups());
                let mut covered = Vec::new();
                for s in &sp.shards {
                    covered.extend(s.layers.clone());
                }
                assert_eq!(covered, (0..7).collect::<Vec<_>>());
                // Interior stages egress, the final stage does not.
                for (i, s) in sp.shards.iter().enumerate() {
                    if i + 1 == sp.used_boards() {
                        assert_eq!(s.egress_bytes, 0);
                    } else {
                        assert!(s.egress_bytes > 0);
                    }
                }
            }
        }
    }

    #[test]
    fn pipelined_bottleneck_non_increasing_in_boards() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let mut last = u64::MAX;
        for boards in 1..=8 {
            let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, boards);
            let b = sp.bottleneck_cycles();
            assert!(b <= last, "boards={boards}: bottleneck rose {b} > {last}");
            last = b;
        }
    }

    #[test]
    fn pipelined_single_board_equals_replicated_single() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::from_group_sizes(7, &[3, 2, 2]).unwrap();
        let p1 = ShardPlan::pipelined(&cfg, &net, &w, &plan, 1);
        let r1 = ShardPlan::replicated(&cfg, &net, &w, &plan, 1);
        assert_eq!(p1.shards[0].item_cycles(), r1.shards[0].item_cycles());
        assert_eq!(p1.shards[0].traffic_bytes, r1.shards[0].traffic_bytes);
        assert_eq!(p1.link_bytes_per_item(), 0);
    }

    #[test]
    fn link_bytes_equal_boundary_volumes() {
        // The conservation law: bytes crossing links = volumes at the board
        // cuts, straight from shape inference — nothing lost or duplicated.
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, 3);
        let shapes = net.shapes();
        let wb = cfg.platform.word_bytes;
        let expected: u64 = sp.shards[..sp.used_boards() - 1]
            .iter()
            .map(|s| (shapes[s.layers.end].elems() * wb) as u64)
            .sum();
        assert!(expected > 0);
        assert_eq!(sp.link_bytes_per_item(), expected);
    }

    #[test]
    fn tiny_net_more_boards_than_groups() {
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 2);
        let plan = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let sp = ShardPlan::pipelined(&cfg, &net, &w, &plan, 16);
        assert_eq!(sp.used_boards(), 2, "only 2 groups to host");
        assert_eq!(sp.boards, 16);
        assert_eq!(sp.idle_boards(), 14);
    }

    #[test]
    fn hetero_pipeline_balances_time_not_cycles() {
        // Fast board first, slow board second. The hetero planner must give
        // the slow board a smaller share than the homogeneous cuts would.
        let (fast, net, w) = setup();
        let fleet = vec![fast.clone(), slow_gen()];
        let plan = FusionPlan::unfused(7);
        let sp = ShardPlan::pipelined_fleet(&fleet, &net, &w, &plan);
        assert!(sp.used_boards() >= 1 && sp.used_boards() <= 2);
        assert_eq!(sp.shards[0].freq_mhz, 120.0);
        if sp.used_boards() == 2 {
            assert_eq!(sp.shards[1].freq_mhz, 60.0);
            // Balanced in time, the slow board gets at most the fast
            // board's cycle share (never more).
            assert!(sp.shards[1].item_cycles() <= sp.shards[0].item_cycles());
        }
        // Naive cuts: balance raw cycles as if the boards were equal.
        let ctx_totals: Vec<u64> = plan
            .groups()
            .iter()
            .map(|g| group_cost_estimate(&fast, &net, g.clone()).total())
            .collect();
        let naive_cuts = balance_min_max(&ctx_totals, 2);
        let naive = ShardPlan::pipelined_fleet_with_cuts(&fleet, &net, &w, &plan, &naive_cuts);
        assert!(
            sp.bottleneck_us() <= naive.bottleneck_us() + 1e-9,
            "hetero-aware cuts {} µs must beat naive cuts {} µs",
            sp.bottleneck_us(),
            naive.bottleneck_us()
        );
    }

    #[test]
    fn hetero_pipeline_respects_each_boards_budget() {
        // Board 1 is too small for the big conv groups; the DP must route
        // around it (or mark the plan unfit) — never silently assign a
        // stage that fails that board's own check.
        let (fast, net, w) = setup();
        let mut tiny = slow_gen();
        tiny.platform.dsp = 40; // a 3×3×64-filter conv needs far more lanes
        tiny.platform.name = "tiny".to_string();
        let fleet = vec![fast.clone(), tiny.clone(), fast.clone()];
        let plan = FusionPlan::unfused(7);
        let sp = ShardPlan::pipelined_fleet(&fleet, &net, &w, &plan);
        for s in &sp.shards {
            if s.fits {
                let board_cfg = &fleet[s.board];
                assert!(
                    s.resources.fits(board_cfg),
                    "board {} claims fit but fails its own budget",
                    s.board
                );
            }
        }
        // If the planner reports an overall fit, every stage passed its own
        // board's check by construction.
        if sp.fits() {
            for s in &sp.shards {
                assert!(s.resources.fits(&fleet[s.board]));
            }
        }
    }

    #[test]
    fn labels_identify_mode_and_cuts() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let r = ShardPlan::replicated(&cfg, &net, &w, &plan, 3);
        assert_eq!(r.label(), "replicated:3");
        let p = ShardPlan::pipelined(&cfg, &net, &w, &plan, 2);
        assert!(p.label().starts_with("pipelined["), "{}", p.label());
        assert!(p.label().contains(".."));
    }

    /// Sum co-resident envelopes the way the placement planner bills them:
    /// one shared shell per board plus each resident's incremental fabric.
    fn joint_residency(plans: &[ShardPlan], nb: usize) -> Vec<Resources> {
        let shell = crate::resources::shell_resources();
        let mut total = vec![Resources::default(); nb];
        let mut residents = vec![0usize; nb];
        for p in plans {
            for s in &p.shards {
                total[s.board].add(s.resources.saturating_sub(shell));
                residents[s.board] += 1;
            }
        }
        for (t, &r) in total.iter_mut().zip(&residents) {
            if r > 0 {
                t.add(shell);
            }
        }
        total
    }

    #[test]
    fn place_tenants_coresident_replicas_fit_jointly() {
        // Two small tenants on a 3-board fleet: every board hosts both
        // (sharing one shell), and the joint envelopes stay inside the
        // fabric budget.
        let cfg = AccelConfig::paper_default();
        let net1 = tiny_vgg();
        let w1 = Weights::random(&net1, 1);
        let net2 = tiny_vgg();
        let w2 = Weights::random(&net2, 2);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let fused = FusionPlan::fully_fused(7);
        let tenants = [
            TenantWorkload {
                name: "hi",
                net: &net1,
                weights: &w1,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 2,
                replicas: None,
            },
            TenantWorkload {
                name: "lo",
                net: &net2,
                weights: &w2,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 0,
                replicas: None,
            },
        ];
        let plans = place_tenants(&fleet, &tenants).unwrap();
        assert_eq!(plans.len(), 2);
        for p in &plans {
            assert_eq!(p.used_boards(), 3, "both tenants replicate everywhere");
        }
        for (b, r) in joint_residency(&plans, 3).iter().enumerate() {
            assert!(r.fits(&fleet[b]), "board {b} jointly overflows: {r:?}");
        }
    }

    #[test]
    fn place_tenants_respects_leftover_budget() {
        // Board 1 is too small for the VGG tenant (DSP-starved); the VGG
        // replicas land on boards 0 and 2 and fill their LUT/FF budgets, so
        // the lower-priority tiny tenant can only land on board 1.
        let (fast, net, w) = setup();
        let mut mid = slow_gen();
        mid.platform.dsp = 600;
        mid.platform.name = "mid-board".to_string();
        let fleet = vec![fast.clone(), mid, fast.clone()];
        let fused = FusionPlan::fully_fused(7);
        let net2 = tiny_vgg();
        let w2 = Weights::random(&net2, 2);
        let tenants = [
            TenantWorkload {
                name: "vgg",
                net: &net,
                weights: &w,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 3,
                replicas: None,
            },
            TenantWorkload {
                name: "tiny",
                net: &net2,
                weights: &w2,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 1,
                replicas: None,
            },
        ];
        let plans = place_tenants(&fleet, &tenants).unwrap();
        let vgg_boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(vgg_boards, vec![0, 2], "DSP-starved board must be skipped");
        let tiny_boards: Vec<usize> = plans[1].shards.iter().map(|s| s.board).collect();
        assert_eq!(tiny_boards, vec![1], "only the mid board has fabric left");
        for (b, r) in joint_residency(&plans, 3).iter().enumerate() {
            assert!(r.fits(&fleet[b]), "board {b} jointly overflows");
        }

        // A replica cap takes the emptiest boards first (ties → low index).
        let capped = [TenantWorkload {
            replicas: Some(1),
            ..tenants[0]
        }];
        let plans = place_tenants(&fleet, &capped).unwrap();
        let boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(boards, vec![0]);

        // And a tenant that fits nowhere is a placement error, not a panic.
        let mut nano = slow_gen();
        nano.platform.dsp = 40;
        let impossible_fleet = vec![nano];
        assert!(place_tenants(&impossible_fleet, &[tenants[0]]).is_err());
    }

    #[test]
    fn place_tenants_pipelined_uses_joint_feasibility() {
        // A small replicated tenant is placed first (higher priority); the
        // pipelined VGG tenant's stage DP must then respect what is left on
        // every board it stages onto.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let net2 = tiny_vgg();
        let w2 = Weights::random(&net2, 2);
        let tiny_fused = FusionPlan::fully_fused(7);
        let unfused = FusionPlan::unfused(7);
        let tenants = [
            TenantWorkload {
                name: "hi",
                net: &net2,
                weights: &w2,
                plan: &tiny_fused,
                mode: ShardMode::Replicated,
                priority: 2,
                replicas: None,
            },
            TenantWorkload {
                name: "piped",
                net: &net,
                weights: &w,
                plan: &unfused,
                mode: ShardMode::Pipelined,
                priority: 1,
                replicas: None,
            },
        ];
        let plans = place_tenants(&fleet, &tenants).unwrap();
        assert_eq!(plans[1].mode, ShardMode::Pipelined);
        // Stage shards cover every layer exactly once.
        let mut covered = Vec::new();
        for s in &plans[1].shards {
            covered.extend(s.layers.clone());
        }
        assert_eq!(covered, (0..7).collect::<Vec<_>>());
        for (b, r) in joint_residency(&plans, 3).iter().enumerate() {
            assert!(r.fits(&fleet[b]), "board {b} jointly overflows");
        }
    }

    #[test]
    fn place_tenants_pipelined_routes_around_an_occupied_prefix() {
        // Board 0 is filled by a high-priority fused-VGG replica (capped to
        // one board). The old stage DP pinned stage i to board i, so the
        // pipelined tenant's stage 0 had to co-reside on board 0 — which
        // does not fit — and placement FAILED even though boards 1 and 2
        // sat completely free. Free placement offers the DP the emptiest
        // boards first and the chain routes around the occupied prefix.
        let (cfg, net, w) = setup();
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let fused = FusionPlan::fully_fused(7);
        // First group fuses two 3×3 convs — wide enough that it can never
        // co-reside with the anchor (a lone conv1_1 barely could).
        let split = FusionPlan::from_group_sizes(7, &[2, 2, 3]).unwrap();
        let w2 = Weights::random(&net, 2);
        let tenants = [
            TenantWorkload {
                name: "anchor",
                net: &net,
                weights: &w,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 3,
                replicas: Some(1),
            },
            TenantWorkload {
                name: "piped",
                net: &net,
                weights: &w2,
                plan: &split,
                mode: ShardMode::Pipelined,
                priority: 1,
                replicas: None,
            },
        ];
        let plans = place_tenants(&fleet, &tenants).unwrap();
        let anchor_boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(anchor_boards, vec![0], "replica cap pins the anchor to board 0");

        // The premise the old pinning tripped on: no stage-0 prefix of the
        // pipelined plan fits board 0 jointly with the anchor — so a DP
        // whose stage 0 must run on board 0 has no candidate at all and the
        // whole placement failed.
        let shell = crate::resources::shell_resources();
        let anchor_incr = plans[0].shards[0].resources.saturating_sub(shell);
        let groups = split.groups();
        for hi in 1..=groups.len() {
            let layer_range = groups[0].start..groups[hi - 1].end;
            let mut joint = shell;
            joint.add(anchor_incr);
            joint.add(
                crate::resources::group_resources(&cfg, &net, layer_range.clone())
                    .saturating_sub(shell),
            );
            assert!(
                !joint.fits(&fleet[0]),
                "premise broken: layer range {layer_range:?} co-fits board 0 — the \
                 old pinned DP would not have failed here"
            );
        }

        // Free placement succeeds, off the occupied board, covering every
        // layer exactly once.
        assert_eq!(plans[1].mode, ShardMode::Pipelined);
        assert!(
            plans[1].shards.iter().all(|s| s.board != 0),
            "no stage may land on the occupied board: {:?}",
            plans[1].shards.iter().map(|s| s.board).collect::<Vec<_>>()
        );
        let mut covered = Vec::new();
        for s in &plans[1].shards {
            covered.extend(s.layers.clone());
        }
        covered.sort_unstable();
        assert_eq!(covered, (0..7).collect::<Vec<_>>());
        assert!(plans[1].fits());
    }

    #[test]
    fn place_tenants_biased_prefers_cool_boards() {
        // With an explicit load bias, a capped replicated tenant lands on
        // the coolest board, and a pipelined tenant's first stage starts
        // there too — the ordering the unified control plane feeds from
        // window busy cycles.
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 1);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let fused = FusionPlan::fully_fused(7);
        let capped = [TenantWorkload {
            name: "t",
            net: &net,
            weights: &w,
            plan: &fused,
            mode: ShardMode::Replicated,
            priority: 1,
            replicas: Some(1),
        }];
        // Board 2 is the coolest.
        let plans = place_tenants_biased(&fleet, &capped, &[500, 300, 100]).unwrap();
        let boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(boards, vec![2]);
        // Zero bias reduces to the static emptiest-first order.
        let plans0 = place_tenants_biased(&fleet, &capped, &[0, 0, 0]).unwrap();
        let boards0: Vec<usize> = plans0[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(boards0, vec![0]);

        let split = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let piped = [TenantWorkload {
            name: "p",
            net: &net,
            weights: &w,
            plan: &split,
            mode: ShardMode::Pipelined,
            priority: 1,
            replicas: None,
        }];
        let plans = place_tenants_biased(&fleet, &piped, &[500, 100, 300]).unwrap();
        assert_eq!(plans[0].shards[0].board, 1, "stage 0 on the coolest board");
        let mut covered = Vec::new();
        for s in &plans[0].shards {
            covered.extend(s.layers.clone());
        }
        covered.sort_unstable();
        assert_eq!(covered, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn place_tenants_alive_excludes_dead_boards() {
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 1);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let fused = FusionPlan::fully_fused(7);
        let repl = [TenantWorkload {
            name: "t",
            net: &net,
            weights: &w,
            plan: &fused,
            mode: ShardMode::Replicated,
            priority: 1,
            replicas: None,
        }];
        // Board 1 dead: replicas land only on the survivors.
        let plans =
            place_tenants_alive(&fleet, &repl, &[0, 0, 0], &[true, false, true]).unwrap();
        let boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(boards, vec![0, 2]);

        // A pipelined chain re-plans onto the surviving permutation (its
        // stage count shrinks to the alive-board count if needed).
        let split = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let piped = [TenantWorkload {
            name: "p",
            net: &net,
            weights: &w,
            plan: &split,
            mode: ShardMode::Pipelined,
            priority: 1,
            replicas: None,
        }];
        let plans =
            place_tenants_alive(&fleet, &piped, &[0, 0, 0], &[false, true, true]).unwrap();
        for s in &plans[0].shards {
            assert!(s.board != 0, "no stage may land on the dead board");
        }
        let mut covered = Vec::new();
        for s in &plans[0].shards {
            covered.extend(s.layers.clone());
        }
        covered.sort_unstable();
        assert_eq!(covered, (0..7).collect::<Vec<_>>());

        // All-alive reduces exactly to place_tenants_biased.
        let a = place_tenants_alive(&fleet, &repl, &[7, 0, 3], &[true, true, true]).unwrap();
        let b = place_tenants_biased(&fleet, &repl, &[7, 0, 3]).unwrap();
        assert_eq!(
            a[0].shards.iter().map(|s| s.board).collect::<Vec<_>>(),
            b[0].shards.iter().map(|s| s.board).collect::<Vec<_>>()
        );

        // A fully dead fleet is an error, not a panic.
        assert!(place_tenants_alive(&fleet, &repl, &[0, 0, 0], &[false, false, false]).is_err());
    }

    #[test]
    fn place_tenants_capacity_routes_around_a_brownout_board() {
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 1);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let fused = FusionPlan::fully_fused(7);
        let alive = [true, true, true];
        let capped = [TenantWorkload {
            name: "t",
            net: &net,
            weights: &w,
            plan: &fused,
            mode: ShardMode::Replicated,
            priority: 1,
            replicas: Some(2),
        }];
        // Board 0 at 30% capacity: the two replicas land on the healthy
        // boards even though board 0 leads the index/bias order.
        let plans = place_tenants_capacity(
            &fleet, &capped, &[0, 0, 0], &alive, &[0.3, 1.0, 1.0],
        )
        .unwrap();
        let boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(boards, vec![1, 2], "brownout board ranks last");
        // Degradation outranks the load bias: a cool-but-degraded board
        // still loses to a warm healthy one.
        let plans = place_tenants_capacity(
            &fleet, &capped, &[0, 900, 900], &alive, &[0.3, 1.0, 1.0],
        )
        .unwrap();
        let boards: Vec<usize> = plans[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(boards, vec![1, 2]);

        // Pipelined: the DP sees the brownout board at a third of its
        // clock, so the stage that lands there shrinks — its cycle share
        // drops versus the all-healthy split of the same chain.
        let split = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let piped = [TenantWorkload {
            name: "p",
            net: &net,
            weights: &w,
            plan: &split,
            mode: ShardMode::Pipelined,
            priority: 1,
            replicas: None,
        }];
        let healthy = place_tenants_capacity(
            &fleet, &piped, &[0, 0, 0], &alive, &[1.0, 1.0, 1.0],
        )
        .unwrap();
        let browned = place_tenants_capacity(
            &fleet, &piped, &[0, 0, 0], &alive, &[0.3, 1.0, 1.0],
        )
        .unwrap();
        // The degraded board is pushed to the back of the permutation, so
        // stage 0 moves off it entirely.
        assert_eq!(healthy[0].shards[0].board, 0);
        assert_ne!(browned[0].shards[0].board, 0);
        let mut covered = Vec::new();
        for s in &browned[0].shards {
            covered.extend(s.layers.clone());
        }
        covered.sort_unstable();
        assert_eq!(covered, (0..7).collect::<Vec<_>>());

        // All-1.0 capacity is exactly place_tenants_alive (same plans).
        let a = place_tenants_capacity(
            &fleet, &piped, &[7, 0, 3], &alive, &[1.0, 1.0, 1.0],
        )
        .unwrap();
        let b = place_tenants_alive(&fleet, &piped, &[7, 0, 3], &alive).unwrap();
        assert_eq!(a[0].label(), b[0].label());
        assert_eq!(
            a[0].shards.iter().map(|s| s.board).collect::<Vec<_>>(),
            b[0].shards.iter().map(|s| s.board).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fabric_placement_spreads_replicas_across_racks() {
        // 4 boards in 2 racks of 2, two replicas. Without a fabric the
        // emptiest-first order stacks both replicas into rack 0 (boards 0
        // and 1); with the topology armed the second pick charges rack 0
        // and jumps to rack 1 — a RackDown now takes out one replica, not
        // both.
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 1);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone(), cfg.clone()];
        let fused = FusionPlan::fully_fused(7);
        let t = [TenantWorkload {
            name: "r",
            net: &net,
            weights: &w,
            plan: &fused,
            mode: ShardMode::Replicated,
            priority: 1,
            replicas: Some(2),
        }];
        let zeros = [0u64; 4];
        let alive = [true; 4];
        let ones = [1.0f64; 4];
        let flat = place_tenants_capacity_fabric(&fleet, &t, &zeros, &alive, &ones, None).unwrap();
        let boards = |p: &ShardPlan| p.shards.iter().map(|s| s.board).collect::<Vec<_>>();
        assert_eq!(boards(&flat[0]), vec![0, 1], "no fabric: emptiest-first");
        let spec = FabricSpec::leaf_spine(2);
        let spread =
            place_tenants_capacity_fabric(&fleet, &t, &zeros, &alive, &ones, Some(&spec)).unwrap();
        assert_eq!(boards(&spread[0]), vec![0, 2], "fabric: one replica per rack");
    }

    #[test]
    fn fabric_placement_keeps_a_chain_in_one_rack() {
        // 4 boards in 2 racks of 2, a 2-stage chain, board 0 running hot
        // (bias). The flat permutation is [1, 2, 3, 0], so the chain lands
        // on boards 1 and 2 — a cross-rack cut whose boundary traffic
        // would ride the uplinks. The topology-aware planner offers rack
        // 0's boards alone first and keeps both stages inside it.
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 1);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone(), cfg.clone()];
        let split = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let t = [TenantWorkload {
            name: "p",
            net: &net,
            weights: &w,
            plan: &split,
            mode: ShardMode::Pipelined,
            priority: 1,
            replicas: None,
        }];
        let bias = [5u64, 0, 1, 2];
        let alive = [true; 4];
        let ones = [1.0f64; 4];
        let spec = FabricSpec::leaf_spine(2);
        let flat = place_tenants_capacity_fabric(&fleet, &t, &bias, &alive, &ones, None).unwrap();
        let fb: Vec<usize> = flat[0].shards.iter().map(|s| s.board).collect();
        assert_eq!(fb, vec![1, 2], "flat order splits the chain across racks");
        let local =
            place_tenants_capacity_fabric(&fleet, &t, &bias, &alive, &ones, Some(&spec)).unwrap();
        let racks: Vec<usize> = local[0].shards.iter().map(|s| spec.rack_of(s.board)).collect();
        assert!(
            racks.windows(2).all(|w| w[0] == w[1]),
            "fabric keeps the chain in one rack, got boards {:?}",
            local[0].shards.iter().map(|s| s.board).collect::<Vec<_>>()
        );
        // Every layer still covered exactly once on the rack-local plan.
        let mut covered: Vec<usize> =
            local[0].shards.iter().flat_map(|s| s.layers.clone()).collect();
        covered.sort_unstable();
        assert_eq!(covered, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn single_rack_fabric_matches_flat_placement() {
        // A fabric whose one rack holds the whole fleet adds no topology
        // information: the greedy replica pick sees a constant rack load
        // and the chain's rack-local permutation IS the flat permutation —
        // plans must come out identical to `fabric: None`.
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let w = Weights::random(&net, 1);
        let fleet = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let split = FusionPlan::from_group_sizes(7, &[4, 3]).unwrap();
        let fused = FusionPlan::fully_fused(7);
        let tenants = [
            TenantWorkload {
                name: "p",
                net: &net,
                weights: &w,
                plan: &split,
                mode: ShardMode::Pipelined,
                priority: 2,
                replicas: None,
            },
            TenantWorkload {
                name: "r",
                net: &net,
                weights: &w,
                plan: &fused,
                mode: ShardMode::Replicated,
                priority: 1,
                replicas: Some(2),
            },
        ];
        let bias = [3u64, 0, 1];
        let alive = [true; 3];
        let ones = [1.0f64; 3];
        let spec = FabricSpec::leaf_spine(3);
        let flat =
            place_tenants_capacity_fabric(&fleet, &tenants, &bias, &alive, &ones, None).unwrap();
        let armed =
            place_tenants_capacity_fabric(&fleet, &tenants, &bias, &alive, &ones, Some(&spec))
                .unwrap();
        for (a, b) in flat.iter().zip(&armed) {
            assert_eq!(a.label(), b.label());
            assert_eq!(
                a.shards.iter().map(|s| s.board).collect::<Vec<_>>(),
                b.shards.iter().map(|s| s.board).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn capacity_estimate_orders_plans_sensibly() {
        let (cfg, net, w) = setup();
        let plan = FusionPlan::unfused(7);
        let link = InterBoardLink::ideal();
        let f = cfg.platform.freq_mhz;
        // More replicas → more capacity.
        let r2 = ShardPlan::replicated(&cfg, &net, &w, &plan, 2);
        let r4 = ShardPlan::replicated(&cfg, &net, &w, &plan, 4);
        assert!(r4.capacity_rps(8, &link, f) > r2.capacity_rps(8, &link, f));
        // A finite link can cap a pipelined plan below its ideal-link form.
        let p = ShardPlan::pipelined(&cfg, &net, &w, &plan, 4);
        let tight = InterBoardLink::new(0.01, 1000);
        assert!(p.capacity_rps(8, &tight, f) < p.capacity_rps(8, &link, f) + 1e-9);
    }
}
