//! Multi-accelerator sharded serving simulation (L4).
//!
//! The paper's engine saturates one board; this subsystem asks what happens
//! when a fleet of boards serves production traffic. It composes the models
//! the repo already trusts — the closed-form cycle/traffic estimates
//! (`accel::latency`), the fusion planner (`coordinator::planner`), the
//! structural resource model (`resources`) — into:
//!
//! * a **shard planner** ([`ShardPlan`]): replicated (data-parallel) or
//!   pipelined (model-parallel, min-max balanced contiguous group ranges
//!   with inter-board link transfers of boundary volumes);
//! * a **shared-DDR contention model** ([`crate::fpga::ddr::SharedDdr`]):
//!   co-located boards drawing from one off-chip bandwidth pool stretch
//!   their DDR phases once oversubscribed — the fleet-level analogue of the
//!   paper's bandwidth-constrained argument;
//! * a **request scheduler** ([`simulate_fleet`]): open-loop Poisson
//!   arrivals, per-board queues batched by the coordinator's
//!   [`crate::coordinator::batcher::DynamicBatcher`], reporting throughput,
//!   p50/p99 latency and per-board utilization.
//!
//! `benches/cluster_scaling.rs` sweeps 1→16 boards in both modes and shows
//! where the shared bandwidth pool flattens the scaling curve.

pub mod link;
pub mod shard;
pub mod sim;

pub use link::InterBoardLink;
pub use shard::{BoardShard, ShardPlan};
pub use sim::{poisson_arrivals, simulate_fleet, BoardStats, FleetReport};

use crate::accel::engine::Weights;
use crate::config::{AccelConfig, ClusterConfig, Network, ShardMode};
use crate::coordinator::planner::{best_plan, Objective};

/// Plan a fleet for `net`: pick the best single-board fusion plan under the
/// latency objective, then shard it according to the cluster config.
pub fn plan_fleet(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
    ccfg: &ClusterConfig,
) -> Result<ShardPlan, String> {
    ccfg.validate()?;
    let best = best_plan(cfg, net, weights, Objective::Latency)
        .ok_or("no fusion plan fits the board")?;
    let shard = match ccfg.mode {
        ShardMode::Replicated => {
            ShardPlan::replicated(cfg, net, weights, &best.plan, ccfg.boards)
        }
        ShardMode::Pipelined => {
            // Pipelining partitions *groups*; a latency-optimal plan is often
            // one big group, which cannot spread over boards. Re-plan under
            // progressively tighter DSP caps until the plan has enough groups
            // to occupy the fleet (or no tighter cap helps — a network can
            // simply run out of split points). Any residual shortfall is
            // visible to callers as `used_boards() < boards`.
            let mut plan = best.plan;
            if plan.n_groups() < ccfg.boards {
                for cap in [50u8, 25, 10] {
                    if let Some(p) =
                        best_plan(cfg, net, weights, Objective::LatencyUnderDspCap(cap))
                    {
                        if p.plan.n_groups() > plan.n_groups() {
                            plan = p.plan;
                        }
                    }
                    if plan.n_groups() >= ccfg.boards {
                        break;
                    }
                }
            }
            ShardPlan::pipelined(cfg, net, weights, &plan, ccfg.boards)
        }
    };
    if !shard.fits() {
        return Err("shard does not fit the per-board resource budget".into());
    }
    Ok(shard)
}

/// Convenience: plan the fleet and run the scheduler simulation in one call.
pub fn run_fleet(
    cfg: &AccelConfig,
    net: &Network,
    ccfg: &ClusterConfig,
) -> Result<FleetReport, String> {
    let weights = Weights::random(net, ccfg.seed);
    let shard = plan_fleet(cfg, net, &weights, ccfg)?;
    Ok(simulate_fleet(cfg, &shard, ccfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::vgg16_prefix;

    #[test]
    fn plan_fleet_replicated_uses_best_plan() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.boards = 3;
        let shard = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
        assert_eq!(shard.mode, ShardMode::Replicated);
        assert_eq!(shard.used_boards(), 3);
        assert!(shard.fits());
    }

    #[test]
    fn plan_fleet_pipelined_spreads_over_boards() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.mode = ShardMode::Pipelined;
        ccfg.boards = 4;
        let shard = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
        assert_eq!(shard.mode, ShardMode::Pipelined);
        assert!(shard.used_boards() > 1, "fleet must actually pipeline");
        assert!(shard.fits());
    }

    #[test]
    fn run_fleet_end_to_end() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.requests = 64;
        let r = run_fleet(&cfg, &net, &ccfg).unwrap();
        assert_eq!(r.completed, 64);
        assert!(r.throughput_rps > 0.0);
    }
}
