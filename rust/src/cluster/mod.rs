//! Multi-accelerator sharded serving simulation (L4).
//!
//! The paper's engine saturates one board; this subsystem asks what happens
//! when a fleet of boards serves production traffic. It composes the models
//! the repo already trusts — the closed-form cycle/traffic estimates
//! (`accel::latency`), the fusion planner (`coordinator::planner`), the
//! structural resource model (`resources`) — into:
//!
//! * a **shard planner** ([`ShardPlan`]): replicated (data-parallel) or
//!   pipelined (model-parallel, min-max balanced contiguous group ranges
//!   with inter-board link transfers of boundary volumes), heterogeneity
//!   aware — stage cost is cycles *at that board's clock* and feasibility
//!   is checked against *that board's* resource envelope;
//! * a **shared-DDR contention model** ([`crate::fpga::ddr::SharedDdr`]):
//!   co-located boards drawing from one off-chip bandwidth pool stretch
//!   their DDR phases once oversubscribed — the fleet-level analogue of the
//!   paper's bandwidth-constrained argument;
//! * a **capacity-limited link model** ([`LinkChannel`]): boundary-volume
//!   transfers serialize on finite wires, so the link itself can be the
//!   bottleneck stage;
//! * a **request scheduler** ([`simulate_fleet`]): open-loop Poisson
//!   arrivals, per-board queues batched by the coordinator's
//!   [`crate::coordinator::batcher::DynamicBatcher`], reporting throughput,
//!   p50/p99 latency and per-board utilization;
//! * a **re-shard controller** ([`simulate_fleet_dynamic`]): watches window
//!   p99 and utilization skew under drifting load, re-plans the shard,
//!   bills the migration, and reports every decision as a [`ReshardEvent`];
//! * a **unified multi-tenant control plane**: several networks share one
//!   fleet — [`place_tenants`] packs per-tenant shard plans onto the boards
//!   under joint fabric feasibility (one shared shell per board plus each
//!   resident's incremental engine; the pipelined stage DP takes the boards
//!   emptiest-first, so a chain routes around an occupied rack prefix), and
//!   [`simulate_fleet_multi_tenant`] serves the merged per-tenant arrival
//!   streams under strict priorities with deficit-weighted round-robin fair
//!   sharing inside each class (`SloPolicy::weight`), work-preserving or
//!   restart preemption (`PreemptMode`), and — with a
//!   [`crate::config::ReshardPolicy`] armed — tenant-aware mid-run
//!   re-placement
//!   ([`place_tenants_biased`], SLO-missing tenants uncapped, coolest
//!   boards first) with per-tenant migration billing and
//!   [`ReshardEvent`]s, reporting per-tenant [`TenantStats`] (p50/p99, SLO
//!   attainment, preemption counts, post-settle tail p99);
//! * a **telemetry layer** ([`telemetry`]): a zero-cost-when-disabled
//!   [`TraceSink`] threaded through all three simulators (the `*_traced`
//!   twins) recording typed byte-deterministic [`TraceEvent`]s — admission
//!   with the DRR deficit, dispatch/flush per board, preemption with the
//!   refunded deficit, the reshard lifecycle, window rollups — plus
//!   windowed time-series ([`WindowSample`]) and per-tenant online
//!   [`QuantileSketch`]es, surfaced as the optional
//!   [`FleetReport::telemetry`] section, the CLI's `--trace` export and
//!   ASCII fleet dashboard ([`fleet_dashboard`]);
//! * a **fault-injection layer**: a seeded, deterministic
//!   [`crate::config::FaultScript`] (board failures with optional recovery,
//!   link-degrade windows, clock derates) threads through the multi-tenant
//!   engine's own event heap, so fault timing composes exactly with
//!   arrivals, batch flushes and controller windows. A dead board's
//!   in-flight batch re-queues under the preemption protocol's accounting,
//!   replicated tenants drain to surviving replicas, severed pipelined
//!   chains trigger an **emergency re-shard** on the live boards
//!   ([`place_tenants_alive`]), and recovery re-admits the board
//!   coolest-first at the next controller window. Partial-capacity
//!   brownouts (`compute_degrade`) stretch the compute phase of the cost
//!   model and demote the board in the capacity-aware placement rank
//!   ([`place_tenants_capacity`]); `board_down` and `clock_derate` scripts
//!   also drive the single-network simulators. Outcomes surface as
//!   fault-typed [`TraceEvent`]s and the optional [`FleetReport::faults`]
//!   summary ([`FaultSummary`]), including a recovery-time objective;
//!   without a script every fault path is branch-gated off and reports
//!   stay byte-identical.
//! * an **overload-shedding layer**: a per-tenant
//!   [`crate::config::OverloadPolicy`] makes admission predict each
//!   request's completion from board occupancy and the DRR deficit and
//!   shed what cannot meet its deadline; shed requests retry on a
//!   deterministic exponential backoff ([`crate::config::RetryPolicy`])
//!   and count as abandoned once the budget is spent — conserved as
//!   `offered == completed + abandoned` per tenant and rolled up in
//!   [`FleetReport`].
//! * an **interconnect fabric layer** ([`fabric`]): an optional routed
//!   topology ([`crate::config::FabricSpec`]: rack ring or leaf-spine)
//!   maps boards to racks and models the physical wires as *shared
//!   serializing segments* — [`Fabric::route`] returns the segment path
//!   between two boards and every transfer (pipeline boundary volumes,
//!   re-shard migration bills, fault drain-to-peers) is billed hop by hop
//!   on the segments' occupancy timelines, so a saturated uplink becomes
//!   a producible bottleneck. Placement turns topology-aware
//!   ([`place_tenants_capacity_fabric`]): pipelined chains stay inside one
//!   rack when feasible, replicated tenants spread across racks as failure
//!   domains, and [`crate::config::FaultEvent::RackDown`] scripts
//!   correlated whole-rack outages. Route traffic surfaces as
//!   `route_transfer` [`TraceEvent`]s, `route_*` telemetry counters and
//!   the per-segment [`FleetReport::fabric`] utilization section; with no
//!   fabric configured every path short-circuits to the point-to-point
//!   [`LinkChannel`] arithmetic and reports stay byte-identical.
//!
//! `benches/cluster_scaling.rs` sweeps 1→16 boards in both modes, adds a
//! heterogeneous two-generation fleet sweep, a load-step re-sharding
//! scenario and a two-tenant priority scene, and emits the
//! `BENCH_cluster.json` metrics CI tracks (including the simulator's own
//! `sim_events_per_sec` self-instrumentation rows).

pub mod events;
pub mod fabric;
pub mod link;
pub mod shard;
pub mod sim;
pub mod telemetry;

pub use fabric::{Fabric, FabricSummary, Segment, SegmentKind, SegmentSummary};
pub use link::{InterBoardLink, LinkChannel};
pub use shard::{
    balance_min_max, place_tenants, place_tenants_alive, place_tenants_biased,
    place_tenants_capacity, place_tenants_capacity_fabric, BoardShard, ShardPlan, TenantWorkload,
};
pub use sim::{
    arrivals_with_steps, poisson_arrivals, simulate_fleet, simulate_fleet_dynamic,
    simulate_fleet_dynamic_traced, simulate_fleet_multi_tenant, simulate_fleet_multi_tenant_traced,
    simulate_fleet_traced, tenant_seed, BoardStats, FaultSummary, FleetReport, ReshardEvent,
    TenantStats,
};
pub use telemetry::{
    fleet_dashboard, flushed_items_per_tenant, last_flush_per_tenant, preemptions_per_tenant,
    QuantileSketch, TelemetrySummary, TraceEvent, TraceSink, WindowSample,
};

use crate::accel::engine::Weights;
use crate::accel::fusion::FusionPlan;
use crate::config::{AccelConfig, ClusterConfig, Network, ShardMode};
use crate::coordinator::planner::{best_plan, Objective};

/// Plan a fleet for `net`: pick the best single-board fusion plan under the
/// latency objective (searched on the base config), then shard it across
/// the fleet `ccfg` describes — homogeneous copies of `cfg` by default, or
/// the per-generation platforms of `ccfg.board_specs`.
pub fn plan_fleet(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
    ccfg: &ClusterConfig,
) -> Result<ShardPlan, String> {
    ccfg.validate()?;
    let fleet = ccfg.board_configs(cfg);
    for (b, f) in fleet.iter().enumerate() {
        if f.platform.word_bytes != cfg.platform.word_bytes {
            return Err(format!(
                "board {b}: word_bytes {} differs from the base config's {}",
                f.platform.word_bytes, cfg.platform.word_bytes
            ));
        }
    }
    let plan = fusion_plan_for_fleet(cfg, net, weights, ccfg.mode, ccfg.boards)?;
    let shard = match ccfg.mode {
        ShardMode::Replicated => ShardPlan::replicated_fleet(&fleet, net, weights, &plan),
        ShardMode::Pipelined => ShardPlan::pipelined_fleet(&fleet, net, weights, &plan),
    };
    if !shard.fits() {
        return Err("shard does not fit some board's resource budget".into());
    }
    Ok(shard)
}

/// Pick the fusion plan a fleet should shard. Latency-optimal by default;
/// for pipelined fleets a latency-optimal plan is often one big group,
/// which cannot spread over boards, so the search re-plans under
/// progressively tighter DSP caps until the plan has enough groups to
/// occupy the fleet (or no tighter cap helps — a network can simply run out
/// of split points). Any residual shortfall is visible to callers as
/// `used_boards() < boards` and reported as `idle_boards`.
fn fusion_plan_for_fleet(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
    mode: ShardMode,
    boards: usize,
) -> Result<FusionPlan, String> {
    let best = best_plan(cfg, net, weights, Objective::Latency)
        .ok_or("no fusion plan fits the board")?;
    let mut plan = best.plan;
    if mode == ShardMode::Pipelined && plan.n_groups() < boards {
        for cap in [50u8, 25, 10] {
            if let Some(p) = best_plan(cfg, net, weights, Objective::LatencyUnderDspCap(cap)) {
                if p.plan.n_groups() > plan.n_groups() {
                    plan = p.plan;
                }
            }
            if plan.n_groups() >= boards {
                break;
            }
        }
    }
    Ok(plan)
}

/// Plan every tenant of a multi-tenant cluster config: per-tenant weights
/// (from each tenant's seed), per-tenant fusion plans (searched on the base
/// config, same policy as [`plan_fleet`]), then the joint placement over the
/// shared fleet. Returns `(weights, plans)` in tenant order.
///
/// # Examples
///
/// ```
/// use decoilfnet::cluster::plan_tenants;
/// use decoilfnet::config::{tiny_vgg, AccelConfig, ClusterConfig, ShardMode, SloPolicy, TenantSpec};
///
/// let cfg = AccelConfig::paper_default();
/// let mut ccfg = ClusterConfig::fleet_default();
/// ccfg.boards = 2;
/// ccfg.tenants = vec![TenantSpec {
///     name: "solo".to_string(),
///     network: tiny_vgg(),
///     weights_seed: 1,
///     arrival_rps: f64::INFINITY, // burst at t = 0
///     requests: 8,
///     load_steps: vec![],
///     mode: ShardMode::Replicated,
///     replicas: None,
///     slo: SloPolicy { p99_ms: 10.0, priority: 1, weight: 1.0, overload: None },
/// }];
/// let (weights, plans) = plan_tenants(&cfg, &ccfg).unwrap();
/// assert_eq!(weights.len(), 1);
/// assert!(plans[0].used_boards() >= 1);
/// ```
pub fn plan_tenants(
    cfg: &AccelConfig,
    ccfg: &ClusterConfig,
) -> Result<(Vec<Weights>, Vec<ShardPlan>), String> {
    ccfg.validate()?;
    assert!(!ccfg.tenants.is_empty(), "no tenants configured");
    let fleet = ccfg.board_configs(cfg);
    let weights: Vec<Weights> = ccfg
        .tenants
        .iter()
        .map(|t| Weights::random(&t.network, t.weights_seed))
        .collect();
    let plans: Vec<FusionPlan> = ccfg
        .tenants
        .iter()
        .zip(&weights)
        .map(|(t, w)| fusion_plan_for_fleet(cfg, &t.network, w, t.mode, ccfg.boards))
        .collect::<Result<Vec<_>, _>>()?;
    let workloads: Vec<TenantWorkload> = ccfg
        .tenants
        .iter()
        .zip(&weights)
        .zip(&plans)
        .map(|((t, w), p)| TenantWorkload {
            name: &t.name,
            net: &t.network,
            weights: w,
            plan: p,
            mode: t.mode,
            priority: t.slo.priority,
            replicas: t.replicas,
        })
        .collect();
    // Static placement goes through the fabric-aware root so an armed
    // topology shapes the initial plan too (in-rack chains, replicas
    // spread across racks); with `fabric: None` this is exactly
    // `place_tenants` — the byte-compat contract the committed
    // multi-tenant fixtures rely on.
    let nb = fleet.len();
    let shard_plans = place_tenants_capacity_fabric(
        &fleet,
        &workloads,
        &vec![0u64; nb],
        &vec![true; nb],
        &vec![1.0; nb],
        ccfg.fabric.as_ref(),
    )?;
    Ok((weights, shard_plans))
}

/// Convenience: plan the fleet and run the scheduler simulation in one
/// call. With tenants configured, the multi-tenant placement planner and
/// the unified control plane run (`net` is ignored — every tenant brings
/// its own network); arming `ccfg.reshard` alongside tenants turns on
/// tenant-aware re-sharding inside that engine (the CLI's combined
/// `--reshard --tenants` path). Otherwise, with a re-shard policy
/// configured, the single-network dynamic controller runs (and may migrate
/// shards under load); else the static scheduler does.
///
/// # Examples
///
/// Single-network static fleet:
///
/// ```
/// use decoilfnet::cluster::run_fleet;
/// use decoilfnet::config::{vgg16_prefix, AccelConfig, ClusterConfig};
///
/// let cfg = AccelConfig::paper_default();
/// let mut ccfg = ClusterConfig::fleet_default();
/// ccfg.requests = 16;
/// let report = run_fleet(&cfg, &vgg16_prefix(), &ccfg).unwrap();
/// assert_eq!(report.completed, 16);
/// assert!(report.faults.is_none(), "no script, no fault section");
/// ```
///
/// Multi-tenant with a scripted outage — the `tenants` path is the only
/// engine that injects faults, and the report then carries
/// [`FleetReport::faults`]:
///
/// ```
/// use decoilfnet::cluster::run_fleet;
/// use decoilfnet::config::{
///     tiny_vgg, AccelConfig, ClusterConfig, FaultEvent, FaultScript, ShardMode, SloPolicy,
///     TenantSpec,
/// };
///
/// let cfg = AccelConfig::paper_default();
/// let mut ccfg = ClusterConfig::fleet_default();
/// ccfg.boards = 2;
/// ccfg.tenants = vec![TenantSpec {
///     name: "burst".to_string(),
///     network: tiny_vgg(),
///     weights_seed: 1,
///     arrival_rps: f64::INFINITY,
///     requests: 32,
///     load_steps: vec![],
///     mode: ShardMode::Replicated,
///     replicas: None,
///     slo: SloPolicy { p99_ms: 10.0, priority: 1, weight: 1.0, overload: None },
/// }];
/// ccfg.faults = Some(FaultScript {
///     events: vec![FaultEvent::BoardDown { board: 1, at_ms: 0.2, recover_ms: Some(1.0) }],
/// });
/// let report = run_fleet(&cfg, &tiny_vgg(), &ccfg).unwrap();
/// assert_eq!(report.completed, 32, "the survivor absorbs the outage");
/// let faults = report.faults.unwrap();
/// assert_eq!(faults.board_failures, 1);
/// assert_eq!(faults.board_recoveries, 1);
/// ```
pub fn run_fleet(
    cfg: &AccelConfig,
    net: &Network,
    ccfg: &ClusterConfig,
) -> Result<FleetReport, String> {
    run_fleet_traced(cfg, net, ccfg, &mut TraceSink::disabled())
}

/// [`run_fleet`] with a caller-supplied [`TraceSink`]: the same three-way
/// engine dispatch, with the sink threaded into whichever simulator runs.
/// Pass [`TraceSink::enabled`] to collect the event trace, window samples
/// and per-tenant latency sketches alongside the report (which then carries
/// the [`FleetReport::telemetry`] summary).
pub fn run_fleet_traced(
    cfg: &AccelConfig,
    net: &Network,
    ccfg: &ClusterConfig,
    sink: &mut TraceSink,
) -> Result<FleetReport, String> {
    if !ccfg.tenants.is_empty() {
        let fleet = ccfg.board_configs(cfg);
        let (weights, plans) = plan_tenants(cfg, ccfg)?;
        return Ok(simulate_fleet_multi_tenant_traced(
            cfg,
            &fleet,
            &ccfg.tenants,
            &weights,
            &plans,
            ccfg,
            sink,
        ));
    }
    let weights = Weights::random(net, ccfg.seed);
    let shard = plan_fleet(cfg, net, &weights, ccfg)?;
    if ccfg.reshard.is_some() {
        let fleet = ccfg.board_configs(cfg);
        Ok(simulate_fleet_dynamic_traced(
            cfg, &fleet, net, &weights, shard, ccfg, sink,
        ))
    } else {
        Ok(simulate_fleet_traced(cfg, &shard, ccfg, sink))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{vgg16_prefix, BoardSpec, Platform, ReshardPolicy};

    #[test]
    fn plan_fleet_replicated_uses_best_plan() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.boards = 3;
        let shard = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
        assert_eq!(shard.mode, ShardMode::Replicated);
        assert_eq!(shard.used_boards(), 3);
        assert!(shard.fits());
    }

    #[test]
    fn plan_fleet_pipelined_spreads_over_boards() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.mode = ShardMode::Pipelined;
        ccfg.boards = 4;
        let shard = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
        assert_eq!(shard.mode, ShardMode::Pipelined);
        assert!(shard.used_boards() > 1, "fleet must actually pipeline");
        assert!(shard.fits());
    }

    #[test]
    fn plan_fleet_heterogeneous_checks_every_boards_budget() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.mode = ShardMode::Pipelined;
        ccfg.boards = 3;
        ccfg.board_specs = vec![
            BoardSpec {
                count: 2,
                platform: Platform::virtex7_xc7v690t(),
            },
            BoardSpec {
                count: 1,
                platform: Platform::virtex7_at_100mhz(),
            },
        ];
        let shard = plan_fleet(&cfg, &net, &w, &ccfg).unwrap();
        assert!(shard.fits());
        let fleet = ccfg.board_configs(&cfg);
        for s in &shard.shards {
            assert!(
                s.resources.fits(&fleet[s.board]),
                "stage on board {} must pass that board's own check",
                s.board
            );
        }
    }

    #[test]
    fn run_fleet_end_to_end() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.requests = 64;
        let r = run_fleet(&cfg, &net, &ccfg).unwrap();
        assert_eq!(r.completed, 64);
        assert!(r.throughput_rps > 0.0);
    }

    #[test]
    fn run_fleet_with_tenants_uses_the_multi_tenant_simulator() {
        use crate::config::{tiny_vgg, SloPolicy, TenantSpec};
        let cfg = AccelConfig::paper_default();
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.boards = 2;
        ccfg.tenants = vec![
            TenantSpec {
                name: "hi".to_string(),
                network: tiny_vgg(),
                weights_seed: 1,
                arrival_rps: 500.0,
                requests: 24,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 10.0,
                    priority: 2,
                    weight: 1.0,
                    overload: None,
                },
            },
            TenantSpec {
                name: "lo".to_string(),
                network: tiny_vgg(),
                weights_seed: 2,
                arrival_rps: f64::INFINITY,
                requests: 40,
                load_steps: vec![],
                mode: ShardMode::Replicated,
                replicas: None,
                slo: SloPolicy {
                    p99_ms: 5000.0,
                    priority: 0,
                    weight: 1.0,
                    overload: None,
                },
            },
        ];
        // `net` is ignored on the multi-tenant path.
        let r = run_fleet(&cfg, &vgg16_prefix(), &ccfg).unwrap();
        assert_eq!(r.tenants.len(), 2);
        assert_eq!(r.completed, 64);
        assert_eq!(r.tenants[0].name, "hi");
        assert_eq!(r.tenants[0].completed, 24);
        assert_eq!(r.tenants[1].completed, 40);
    }

    #[test]
    fn run_fleet_with_tenants_and_reshard_arms_the_unified_engine() {
        use crate::config::{tiny_vgg, SloPolicy, TenantSpec};
        let cfg = AccelConfig::paper_default();
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.boards = 2;
        ccfg.reshard = Some(ReshardPolicy::default_policy());
        ccfg.tenants = vec![TenantSpec {
            name: "solo".to_string(),
            network: tiny_vgg(),
            weights_seed: 1,
            arrival_rps: 500.0,
            requests: 24,
            load_steps: vec![],
            mode: ShardMode::Replicated,
            replicas: None,
            slo: SloPolicy {
                p99_ms: 10.0,
                priority: 1,
                weight: 1.0,
                overload: None,
            },
        }];
        let r = run_fleet(&cfg, &vgg16_prefix(), &ccfg).unwrap();
        assert_eq!(r.completed, 24);
        // The armed controller reports the post-settle tail even when it
        // never needs to move anything.
        assert!(r.tenants[0].tail_p99_ms.is_some());
        assert!(
            r.reshard_events.is_empty(),
            "an idle well-placed tenant must not churn"
        );
    }

    #[test]
    fn run_fleet_with_reshard_policy_uses_the_controller() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let mut ccfg = ClusterConfig::fleet_default();
        ccfg.requests = 64;
        ccfg.reshard = Some(ReshardPolicy::default_policy());
        let r = run_fleet(&cfg, &net, &ccfg).unwrap();
        assert_eq!(r.completed, 64);
        // Starting from the planner's own best shard, the controller has
        // nothing better to move to — no churn on a well-planned fleet.
        assert!(r.reshard_events.is_empty());
    }
}
