//! Offline utility substrate: JSON, CLI parsing, PRNG, property tests,
//! table rendering, statistics, and a micro-bench harness.
//!
//! These exist because the build environment vendors only the `xla` crate's
//! dependency closure — serde/clap/rand/proptest/criterion are unavailable.
pub mod bench;
pub mod cli;
pub mod json;
pub mod math;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
