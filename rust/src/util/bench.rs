//! Micro-bench harness ("criterion-lite").
//!
//! criterion is unavailable offline; `cargo bench` benches in this repo use
//! `harness = false` and drive this module: warmup, fixed-duration sampling,
//! robust stats, and black-box value sinking so the optimizer cannot delete
//! the measured work.

use std::time::{Duration, Instant};

use super::stats::{fmt_ns, Summary};

/// Prevent the optimizer from removing a computed value.
/// (std::hint::black_box is stable since 1.66.)
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Bench configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_samples: 10,
            max_samples: 200,
        }
    }
}

/// Quick config for long-running end-to-end benches where one iteration takes
/// hundreds of ms — fewer samples, shorter budget.
pub fn e2e_config() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(3),
        min_samples: 3,
        max_samples: 30,
    }
}

/// Result of one bench.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn ns_per_iter(&self) -> f64 {
        self.summary.median
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (n={}, ±{} mad, p95 {})",
            self.name,
            fmt_ns(self.summary.median),
            self.summary.n,
            fmt_ns(self.summary.mad),
            fmt_ns(self.summary.p95),
        )
    }
}

/// A bench group that prints results as it goes and collects them.
pub struct Bencher {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher {
            cfg: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    pub fn with_config(cfg: BenchConfig) -> Bencher {
        Bencher {
            cfg,
            results: Vec::new(),
        }
    }

    /// Measure `f`, auto-scaling iterations per sample so each sample takes
    /// ≥ ~1ms (amortizes timer overhead for fast bodies).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + calibration: how many iters fit in ~1ms?
        let warm_end = Instant::now() + self.cfg.warmup;
        let mut calib_iters: u64 = 0;
        let calib_start = Instant::now();
        while Instant::now() < warm_end {
            black_box(f());
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_nanos() as f64 / calib_iters.max(1) as f64;
        let iters_per_sample = ((1e6 / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::new();
        let measure_end = Instant::now() + self.cfg.measure;
        while (Instant::now() < measure_end || samples.len() < self.cfg.min_samples)
            && samples.len() < self.cfg.max_samples
        {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            let dt = t0.elapsed().as_nanos() as f64 / iters_per_sample as f64;
            samples.push(dt);
        }
        let res = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters_per_sample,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Measure a body once (for very expensive bodies where statistics over
    /// repeated runs are unaffordable); still repeated `min_samples` times.
    pub fn bench_once<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        let mut samples = Vec::new();
        for _ in 0..self.cfg.min_samples.max(1) {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters_per_sample: 1,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(10),
            measure: Duration::from_millis(50),
            min_samples: 3,
            max_samples: 10,
        }
    }

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::with_config(fast_cfg());
        let r = b.bench("sum", || (0..1000u64).sum::<u64>());
        assert!(r.ns_per_iter() > 0.0);
        assert!(r.summary.n >= 3);
    }

    #[test]
    fn slower_body_measures_slower() {
        let mut b = Bencher::with_config(fast_cfg());
        let fast = b.bench("fast", || (0..100u64).sum::<u64>()).ns_per_iter();
        let slow = b
            .bench("slow", || (0..100_000u64).fold(0u64, |a, x| a ^ x.wrapping_mul(3)))
            .ns_per_iter();
        assert!(
            slow > fast * 5.0,
            "expected clear separation, fast={fast} slow={slow}"
        );
    }

    #[test]
    fn bench_once_runs_min_samples() {
        let mut b = Bencher::with_config(fast_cfg());
        let mut count = 0;
        b.bench_once("count", || {
            count += 1;
        });
        assert_eq!(count, 3);
    }

    #[test]
    fn results_accumulate() {
        let mut b = Bencher::with_config(fast_cfg());
        b.bench("a", || 1 + 1);
        b.bench("b", || 2 + 2);
        assert_eq!(b.results.len(), 2);
        assert_eq!(b.results[0].name, "a");
    }
}
