//! Portable deterministic math kernels.
//!
//! The workload samplers feed committed golden fixtures
//! (`tests/fixtures/`), so every float op on their path must produce the
//! same bits on every platform. IEEE-754 add/mul/div are exact by spec, but
//! `f64::ln` routes to the platform libm, whose last-ulp behavior differs
//! across libc versions — enough to shift a rounded arrival cycle and
//! cascade through a whole simulated schedule. This module provides a
//! deterministic natural log built only from exactly-specified operations
//! (bit manipulation, add/mul/div), accurate to a couple of ulp — sampling
//! quality is unaffected, and the result is bit-identical everywhere.

use std::f64::consts::{LN_2, SQRT_2};

/// Deterministic natural logarithm for finite `x > 0`.
///
/// Decomposes `x = m·2^e` with `m ∈ (√2/2, √2]`, then evaluates
/// `ln m = 2·atanh(t)` for `t = (m−1)/(m+1)` (|t| ≤ 0.1716) with a fixed
/// 12-term odd series in Horner form. Every step is an exactly-specified
/// IEEE-754 operation, so the result is bit-identical on every conforming
/// platform (unlike the libm `f64::ln`).
pub fn ln_det(x: f64) -> f64 {
    assert!(x > 0.0 && x.is_finite(), "ln_det domain: 0 < x < inf, got {x}");
    let mut bits = x.to_bits();
    let mut e = ((bits >> 52) & 0x7ff) as i64 - 1023;
    if e == -1023 {
        // Subnormal: renormalize by 2^54 (exact).
        bits = (x * 18_014_398_509_481_984.0).to_bits();
        e = ((bits >> 52) & 0x7ff) as i64 - 1023 - 54;
    }
    let mut m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | 0x3ff0_0000_0000_0000);
    if m > SQRT_2 {
        m *= 0.5;
        e += 1;
    }
    let t = (m - 1.0) / (m + 1.0);
    let t2 = t * t;
    // Σ_{j=0..11} t²ʲ/(2j+1), Horner over t².
    let mut p = 1.0 / 23.0;
    p = p * t2 + 1.0 / 21.0;
    p = p * t2 + 1.0 / 19.0;
    p = p * t2 + 1.0 / 17.0;
    p = p * t2 + 1.0 / 15.0;
    p = p * t2 + 1.0 / 13.0;
    p = p * t2 + 1.0 / 11.0;
    p = p * t2 + 1.0 / 9.0;
    p = p * t2 + 1.0 / 7.0;
    p = p * t2 + 1.0 / 5.0;
    p = p * t2 + 1.0 / 3.0;
    p = p * t2 + 1.0;
    e as f64 * LN_2 + 2.0 * t * p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_libm_closely_over_wide_range() {
        // A couple of ulp of agreement with the platform ln is plenty — the
        // point is determinism, not replacing libm.
        let mut x = 1e-12f64;
        while x < 1e12 {
            let got = ln_det(x);
            let want = x.ln();
            let tol = 1e-14 * want.abs().max(1.0);
            assert!(
                (got - want).abs() <= tol,
                "ln_det({x}) = {got} vs libm {want}"
            );
            x *= 1.318;
        }
    }

    #[test]
    fn exact_anchors() {
        assert_eq!(ln_det(1.0), 0.0);
        // ln 2 and ln ½ come straight off the exponent path.
        assert_eq!(ln_det(2.0), LN_2);
        assert_eq!(ln_det(0.5), -LN_2);
        assert_eq!(ln_det(4.0), 2.0 * LN_2);
    }

    #[test]
    fn subnormal_inputs_are_handled() {
        let tiny = f64::from_bits(1); // smallest positive subnormal
        let got = ln_det(tiny);
        let want = tiny.ln();
        assert!((got - want).abs() < 1e-11 * want.abs(), "{got} vs {want}");
    }

    #[test]
    #[should_panic(expected = "ln_det domain")]
    fn rejects_nonpositive() {
        ln_det(0.0);
    }

    #[test]
    fn unit_interval_samples_match_libm() {
        // The sampler's actual domain: 1 − u for u ∈ [0, 1).
        let mut u = 1e-16f64;
        while u < 1.0 {
            let x = 1.0 - u;
            if x > 0.0 {
                let got = ln_det(x);
                let want = x.ln();
                assert!(
                    (got - want).abs() <= 1e-14 * want.abs().max(1e-300),
                    "ln_det({x}) = {got} vs {want}"
                );
            }
            u *= 1.7;
        }
    }
}
