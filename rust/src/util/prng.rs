//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used everywhere randomness is needed (synthetic images, weights, property
//! tests, workload generators) so every experiment is reproducible from a
//! seed. No external `rand` crate is available offline.

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Rejection sampling on the high bits; bias is negligible for the
        // n << 2^64 used here, but do it properly anyway.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (independent stream) — handy for per-request
    /// randomness in the coordinator without sharing state across threads.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let eq = (0..32).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(eq, 0);
    }
}
