//! Small statistics helpers for the bench harness and metric reports.

/// Summary statistics over a sample of measurements (e.g. ns per iteration).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    /// Median absolute deviation — robust spread estimate.
    pub mad: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample (a bench that produced no
    /// measurements is a harness bug).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let mut xs: Vec<f64> = samples.to_vec();
        // Total order instead of `partial_cmp(..).unwrap()`: a NaN sample
        // (degenerate timer math) sorts last instead of panicking the
        // whole harness; order is identical on finite data.
        xs.sort_by(f64::total_cmp);
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let median = percentile_sorted(&xs, 50.0);
        let mut devs: Vec<f64> = xs.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(f64::total_cmp);
        Summary {
            n,
            min: xs[0],
            max: xs[n - 1],
            mean,
            median,
            p95: percentile_sorted(&xs, 95.0),
            mad: percentile_sorted(&devs, 50.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Geometric mean; used for "average speedup across layers" style numbers,
/// where arithmetic means over ratios mislead.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Format a nanosecond quantity human-readably (for bench output).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a byte quantity human-readably.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{:.0} B", b)
    } else if b < 1024.0 * 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Format a count with thousands separators (cycle counts get long).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.mad, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn unsorted_input_is_sorted_internally() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.000 s");
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(42), "42");
    }

    #[test]
    fn mad_is_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.0, 1.0, 1.0, 100.0]);
        assert_eq!(s.mad, 0.0); // median is 1, most deviations are 0
        assert!(s.stddev > 10.0); // but stddev blows up
    }
}
