//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Supports: `prog <subcommand> [--flag] [--key value] [--key=value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed arguments for one invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Declared option/flag spec for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse raw argv (excluding program name). `known` validates option
    /// names; unknown `--options` are an error so typos fail fast.
    pub fn parse(argv: &[String], known: &[OptSpec]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = known
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("option --{name} needs a value"))?,
                    };
                    out.options.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    out.flags.push(name);
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg.clone());
            } else {
                out.positional.push(arg.clone());
            }
        }
        // Fill defaults.
        for spec in known {
            if let Some(d) = spec.default {
                out.options.entry(spec.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(out)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.options.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Render a help string from the spec list.
pub fn render_help(prog: &str, subcommands: &[(&str, &str)], opts: &[OptSpec]) -> String {
    let mut s = format!("usage: {prog} <subcommand> [options]\n\nsubcommands:\n");
    let wid = subcommands.iter().map(|(n, _)| n.len()).max().unwrap_or(0);
    for (name, help) in subcommands {
        s.push_str(&format!("  {:wid$}  {}\n", name, help, wid = wid));
    }
    s.push_str("\noptions:\n");
    let wid = opts.iter().map(|o| o.name.len()).max().unwrap_or(0) + 2;
    for o in opts {
        let name = format!("--{}", o.name);
        let d = o
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  {:wid$}  {}{}\n", name, o.help, d, wid = wid));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "layers",
                takes_value: true,
                help: "number of layers",
                default: Some("7"),
            },
            OptSpec {
                name: "verbose",
                takes_value: false,
                help: "chatty output",
                default: None,
            },
        ]
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_positionals() {
        let a = Args::parse(
            &argv(&["simulate", "--layers", "5", "--verbose", "net.json"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.opt("layers"), Some("5"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["net.json"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&argv(&["run", "--layers=3"]), &specs()).unwrap();
        assert_eq!(a.opt_usize("layers").unwrap(), Some(3));
    }

    #[test]
    fn defaults_fill_in() {
        let a = Args::parse(&argv(&["run"]), &specs()).unwrap();
        assert_eq!(a.opt("layers"), Some("7"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(Args::parse(&argv(&["run", "--bogus"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv(&["run", "--layers"]), &specs()).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(Args::parse(&argv(&["run", "--verbose=yes"]), &specs()).is_err());
    }

    #[test]
    fn bad_int_reports_nicely() {
        let a = Args::parse(&argv(&["run", "--layers", "abc"]), &specs()).unwrap();
        let e = a.opt_usize("layers").unwrap_err();
        assert!(e.contains("abc"));
    }

    #[test]
    fn help_renders() {
        let h = render_help("decoilfnet", &[("simulate", "run the simulator")], &specs());
        assert!(h.contains("simulate"));
        assert!(h.contains("--layers"));
        assert!(h.contains("default: 7"));
    }
}
