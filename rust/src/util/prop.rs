//! Property-test runner ("proptest-lite").
//!
//! `proptest` is unavailable offline; this provides the part we rely on:
//! run a property over many PRNG-generated cases with a fixed seed, and on
//! failure report the seed + case index so the exact case replays, plus a
//! greedy integer-shrink helper for the common "vector of sizes" inputs.

use super::prng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 128,
            seed: 0xDEC0117,
        }
    }
}

/// Run `prop` over `cases` generated inputs. `gen` draws one case from the
/// RNG; `prop` returns `Err(msg)` to fail. Panics with a replayable report.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(cfg.seed);
    for i in 0..cfg.cases {
        // Fork per case: failures replay from (seed, i) without regenerating
        // the preceding cases.
        let mut case_rng = Rng::new(cfg.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {i}/{} (seed=0x{:X}):\n  input: {:?}\n  error: {msg}",
                cfg.cases, cfg.seed, input
            );
        }
        // keep the top-level rng advancing so `gen` may also use it if captured
        let _ = rng.next_u64();
    }
}

/// Shorthand with default config.
pub fn check_default<T: std::fmt::Debug>(
    name: &str,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(name, PropConfig::default(), gen, prop);
}

/// Greedy shrink of a failing `Vec<usize>` case: repeatedly try removing
/// elements and halving values while the property still fails. Returns the
/// smallest failing input found. Used by tests that want minimal repros.
pub fn shrink_vec_usize(
    mut input: Vec<usize>,
    mut fails: impl FnMut(&[usize]) -> bool,
) -> Vec<usize> {
    debug_assert!(fails(&input), "shrink called on a passing input");
    loop {
        let mut progressed = false;
        // Try dropping each element.
        let mut i = 0;
        while i < input.len() {
            let mut cand = input.clone();
            cand.remove(i);
            if fails(&cand) {
                input = cand;
                progressed = true;
            } else {
                i += 1;
            }
        }
        // Try halving each element.
        for i in 0..input.len() {
            while input[i] > 0 {
                let mut cand = input.clone();
                cand[i] /= 2;
                if fails(&cand) {
                    input = cand;
                    progressed = true;
                } else {
                    break;
                }
            }
        }
        if !progressed {
            return input;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0usize;
        check_default(
            "count",
            |r| r.below(100),
            |_| {
                n += 1;
                Ok(())
            },
        );
        assert_eq!(n, PropConfig::default().cases);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_report() {
        check_default("always-fails", |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check_default(
            "collect1",
            |r| r.next_u64(),
            |v| {
                first.push(*v);
                Ok(())
            },
        );
        let mut second: Vec<u64> = Vec::new();
        check_default(
            "collect2",
            |r| r.next_u64(),
            |v| {
                second.push(*v);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }

    #[test]
    fn shrink_finds_minimal_vec() {
        // Property fails iff the vec contains an element >= 10.
        let failing = vec![3, 17, 5, 40];
        let min = shrink_vec_usize(failing, |xs| xs.iter().any(|&x| x >= 10));
        assert_eq!(min, vec![10]);
    }
}
