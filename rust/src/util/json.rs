//! Minimal JSON value, parser and serializer.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure, so `serde`/`serde_json` are unavailable. This module implements
//! the subset of JSON the repo needs: config files, the AOT `manifest.json`
//! written by `python/compile/aot.py`, metric dumps and bench reports.
//!
//! It is a full RFC 8259 parser (strings with escapes incl. `\uXXXX`,
//! numbers, nested containers) minus only surrogate-pair pedantry.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in a `BTreeMap` so serialization
/// is deterministic (stable diffs for golden files).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and a short message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= i64::MIN as f64 && *n <= i64::MAX as f64 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for misses keeps call chains short.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — builder misuse is a bug).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(o) => {
                o.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(mut self, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Arr(a) => a.push(val.into()),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    // ------------------------------------------------------------------
    // Serialization
    // ------------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like serde_json's default would error —
        // we choose null so metric dumps with missing data still round-trip.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{}'", lit)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling for completeness.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                                char::from_u32(combined)
                            } else {
                                None
                            }
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control character in string")),
                Some(b) => {
                    // Re-decode UTF-8 continuation bytes: back up and take the
                    // whole char from the source slice.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(0).as_u64(), Some(1));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-7,"obj":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj()
            .set("name", "decoilfnet")
            .set("layers", vec![1u64, 2, 3])
            .set("fused", true);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn builder_accessors() {
        let v = Json::obj().set("x", 3usize).set("y", 2.5f64);
        assert_eq!(v.get("x").as_usize(), Some(3));
        assert_eq!(v.get("y").as_f64(), Some(2.5));
        assert_eq!(v.get("missing").as_f64(), None);
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn integer_precision_u64() {
        // f64 holds integers exactly to 2^53; our cycle counts stay below.
        let v = parse("9007199254740992").unwrap();
        assert_eq!(v.as_u64(), Some(9007199254740992));
    }

    #[test]
    fn nan_serializes_as_null() {
        let v = Json::Num(f64::NAN);
        assert_eq!(v.to_string_compact(), "null");
    }
}
