//! ASCII / markdown table rendering for experiment reports.
//!
//! Every bench prints a "paper row vs measured row" table; this keeps the
//! formatting in one place and identical across benches and the CLI `report`
//! subcommand.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title(mut self, t: &str) -> Table {
        self.title = Some(t.to_string());
        self
    }

    /// Set alignment per column (defaults to Right; first column commonly Left).
    pub fn align(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Convenience: left-align the first column only.
    pub fn label_col(mut self) -> Table {
        if !self.aligns.is_empty() {
            self.aligns[0] = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Table {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    fn pad(cell: &str, width: usize, align: Align) -> String {
        let len = cell.chars().count();
        let gap = width.saturating_sub(len);
        match align {
            Align::Left => format!("{}{}", cell, " ".repeat(gap)),
            Align::Right => format!("{}{}", " ".repeat(gap), cell),
        }
    }

    /// Render as a boxed ASCII table.
    pub fn to_ascii(&self) -> String {
        let w = self.widths();
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push('|');
        for (i, h) in self.headers.iter().enumerate() {
            out.push(' ');
            out.push_str(&Self::pad(h, w[i], self.aligns[i]));
            out.push_str(" |");
        }
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for (i, c) in row.iter().enumerate() {
                out.push(' ');
                out.push_str(&Self::pad(c, w[i], self.aligns[i]));
                out.push_str(" |");
            }
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as GitHub-flavoured markdown (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("**{}**\n\n", t));
        }
        out.push('|');
        for (i, h) in self.headers.iter().enumerate() {
            out.push(' ');
            out.push_str(&Self::pad(h, w[i], self.aligns[i]));
            out.push_str(" |");
        }
        out.push('\n');
        out.push('|');
        for (i, _) in self.headers.iter().enumerate() {
            match self.aligns[i] {
                Align::Left => out.push_str(&format!("{}|", "-".repeat(w[i] + 2))),
                Align::Right => out.push_str(&format!("{}:|", "-".repeat(w[i] + 1))),
            }
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for (i, c) in row.iter().enumerate() {
                out.push(' ');
                out.push_str(&Self::pad(c, w[i], self.aligns[i]));
                out.push_str(" |");
            }
            out.push('\n');
        }
        out
    }
}

/// Format a speedup ratio the way the paper prints them ("30.93X").
pub fn fmt_speedup(x: f64) -> String {
    if x >= 100.0 {
        format!("{:.0}X", x)
    } else if x >= 10.0 {
        format!("{:.1}X", x)
    } else {
        format!("{:.2}X", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(&["layer", "cycles", "ms"]).label_col();
        t.row_strs(&["conv1_1", "3211264", "26.76"]);
        t.row_strs(&["conv1_2", "3241000", "27.01"]);
        t
    }

    #[test]
    fn ascii_contains_cells_and_borders() {
        let s = sample().to_ascii();
        assert!(s.contains("conv1_1"));
        assert!(s.contains("3211264"));
        assert!(s.starts_with('+'));
        let lines: Vec<&str> = s.lines().collect();
        // top border, header, mid border, 2 rows, bottom border
        assert_eq!(lines.len(), 6);
        // all lines the same width
        let widths: Vec<usize> = lines.iter().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[1].contains("-|") || lines[1].contains(":-") || lines[1].contains("-:"));
        assert!(lines[2].starts_with("| conv1_1"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn alignment() {
        let mut t = Table::new(&["name", "val"]).label_col();
        t.row_strs(&["x", "1"]);
        let s = t.to_ascii();
        // left-aligned label has trailing spaces, right-aligned value leading.
        assert!(s.contains("| x    |"));
        assert!(s.contains("|   1 |"));
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(4.283), "4.28X");
        assert_eq!(fmt_speedup(30.93), "30.9X");
        assert_eq!(fmt_speedup(123.4), "123X");
    }

    #[test]
    fn title_rendering() {
        let mut t = Table::new(&["a"]).title("Table II");
        t.row_strs(&["1"]);
        assert!(t.to_ascii().starts_with("Table II\n"));
        assert!(t.to_markdown().starts_with("**Table II**"));
    }
}
