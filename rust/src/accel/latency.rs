//! Closed-form cycle model (cross-checked against the streaming engine).
//!
//! For a fused group of layers the steady-state pipeline is throttled by its
//! slowest stage; the total is approximately
//!
//! ```text
//! cycles(group) ≈ Σ_l fill_l + max_l work_l + drain
//!   work_l  = out_pixels_l · rate_l          (rate = k·f_g for conv, 1 for pool)
//!   fill_l  = line-buffer fill at the producer's emission rate + pipe latency
//! ```
//!
//! The engine is ground truth (it resolves backpressure exactly); this model
//! exists so the planner can search thousands of plans cheaply, and a test
//! asserts it stays within a few percent of the engine on the paper's nets.

use crate::config::{AccelConfig, Layer, Network};

use super::conv3d::ConvUnit;
use super::engine::Weights;
use super::fusion::FusionPlan;

/// Additive decomposition of one fused group's closed-form estimate.
///
/// `fill` and `drain` are per-activation overheads (line-buffer priming and
/// the last-row DDR writeback); `steady` is the per-inference bottleneck
/// work. A batch of `B` back-to-back inferences through a resident group
/// pays the overheads once: `fill + B·steady + drain` — the same
/// amortization the serving batcher exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupCost {
    pub fill: u64,
    pub steady: u64,
    pub drain: u64,
}

impl GroupCost {
    /// Single-inference cycles (the classic estimate).
    pub fn total(&self) -> u64 {
        self.fill + self.steady + self.drain
    }

    /// Cycles for `batch` back-to-back inferences with the group resident.
    pub fn batched(&self, batch: u64) -> u64 {
        self.fill + self.steady.saturating_mul(batch) + self.drain
    }
}

/// Closed-form cost decomposition for one fused group.
pub fn group_cost_estimate(
    cfg: &AccelConfig,
    net: &Network,
    group: std::ops::Range<usize>,
) -> GroupCost {
    let shapes = net.shapes();
    let mut fill_total = 0u64;
    let mut bottleneck = 0u64;
    // Emission interval of the stream feeding the current layer (cycles per
    // depth-concatenated pixel). The DDR feed for the group's first layer is
    // effectively unconstrained relative to compute rates here.
    let mut feed_interval = {
        let in_sh = shapes[group.start];
        let px_bytes = (in_sh.d * cfg.platform.word_bytes) as f64;
        (px_bytes / cfg.platform.ddr_bytes_per_cycle).ceil() as u64
    }
    .max(1);

    for li in group.clone() {
        let in_sh = shapes[li];
        match &net.layers[li] {
            Layer::Conv {
                kernel,
                filters,
                padding,
                ..
            } => {
                let unit = ConvUnit::for_layer(cfg, *kernel, in_sh.d, *filters);
                let rate = unit.cycles_per_output_pixel();
                // Fill: (kernel − 1 − pad) rows + (kernel − pad) pixels at
                // the incoming rate, plus the arithmetic pipeline latency.
                let fill_px = ((kernel - 1 - padding.min(&(kernel - 1))) * in_sh.w
                    + (kernel - padding))
                    as u64;
                fill_total += fill_px * feed_interval + unit.stage().latency;
                let out = net.shape_after(li);
                let work = (out.h * out.w) as u64 * rate;
                bottleneck = bottleneck.max(work);
                feed_interval = rate;
            }
            Layer::MaxPool { window, stride, .. } => {
                // A pooled row needs `window` input rows: fill = window rows
                // at the incoming rate.
                fill_total += (*window * in_sh.w) as u64 * feed_interval;
                let out = net.shape_after(li);
                let work = (out.h * out.w) as u64; // II=1
                bottleneck = bottleneck.max(work);
                // Each pooled pixel aggregates stride² inputs: emission
                // interval grows accordingly.
                feed_interval *= (stride * stride) as u64;
            }
        }
    }

    // Drain: the group output crosses DDR; at the output rate this overlaps
    // compute except the last row.
    let out_sh = shapes[group.end];
    let drain = ((out_sh.w * out_sh.d * cfg.platform.word_bytes) as f64
        / cfg.platform.ddr_bytes_per_cycle)
        .ceil() as u64;

    GroupCost {
        fill: fill_total,
        steady: bottleneck,
        drain,
    }
}

/// Closed-form estimate for one fused group. `shapes` are the network's
/// volume shapes (`shapes[i]` = input of layer i).
pub fn group_cycles_estimate(
    cfg: &AccelConfig,
    net: &Network,
    group: std::ops::Range<usize>,
) -> u64 {
    group_cost_estimate(cfg, net, group).total()
}

/// Closed-form estimate for `batch` back-to-back inferences of a whole plan:
/// per group, fill/drain are paid once and steady-state work `batch` times.
pub fn plan_batch_cycles_estimate(
    cfg: &AccelConfig,
    net: &Network,
    plan: &FusionPlan,
    batch: u64,
) -> u64 {
    plan.groups()
        .into_iter()
        .map(|g| group_cost_estimate(cfg, net, g).batched(batch))
        .sum()
}

/// Closed-form estimate for a whole plan (groups serialize).
pub fn plan_cycles_estimate(cfg: &AccelConfig, net: &Network, plan: &FusionPlan) -> u64 {
    plan.groups()
        .into_iter()
        .map(|g| group_cycles_estimate(cfg, net, g))
        .sum()
}

/// DDR traffic of one fused group in bytes (exact): input volume in +
/// weights in + output volume out.
pub fn group_traffic_bytes(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
    group: std::ops::Range<usize>,
) -> u64 {
    let shapes = net.shapes();
    let wb = cfg.platform.word_bytes;
    let in_sh = shapes[group.start];
    let out_sh = shapes[group.end];
    (in_sh.elems() * wb) as u64
        + (out_sh.elems() * wb) as u64
        + weights.bytes_for_layers(group, wb)
}

/// DDR traffic of a plan in bytes (exact, not an estimate): per group, the
/// input volume in + weights in + output volume out. (Single shape-inference
/// pass; `group_traffic_bytes` is the one-off per-group entry point.)
pub fn plan_traffic_bytes(
    cfg: &AccelConfig,
    net: &Network,
    weights: &Weights,
    plan: &FusionPlan,
) -> u64 {
    let shapes = net.shapes();
    let wb = cfg.platform.word_bytes;
    let mut bytes = 0u64;
    for g in plan.groups() {
        bytes += (shapes[g.start].elems() * wb) as u64;
        bytes += (shapes[g.end].elems() * wb) as u64;
        bytes += weights.bytes_for_layers(g, wb);
    }
    bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::engine::Engine;
    use crate::config::{paper_test_example, tiny_vgg, vgg16_prefix, AccelConfig};

    fn relative_error(a: u64, b: u64) -> f64 {
        (a as f64 - b as f64).abs() / b as f64
    }

    #[test]
    fn closed_form_tracks_engine_on_paper_nets() {
        let cfg = AccelConfig::paper_default();
        let engine = Engine::new(cfg.clone());
        for (net, tol) in [
            (vgg16_prefix(), 0.06),
            (crate::config::custom_4conv(), 0.06),
            (tiny_vgg(), 0.25), // small nets: fill terms dominate, coarser
            (paper_test_example(), 0.8),
        ] {
            let w = Weights::random(&net, 1);
            let n = net.layers.len();
            for plan in [FusionPlan::fully_fused(n), FusionPlan::unfused(n)] {
                let sim = engine.simulate(&net, &w, &plan).total_cycles;
                let est = plan_cycles_estimate(&cfg, &net, &plan);
                let err = relative_error(est, sim);
                assert!(
                    err < tol,
                    "{} {}: est {est} vs sim {sim} (err {err:.3})",
                    net.name,
                    plan.label()
                );
            }
        }
    }

    #[test]
    fn batched_cost_decomposition_is_consistent() {
        let cfg = AccelConfig::paper_default();
        let net = tiny_vgg();
        let n = net.layers.len();
        for plan in [FusionPlan::fully_fused(n), FusionPlan::unfused(n)] {
            // batch=1 reduces to the single-inference estimate.
            assert_eq!(
                plan_batch_cycles_estimate(&cfg, &net, &plan, 1),
                plan_cycles_estimate(&cfg, &net, &plan)
            );
            // Amortization: a batch of 8 is strictly cheaper than 8 singles
            // (fill/drain paid once), but no cheaper than 8× steady work.
            let b8 = plan_batch_cycles_estimate(&cfg, &net, &plan, 8);
            let single = plan_cycles_estimate(&cfg, &net, &plan);
            assert!(b8 < 8 * single, "{}: {b8} vs {single}", plan.label());
            let steady: u64 = plan
                .groups()
                .into_iter()
                .map(|g| group_cost_estimate(&cfg, &net, g).steady)
                .sum();
            assert!(b8 >= 8 * steady);
        }
    }

    #[test]
    fn traffic_matches_engine_exactly() {
        let cfg = AccelConfig::paper_default();
        let engine = Engine::new(cfg.clone());
        let net = tiny_vgg();
        let w = Weights::random(&net, 2);
        for plan in [
            FusionPlan::fully_fused(7),
            FusionPlan::unfused(7),
            FusionPlan::from_group_sizes(7, &[3, 2, 2]).unwrap(),
        ] {
            let sim = engine.simulate(&net, &w, &plan);
            let est = plan_traffic_bytes(&cfg, &net, &w, &plan);
            assert_eq!(
                sim.ddr_read_bytes + sim.ddr_write_bytes,
                est,
                "plan {}",
                plan.label()
            );
        }
    }

    #[test]
    fn fused_traffic_less_than_unfused() {
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 3);
        let fused = plan_traffic_bytes(&cfg, &net, &w, &FusionPlan::fully_fused(7));
        let unfused = plan_traffic_bytes(&cfg, &net, &w, &FusionPlan::unfused(7));
        assert!(fused < unfused / 3, "fused {fused} vs unfused {unfused}");
    }

    #[test]
    fn paper_traffic_magnitude() {
        // Fully fused VGG prefix ≈ input (0.57 MB) + weights (2.2 MB) +
        // output (3.06 MB) ≈ 5.9 MB — the paper's Table IV says 6.69 MB
        // (их accounting includes alignment/bias padding; same magnitude).
        let cfg = AccelConfig::paper_default();
        let net = vgg16_prefix();
        let w = Weights::random(&net, 4);
        let mb = plan_traffic_bytes(&cfg, &net, &w, &FusionPlan::fully_fused(7)) as f64
            / (1024.0 * 1024.0);
        assert!((5.0..8.0).contains(&mb), "got {mb} MB");
    }
}
