//! Inter-layer fusion plans (paper §III-E, §V, Fig 7).
//!
//! A fusion plan partitions the network's layer sequence into contiguous
//! groups. Layers within a group are pipelined on chip (intermediates never
//! touch DDR); groups execute serially with their boundary volumes spilled
//! to and reloaded from DDR. Point A of Fig 7 is "every layer its own
//! group"; point G is "one group containing everything".

use crate::config::Network;

/// A fusion plan: group `i` covers layers `[bounds[i], bounds[i+1])`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusionPlan {
    n_layers: usize,
    /// Ascending cut points; always starts at 0 and ends at n_layers.
    bounds: Vec<usize>,
}

impl FusionPlan {
    /// Build from explicit group sizes (must sum to the layer count).
    pub fn from_group_sizes(n_layers: usize, sizes: &[usize]) -> Result<FusionPlan, String> {
        if sizes.iter().any(|&s| s == 0) {
            return Err("empty fusion group".to_string());
        }
        let total: usize = sizes.iter().sum();
        if total != n_layers {
            return Err(format!(
                "group sizes sum to {total}, network has {n_layers} layers"
            ));
        }
        let mut bounds = vec![0usize];
        for &s in sizes {
            bounds.push(bounds.last().unwrap() + s);
        }
        Ok(FusionPlan { n_layers, bounds })
    }

    /// Every layer its own group (Fig 7 point A / the unfused baseline).
    pub fn unfused(n_layers: usize) -> FusionPlan {
        FusionPlan::from_group_sizes(n_layers, &vec![1; n_layers]).unwrap()
    }

    /// One group spanning the whole network (Fig 7 point G / DeCoILFNet's
    /// headline configuration for the VGG prefix).
    pub fn fully_fused(n_layers: usize) -> FusionPlan {
        FusionPlan::from_group_sizes(n_layers, &[n_layers]).unwrap()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_groups(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Layer index ranges of each group.
    pub fn groups(&self) -> Vec<std::ops::Range<usize>> {
        self.bounds
            .windows(2)
            .map(|w| w[0]..w[1])
            .collect()
    }

    pub fn group_sizes(&self) -> Vec<usize> {
        self.bounds.windows(2).map(|w| w[1] - w[0]).collect()
    }

    /// Which group a layer belongs to.
    pub fn group_of(&self, layer: usize) -> usize {
        assert!(layer < self.n_layers);
        self.bounds.partition_point(|&b| b <= layer) - 1
    }

    /// Invariant check: groups are a contiguous, complete, non-overlapping
    /// partition (property-tested in the coordinator planner).
    pub fn is_valid_partition(&self) -> bool {
        self.bounds.first() == Some(&0)
            && self.bounds.last() == Some(&self.n_layers)
            && self.bounds.windows(2).all(|w| w[0] < w[1])
    }

    /// Short human label, e.g. "[2|3|2]".
    pub fn label(&self) -> String {
        let sizes: Vec<String> = self.group_sizes().iter().map(|s| s.to_string()).collect();
        format!("[{}]", sizes.join("|"))
    }
}

/// Enumerate all 2^(n−1) contiguous-group fusion plans of an `n`-layer
/// network (the Fig 7 design space; n = 7 for the VGG prefix ⇒ 64 plans).
pub fn enumerate_plans(n_layers: usize) -> Vec<FusionPlan> {
    assert!(n_layers >= 1 && n_layers <= 20, "enumeration explodes past 20");
    let mut out = Vec::new();
    // Bitmask over the n−1 possible cut points.
    for mask in 0..(1u32 << (n_layers - 1)) {
        let mut bounds = vec![0usize];
        for cut in 0..n_layers - 1 {
            if mask & (1 << cut) != 0 {
                bounds.push(cut + 1);
            }
        }
        bounds.push(n_layers);
        out.push(FusionPlan {
            n_layers,
            bounds,
        });
    }
    out
}

/// The named Fig 7 sweep for a 7-layer network: A = unfused … G = one group.
/// Intermediate points fuse progressively larger prefixes, matching the
/// paper's "grouped fusion of five convolutions and two pooling layers".
pub fn fig7_points(net: &Network) -> Vec<(char, FusionPlan)> {
    let n = net.layers.len();
    assert_eq!(n, 7, "fig7 sweep is defined for the 7-layer VGG prefix");
    vec![
        ('A', FusionPlan::from_group_sizes(n, &[1, 1, 1, 1, 1, 1, 1]).unwrap()),
        ('B', FusionPlan::from_group_sizes(n, &[2, 1, 1, 1, 1, 1]).unwrap()),
        ('C', FusionPlan::from_group_sizes(n, &[3, 1, 1, 1, 1]).unwrap()),
        ('D', FusionPlan::from_group_sizes(n, &[4, 1, 1, 1]).unwrap()),
        ('E', FusionPlan::from_group_sizes(n, &[5, 1, 1]).unwrap()),
        ('F', FusionPlan::from_group_sizes(n, &[6, 1]).unwrap()),
        ('G', FusionPlan::fully_fused(n)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::vgg16_prefix;
    use crate::util::prng::Rng;
    use crate::util::prop;

    #[test]
    fn group_sizes_roundtrip() {
        let p = FusionPlan::from_group_sizes(7, &[2, 3, 2]).unwrap();
        assert_eq!(p.group_sizes(), vec![2, 3, 2]);
        assert_eq!(p.n_groups(), 3);
        assert_eq!(p.groups(), vec![0..2, 2..5, 5..7]);
        assert_eq!(p.label(), "[2|3|2]");
        assert!(p.is_valid_partition());
    }

    #[test]
    fn rejects_bad_sizes() {
        assert!(FusionPlan::from_group_sizes(7, &[2, 3]).is_err());
        assert!(FusionPlan::from_group_sizes(7, &[0, 7]).is_err());
        assert!(FusionPlan::from_group_sizes(7, &[8]).is_err());
    }

    #[test]
    fn group_of_lookup() {
        let p = FusionPlan::from_group_sizes(7, &[2, 3, 2]).unwrap();
        assert_eq!(p.group_of(0), 0);
        assert_eq!(p.group_of(1), 0);
        assert_eq!(p.group_of(2), 1);
        assert_eq!(p.group_of(4), 1);
        assert_eq!(p.group_of(5), 2);
        assert_eq!(p.group_of(6), 2);
    }

    #[test]
    fn unfused_and_fused_extremes() {
        assert_eq!(FusionPlan::unfused(5).n_groups(), 5);
        assert_eq!(FusionPlan::fully_fused(5).n_groups(), 1);
    }

    #[test]
    fn enumeration_counts() {
        assert_eq!(enumerate_plans(1).len(), 1);
        assert_eq!(enumerate_plans(3).len(), 4);
        assert_eq!(enumerate_plans(7).len(), 64);
    }

    #[test]
    fn enumeration_all_valid_and_unique() {
        let plans = enumerate_plans(7);
        for p in &plans {
            assert!(p.is_valid_partition());
        }
        let mut labels: Vec<String> = plans.iter().map(|p| p.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 64);
    }

    #[test]
    fn fig7_points_progression() {
        let pts = fig7_points(&vgg16_prefix());
        assert_eq!(pts.len(), 7);
        assert_eq!(pts[0].1.n_groups(), 7);
        assert_eq!(pts[6].1.n_groups(), 1);
        // monotone decreasing group count
        for w in pts.windows(2) {
            assert!(w[0].1.n_groups() > w[1].1.n_groups());
        }
    }

    #[test]
    fn property_partition_invariants() {
        prop::check_default(
            "fusion-partition",
            |r: &mut Rng| {
                let n = r.range_usize(1, 12);
                let plans = enumerate_plans(n);
                let pick = r.range_usize(0, plans.len() - 1);
                (n, plans[pick].clone())
            },
            |(n, plan)| {
                if !plan.is_valid_partition() {
                    return Err("invalid partition".into());
                }
                // every layer in exactly one group
                let mut seen = vec![0usize; *n];
                for g in plan.groups() {
                    for l in g {
                        seen[l] += 1;
                    }
                }
                if seen.iter().all(|&c| c == 1) {
                    Ok(())
                } else {
                    Err(format!("coverage {seen:?}"))
                }
            },
        );
    }
}
