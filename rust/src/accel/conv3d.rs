//! The pipelined 3-D convolution module (paper §III-C).
//!
//! Structure per the paper: the depth-concatenated window is split into d_g
//! parallel 2-D windows; w·w·d_g DSP multipliers and a LUT adder tree produce
//! one filter's 3-D dot product; the k filters (× f_g serial depth groups)
//! stream through the same unit one per cycle while the window is held.

use crate::config::AccelConfig;
use crate::fpga::dsp::{conv2d_unit_stage, depth_sum_stage};
use crate::fpga::pipeline::Stage;
use crate::tensor::fixed::{Fx, MacAcc};

use super::depth_concat::FilterBanks;

/// Static configuration of one conv layer's compute unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvUnit {
    /// Kernel extent (w × w).
    pub w: usize,
    /// Input depth of the layer.
    pub d: usize,
    /// Channels processed in parallel (depth-group size d_g ≤ d).
    pub d_par: usize,
    /// Serial depth groups f_g = ceil(d / d_par) (§V iterative decomposition).
    pub d_groups: usize,
    /// Filters in the layer.
    pub k: usize,
    /// DSP multiplier pipeline depth.
    pub mult_latency: u64,
}

impl ConvUnit {
    pub fn for_layer(cfg: &AccelConfig, w: usize, d: usize, k: usize) -> ConvUnit {
        let d_par = cfg.depth_parallel(d);
        ConvUnit {
            w,
            d,
            d_par,
            d_groups: cfg.depth_groups(d),
            k,
            mult_latency: cfg.mult_latency as u64,
        }
    }

    /// Pipeline stage of the unit: latency
    /// `9·(1 + 2·ceil(log2 w) + ceil(log2 d_par))` per §III-C (45 for w=3
    /// alone, 63 with the d=3 depth-sum), II = 1 filter-result per cycle.
    pub fn stage(&self) -> Stage {
        conv2d_unit_stage(self.w, self.mult_latency)
            .then(depth_sum_stage(self.d_par, self.mult_latency))
    }

    /// Cycles between successive *complete output pixels*: the window is held
    /// while the k filters stream through, repeated for each serial depth
    /// group — `k · f_g` (paper §III-E + §V).
    pub fn cycles_per_output_pixel(&self) -> u64 {
        (self.k * self.d_groups) as u64
    }

    /// DSP multiplier lanes instantiated: w·w·d_par.
    pub fn dsp_lanes(&self) -> usize {
        self.w * self.w * self.d_par
    }

    /// Functional: one output pixel (all k filters) from a gathered
    /// depth-concatenated window of `w·w` taps × `d` channels
    /// (`window[t*d + c]`), replicating the hardware's accumulation order:
    /// per filter, per depth group, taps multiply in parallel and reduce;
    /// groups accumulate serially into the widened accumulator; bias and
    /// optional ReLU at the end. Bit-exact w.r.t. the simulated datapath.
    pub fn compute_pixel(&self, window: &[Fx], banks: &FilterBanks, relu: bool) -> Vec<Fx> {
        let mut accs = vec![MacAcc::new(); self.k];
        self.compute_pixel_into(window, banks, relu, &mut accs)
    }

    /// `compute_pixel` with a caller-provided accumulator scratch (the
    /// functional simulator reuses it across all output pixels — §Perf L3).
    ///
    /// Loop order: window-value-outer, filters-inner over the transposed
    /// bank view, so each window value broadcasts across a unit-stride
    /// weight row (vectorizes). The arithmetic is identical to the
    /// hardware's filter-serial order — integer MAC addition commutes
    /// exactly, unlike floats — which the `group_decomposition_is_exact`
    /// test pins down.
    pub fn compute_pixel_into(
        &self,
        window: &[Fx],
        banks: &FilterBanks,
        relu: bool,
        accs: &mut [MacAcc],
    ) -> Vec<Fx> {
        debug_assert_eq!(window.len(), self.w * self.w * self.d);
        debug_assert_eq!(banks.d, self.d);
        debug_assert_eq!(banks.k, self.k);
        debug_assert_eq!(accs.len(), self.k);
        let taps = self.w * self.w;
        for a in accs.iter_mut() {
            *a = MacAcc::new();
        }
        for t in 0..taps {
            for c in 0..self.d {
                let x = window[t * self.d + c].0 as i64;
                if x == 0 {
                    continue; // padding/ReLU zeros are common; skip the row
                }
                let wrow = banks.tap_channel_all_filters(t, c);
                for (a, w) in accs.iter_mut().zip(wrow) {
                    a.0 = a.0.saturating_add(x * w.0 as i64);
                }
            }
        }
        let mut out = Vec::with_capacity(self.k);
        for (f, acc) in accs.iter_mut().enumerate() {
            acc.add_bias(banks.bias(f));
            let v = acc.finish();
            out.push(if relu { v.relu() } else { v });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::NdTensor;
    use crate::util::prng::Rng;
    use crate::util::prop;

    fn random_banks(rng: &mut Rng, k: usize, w: usize, d: usize) -> FilterBanks {
        let filt = NdTensor::random(&[k, w, w, d], rng.next_u64(), -0.5, 0.5);
        let bias = NdTensor::random(&[k], rng.next_u64(), -0.5, 0.5);
        FilterBanks::from_tensor(&filt, &bias)
    }

    fn unit(cfg_cap: usize, w: usize, d: usize, k: usize) -> ConvUnit {
        let mut cfg = AccelConfig::paper_default();
        cfg.max_depth_parallel = cfg_cap;
        ConvUnit::for_layer(&cfg, w, d, k)
    }

    /// Float reference for one pixel.
    fn ref_pixel(window: &[Fx], banks: &FilterBanks, w: usize, d: usize, relu: bool) -> Vec<f64> {
        let taps = w * w;
        (0..banks.k)
            .map(|f| {
                let mut s = 0.0f64;
                for t in 0..taps {
                    for c in 0..d {
                        s += window[t * d + c].to_f64() * banks.tap(f, t)[c].to_f64();
                    }
                }
                s += banks.bias(f).to_f64();
                if relu {
                    s.max(0.0)
                } else {
                    s
                }
            })
            .collect()
    }

    #[test]
    fn paper_latency_and_rate() {
        // The §III test example: w=3, d=3, k=3, depth fully parallel.
        let u = unit(8, 3, 3, 3);
        assert_eq!(u.d_par, 3);
        assert_eq!(u.d_groups, 1);
        assert_eq!(u.stage().latency, 63);
        assert_eq!(u.stage().ii, 1);
        assert_eq!(u.cycles_per_output_pixel(), 3);
        assert_eq!(u.dsp_lanes(), 27);
    }

    #[test]
    fn vgg_later_layer_decomposes() {
        // conv2_2: d=128, cap 64 → 2 serial groups; k=128 → 256 cyc/pixel.
        let u = unit(64, 3, 128, 128);
        assert_eq!(u.d_par, 64);
        assert_eq!(u.d_groups, 2);
        assert_eq!(u.cycles_per_output_pixel(), 256);
        assert_eq!(u.dsp_lanes(), 9 * 64);
    }

    #[test]
    fn compute_matches_float_reference() {
        prop::check_default(
            "conv3d-pixel-vs-ref",
            |r: &mut Rng| {
                let w = 3usize;
                let d = r.range_usize(1, 12);
                let k = r.range_usize(1, 6);
                let cap = r.range_usize(1, 12);
                (w, d, k, cap, r.next_u64())
            },
            |&(w, d, k, cap, seed)| {
                let mut rng = Rng::new(seed);
                let banks = random_banks(&mut rng, k, w, d);
                let u = unit(cap, w, d, k);
                let window: Vec<Fx> = (0..w * w * d)
                    .map(|_| Fx::from_f32(rng.range_f32(-1.0, 1.0)))
                    .collect();
                let got = u.compute_pixel(&window, &banks, false);
                let want = ref_pixel(&window, &banks, w, d, false);
                for (g, wv) in got.iter().zip(&want) {
                    // full-width accumulator: error ≤ 1 quantization step
                    if (g.to_f64() - wv).abs() > Fx::epsilon() {
                        return Err(format!("pixel err {} vs {}", g.to_f64(), wv));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn group_decomposition_is_exact() {
        // Serial depth groups must give bit-identical results to full-depth
        // processing (the accumulator is wide enough that order is exact).
        let mut rng = Rng::new(99);
        let (w, d, k) = (3, 10, 4);
        let banks = random_banks(&mut rng, k, w, d);
        let window: Vec<Fx> = (0..w * w * d)
            .map(|_| Fx::from_f32(rng.range_f32(-2.0, 2.0)))
            .collect();
        let full = unit(16, w, d, k).compute_pixel(&window, &banks, false);
        for cap in [1, 2, 3, 4, 7] {
            let grouped = unit(cap, w, d, k).compute_pixel(&window, &banks, false);
            assert_eq!(full, grouped, "cap={cap} changed results");
        }
    }

    #[test]
    fn relu_applies() {
        let mut rng = Rng::new(5);
        let banks = random_banks(&mut rng, 3, 3, 2);
        let u = unit(8, 3, 2, 3);
        let window: Vec<Fx> = (0..18).map(|_| Fx::from_f32(rng.range_f32(-2.0, 2.0))).collect();
        let plain = u.compute_pixel(&window, &banks, false);
        let relued = u.compute_pixel(&window, &banks, true);
        for (p, r) in plain.iter().zip(&relued) {
            assert_eq!(r.to_f32(), p.to_f32().max(0.0));
        }
    }
}
