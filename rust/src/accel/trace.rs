//! Pipeline trace export: turn a [`SimReport`](super::engine::SimReport)
//! into a structured timeline (JSON) for debugging fusion schedules and for
//! the CLI's `trace` subcommand. The paper's Fig 5 ("Overall Pipeline
//! design") is essentially this view: per layer, when it starts producing,
//! when it finishes, and the steady-state rate.

use crate::accel::engine::SimReport;
use crate::config::Network;
use crate::util::json::Json;

/// One row of the timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    pub layer: String,
    pub first_out: u64,
    pub last_out: u64,
    pub rate: u64,
    pub out_pixels: u64,
    /// Fraction of the run this layer spent actively producing.
    pub occupancy: f64,
    /// Overlap with the previous layer's production window, in cycles —
    /// the quantitative version of the paper's Fig 5 staircase.
    pub overlap_with_prev: u64,
}

/// Build the timeline from a report.
pub fn timeline(net: &Network, rep: &SimReport) -> Vec<TraceRow> {
    let total = rep.total_cycles.max(1);
    let mut rows: Vec<TraceRow> = Vec::new();
    for (i, lt) in rep.per_layer.iter().enumerate() {
        let overlap = if i == 0 {
            0
        } else {
            let prev = &rep.per_layer[i - 1];
            // Overlap of [first_out, last_out] windows.
            prev.last_out.min(lt.last_out).saturating_sub(lt.first_out.max(prev.first_out))
        };
        rows.push(TraceRow {
            layer: lt.name.clone(),
            first_out: lt.first_out,
            last_out: lt.last_out,
            rate: lt.rate,
            out_pixels: lt.out_pixels,
            occupancy: (lt.last_out - lt.first_out) as f64 / total as f64,
            overlap_with_prev: overlap,
        });
    }
    debug_assert_eq!(rows.len(), net.layers.len());
    rows
}

/// JSON export (for dashboards / diffing schedules).
pub fn to_json(net: &Network, rep: &SimReport) -> Json {
    let rows = timeline(net, rep);
    let mut arr = Json::Arr(vec![]);
    for r in rows {
        arr = arr.push(
            Json::obj()
                .set("layer", r.layer.as_str())
                .set("first_out", r.first_out)
                .set("last_out", r.last_out)
                .set("rate", r.rate)
                .set("out_pixels", r.out_pixels)
                .set("occupancy", r.occupancy)
                .set("overlap_with_prev", r.overlap_with_prev),
        );
    }
    Json::obj()
        .set("network", net.name.as_str())
        .set("total_cycles", rep.total_cycles)
        .set("ddr_read_bytes", rep.ddr_read_bytes)
        .set("ddr_write_bytes", rep.ddr_write_bytes)
        .set("layers", arr)
}

/// ASCII rendering of the Fig 5 staircase: one bar per layer spanning its
/// production window, scaled to `width` columns.
pub fn ascii_gantt(net: &Network, rep: &SimReport, width: usize) -> String {
    let rows = timeline(net, rep);
    let total = rep.total_cycles.max(1) as f64;
    let name_w = rows.iter().map(|r| r.layer.len()).max().unwrap_or(4);
    let mut out = String::new();
    for r in &rows {
        let a = ((r.first_out as f64 / total) * width as f64).round() as usize;
        let b = ((r.last_out as f64 / total) * width as f64).round() as usize;
        let b = b.max(a + 1).min(width);
        out.push_str(&format!(
            "{:name_w$} |{}{}{}| rate {}\n",
            r.layer,
            " ".repeat(a),
            "█".repeat(b - a),
            " ".repeat(width - b),
            r.rate,
            name_w = name_w
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{Engine, FusionPlan, Weights};
    use crate::config::{tiny_vgg, vgg16_prefix, AccelConfig};

    fn setup(fused: bool) -> (Network, SimReport) {
        let net = vgg16_prefix();
        let w = Weights::random(&net, 1);
        let plan = if fused {
            FusionPlan::fully_fused(7)
        } else {
            FusionPlan::unfused(7)
        };
        let rep = Engine::new(AccelConfig::paper_default()).simulate(&net, &w, &plan);
        (net, rep)
    }

    #[test]
    fn fused_layers_overlap_unfused_do_not() {
        let (_, fused) = setup(true);
        let (_, unfused) = setup(false);
        let net = vgg16_prefix();
        let tf = timeline(&net, &fused);
        let tu = timeline(&net, &unfused);
        // Fused: every conv beyond the first overlaps its producer heavily.
        for r in &tf[1..] {
            assert!(
                r.overlap_with_prev > 0,
                "{} must overlap its producer when fused",
                r.layer
            );
        }
        // Unfused: layer production windows are serialized by DDR spills —
        // overlap must be (near) zero.
        for r in &tu[1..] {
            assert_eq!(r.overlap_with_prev, 0, "{} overlapped while unfused", r.layer);
        }
    }

    #[test]
    fn occupancy_bounded_and_pipeline_dense() {
        let (net, rep) = setup(true);
        for r in timeline(&net, &rep) {
            assert!((0.0..=1.0).contains(&r.occupancy), "{}", r.layer);
        }
        // The first conv spans nearly the whole fused run.
        let rows = timeline(&net, &rep);
        assert!(rows[0].occupancy > 0.9);
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let (net, rep) = setup(true);
        let j = to_json(&net, &rep);
        let txt = j.to_string_pretty();
        let back = crate::util::json::parse(&txt).unwrap();
        assert_eq!(back.get("total_cycles").as_u64(), Some(rep.total_cycles));
        assert_eq!(back.get("layers").as_arr().unwrap().len(), 7);
    }

    #[test]
    fn gantt_renders_all_layers() {
        let net = tiny_vgg();
        let w = Weights::random(&net, 2);
        let rep = Engine::new(AccelConfig::paper_default()).simulate(
            &net,
            &w,
            &FusionPlan::fully_fused(7),
        );
        let g = ascii_gantt(&net, &rep, 60);
        assert_eq!(g.lines().count(), 7);
        assert!(g.contains("conv1_1"));
        assert!(g.contains('█'));
        // every line same visual width prefix structure
        for line in g.lines() {
            assert!(line.contains('|'));
        }
    }
}
