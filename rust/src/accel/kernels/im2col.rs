//! im2col lowering into the depth-major scratch layout.
//!
//! Each output pixel's receptive field becomes one contiguous scratch row of
//! `kernel² · d` values in `tap·d + c` order — the same depth-concatenated
//! word layout the paper's line buffer emits (§III-B), which is what lets
//! the MAC kernel consume all channels of a window in one unit-stride burst.
//! Because feature maps are `[h, w, c]` row-major, every kernel row of a
//! window is a single contiguous `run·d` copy from the input (clipped at the
//! zero-padded borders), so the lowering is memcpy-bound, not gather-bound.

use std::ops::Range;

use crate::tensor::fixed::Fx;
use crate::tensor::FxTensor;

use super::ConvGeom;

/// Lower output rows `rows` of the conv described by `geom` into `col`,
/// which must hold exactly `(rows.len() · out_w) · patch` values. Row
/// `(oy - rows.start)·out_w + ox` of `col` is the depth-major window of
/// output pixel `(oy, ox)`, zero-padded outside the image.
pub fn im2col_band(input: &FxTensor, geom: &ConvGeom, rows: Range<usize>, col: &mut [Fx]) {
    let (w, d) = (geom.w, geom.d);
    let (kernel, pad) = (geom.kernel, geom.pad);
    let ow = geom.out_w();
    let patch = geom.patch();
    assert_eq!(col.len(), (rows.end - rows.start) * ow * patch);
    let data = input.data();

    for oy in rows.clone() {
        let band_row = oy - rows.start;
        for ox in 0..ow {
            let dst_row = &mut col[(band_row * ow + ox) * patch..][..patch];
            // Columns of the window that land on real pixels: dx in
            // [dx_lo, dx_hi) maps to input column ox + dx - pad.
            let dx_lo = pad.saturating_sub(ox);
            let dx_hi = kernel.min(w + pad - ox);
            for dy in 0..kernel {
                let tap_base = dy * kernel * d;
                let iy = oy + dy;
                if iy < pad || iy - pad >= geom.h {
                    dst_row[tap_base..tap_base + kernel * d].fill(Fx::ZERO);
                    continue;
                }
                let ry = iy - pad;
                // Zero the clipped taps, then one contiguous copy for the
                // valid run (runs are depth-contiguous in both layouts).
                dst_row[tap_base..tap_base + dx_lo * d].fill(Fx::ZERO);
                dst_row[tap_base + dx_hi * d..tap_base + kernel * d].fill(Fx::ZERO);
                if dx_lo < dx_hi {
                    let rx = ox + dx_lo - pad;
                    let run = (dx_hi - dx_lo) * d;
                    let src = (ry * w + rx) * d;
                    dst_row[tap_base + dx_lo * d..tap_base + dx_hi * d]
                        .copy_from_slice(&data[src..src + run]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::NdTensor;

    fn geom(h: usize, w: usize, d: usize, kernel: usize, pad: usize) -> ConvGeom {
        ConvGeom {
            h,
            w,
            d,
            kernel,
            pad,
            filters: 1,
        }
    }

    /// Scalar reference: index arithmetic straight from the definition.
    fn reference(input: &FxTensor, g: &ConvGeom, oy: usize, ox: usize) -> Vec<Fx> {
        let mut out = Vec::with_capacity(g.patch());
        for dy in 0..g.kernel {
            for dx in 0..g.kernel {
                for c in 0..g.d {
                    let (iy, ix) = (oy + dy, ox + dx);
                    let v = if iy < g.pad || ix < g.pad {
                        Fx::ZERO
                    } else {
                        let (ry, rx) = (iy - g.pad, ix - g.pad);
                        if ry >= g.h || rx >= g.w {
                            Fx::ZERO
                        } else {
                            input.at3(ry, rx, c)
                        }
                    };
                    out.push(v);
                }
            }
        }
        out
    }

    #[test]
    fn matches_reference_with_and_without_padding() {
        for &(h, w, d, pad) in &[(5usize, 7usize, 3usize, 1usize), (4, 4, 2, 0), (3, 3, 1, 2)] {
            let g = geom(h, w, d, 3, pad);
            let input = NdTensor::random(&[h, w, d], 3, -1.0, 1.0).to_fixed();
            let (oh, ow, patch) = (g.out_h(), g.out_w(), g.patch());
            let mut col = vec![Fx::ZERO; oh * ow * patch];
            im2col_band(&input, &g, 0..oh, &mut col);
            for oy in 0..oh {
                for ox in 0..ow {
                    let got = &col[(oy * ow + ox) * patch..][..patch];
                    let want = reference(&input, &g, oy, ox);
                    assert_eq!(got, &want[..], "h={h} w={w} pad={pad} at ({oy},{ox})");
                }
            }
        }
    }

    #[test]
    fn band_slices_agree_with_full_lowering() {
        let g = geom(9, 6, 4, 3, 1);
        let input = NdTensor::random(&[9, 6, 4], 8, -1.0, 1.0).to_fixed();
        let (oh, ow, patch) = (g.out_h(), g.out_w(), g.patch());
        let mut full = vec![Fx::ZERO; oh * ow * patch];
        im2col_band(&input, &g, 0..oh, &mut full);
        for r0 in 0..oh {
            for r1 in r0 + 1..=oh {
                let mut band = vec![Fx::ZERO; (r1 - r0) * ow * patch];
                im2col_band(&input, &g, r0..r1, &mut band);
                assert_eq!(band, full[r0 * ow * patch..r1 * ow * patch].to_vec());
            }
        }
    }

    #[test]
    fn stale_scratch_contents_are_fully_overwritten() {
        // Every slot is written (zero-fill or copy), so a dirty buffer from a
        // previous layer cannot leak through.
        let g = geom(4, 4, 2, 3, 1);
        let input = NdTensor::random(&[4, 4, 2], 2, -1.0, 1.0).to_fixed();
        let n = g.out_h() * g.out_w() * g.patch();
        let mut clean = vec![Fx::ZERO; n];
        im2col_band(&input, &g, 0..g.out_h(), &mut clean);
        let mut dirty = vec![Fx::from_f32(123.0); n];
        im2col_band(&input, &g, 0..g.out_h(), &mut dirty);
        assert_eq!(clean, dirty);
    }
}
