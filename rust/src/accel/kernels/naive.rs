//! The naive per-pixel, per-channel conv walk — the bit-exact oracle.
//!
//! This is the loop nest the paper's depth flattening exists to kill: one
//! output pixel at a time, one filter at a time, one tap at a time, one
//! channel at a time, with indexed tensor reads and a window re-gathered per
//! filter. It is kept (a) as the ground-truth oracle the blocked kernel is
//! property-tested against, and (b) as the "before" side of
//! `benches/compute_kernels.rs`, whose `BENCH_compute.json` tracks the
//! speedup of the depth-flattened path over this walk.
//!
//! Accumulation per (pixel, filter) is ascending `tap·d + c` with
//! [`MacAcc`] saturating adds — the identical order and arithmetic of both
//! the blocked kernel and the hardware-mirroring
//! [`crate::accel::conv3d::ConvUnit`], which is what makes bit-equality a
//! meaningful assertion rather than a tolerance check.

use crate::accel::depth_concat::FilterBanks;
use crate::accel::pool::PoolUnit;
use crate::config::{Layer, Network};
use crate::tensor::fixed::{Fx, MacAcc};
use crate::tensor::FxTensor;

use crate::accel::engine::Weights;

use super::ConvGeom;

/// Textbook convolution: no lowering, no blocking, no threading.
pub fn conv2d_fx_naive(input: &FxTensor, banks: &FilterBanks, pad: usize, relu: bool) -> FxTensor {
    let geom = ConvGeom::for_input(input, banks, pad);
    let (oh, ow) = (geom.out_h(), geom.out_w());
    let (kernel, d, k) = (geom.kernel, geom.d, geom.filters);
    let mut out = FxTensor::zeros(&[oh, ow, k]);
    for oy in 0..oh {
        for ox in 0..ow {
            for f in 0..k {
                let mut acc = MacAcc::new();
                for dy in 0..kernel {
                    for dx in 0..kernel {
                        let (iy, ix) = (oy + dy, ox + dx);
                        if iy < pad || ix < pad {
                            continue;
                        }
                        let (ry, rx) = (iy - pad, ix - pad);
                        if ry >= geom.h || rx >= geom.w {
                            continue;
                        }
                        let tap = banks.tap(f, dy * kernel + dx);
                        for (c, wv) in tap.iter().enumerate().take(d) {
                            acc.mac(input.at3(ry, rx, c), *wv);
                        }
                    }
                }
                acc.add_bias(banks.bias(f));
                let v = acc.finish();
                out.set3(oy, ox, f, if relu { v.relu() } else { v });
            }
        }
    }
    out
}

/// Whole-network forward on the naive walk (pooling shared with the fast
/// path — it was never the hot spot).
pub fn forward_network_fx_naive(net: &Network, weights: &Weights, input: &FxTensor) -> FxTensor {
    let mut cur = input.clone();
    for (li, layer) in net.layers.iter().enumerate() {
        cur = match layer {
            Layer::Conv { padding, relu, .. } => {
                let banks = weights.banks[li].as_ref().expect("conv layer needs weights");
                conv2d_fx_naive(&cur, banks, *padding, *relu)
            }
            Layer::MaxPool { window, stride, .. } => PoolUnit::new(*window, *stride).forward(&cur),
        };
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::conv3d::ConvUnit;
    use crate::config::AccelConfig;
    use crate::fpga::line_buffer::WindowSchedule;
    use crate::tensor::NdTensor;
    use crate::util::prng::Rng;

    /// The naive walk must agree bit-for-bit with the hardware-mirroring
    /// `ConvUnit::compute_pixel` path (window gathered via the line-buffer
    /// schedule) — the pre-kernel `forward_fx` implementation.
    #[test]
    fn naive_matches_conv_unit_pixelwise() {
        let mut rng = Rng::new(21);
        let (h, w, d, k, pad) = (7, 6, 5, 4, 1);
        let filt = NdTensor::random(&[k, 3, 3, d], rng.next_u64(), -0.5, 0.5);
        let bias = NdTensor::random(&[k], rng.next_u64(), -0.1, 0.1);
        let banks = FilterBanks::from_tensor(&filt, &bias);
        let input = NdTensor::random(&[h, w, d], rng.next_u64(), -1.0, 1.0).to_fixed();
        let got = conv2d_fx_naive(&input, &banks, pad, true);

        let cfg = AccelConfig::paper_default();
        let unit = ConvUnit::for_layer(&cfg, 3, d, k);
        let sched = WindowSchedule::new(h, w, 3, pad);
        let mut window = vec![Fx::ZERO; 9 * d];
        for oy in 0..sched.out_h() {
            for ox in 0..sched.out_w() {
                for dy in 0..3 {
                    for dx in 0..3 {
                        let t = dy * 3 + dx;
                        let (iy, ix) = (oy + dy, ox + dx);
                        let dst = &mut window[t * d..(t + 1) * d];
                        if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                            dst.fill(Fx::ZERO);
                        } else {
                            dst.copy_from_slice(input.pixel(iy - pad, ix - pad));
                        }
                    }
                }
                let pixel = unit.compute_pixel(&window, &banks, true);
                for (f, v) in pixel.iter().enumerate() {
                    assert_eq!(got.at3(oy, ox, f), *v, "pixel ({oy},{ox}) filter {f}");
                }
            }
        }
    }
}
